"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's built-in ``cost_analysis()`` counts every while-loop body ONCE, which
makes it useless for scanned layer stacks (an 80-layer scan reports 1/80 of
the FLOPs). This module re-derives per-device quantities from the compiled
module text, weighting each computation by the product of enclosing loop
trip counts (``backend_config={"known_trip_count":{"n":...}}``):

  * matmul_flops  — 2 x numel(result) x contraction for every dot op;
  * hbm_bytes     — per-instruction result+operand bytes at fusion
                    granularity (fusion internals stay in VMEM/registers);
  * collectives   — result bytes per collective kind, with wire-byte factors
                    and an ICI/DCN split derived from the replica groups
                    (a group that spans a pod boundary is DCN traffic).

Everything is computed on the per-device partitioned module, matching the
roofline convention "per chip".
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w.\-]+) \(")
_INSTR = re.compile(r"^\s*(?:ROOT )?%?([\w.\-]+) = (.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
# wire-bytes multiplier on the result size (ring algorithms, n>>1)
WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_of(body: str) -> str:
    """The result-shape portion of an instruction body (before the op name)."""
    # body looks like: "f32[512,512]{1,0} dot(%a, %b), ..." or tuple shapes
    m = re.match(r"^((?:\([^)]*\)|[\w\[\],{}\/ ]+?)) ([a-z][\w\-]*)\(", body)
    if not m:
        return ""
    return m.group(1)


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result: str           # result shape text
    operands: list[str]
    body: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    params: dict[str, str]       # param name -> shape text


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                name = m.group(2)
                # parse params from header: (a: f32[..], b: bf16[..])
                params = {}
                pm = re.search(r"\((.*)\) ->", line)
                if pm:
                    for part in pm.group(1).split(","):
                        if ":" in part:
                            pname, pshape = part.split(":", 1)
                            params[pname.strip().lstrip("%")] = pshape.strip()
                cur = Computation(name=name, instrs=[], params=params)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, body = m.group(1), m.group(2)
        opm = re.search(r"^(?:\([^)]*\)|[\w\[\],{}\/ ]+?) ([a-z][\w\-]*)\(",
                        body)
        op = opm.group(1) if opm else ""
        # operand names: %refs inside the first (...) after the op name
        operands = []
        if opm:
            after = body[opm.end():]
            depth = 1
            arg = ""
            for ch in after:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                arg += ch
            operands = re.findall(r"%([\w.\-]+)", arg)
        cur.instrs.append(Instr(name=name, op=op, result=_result_of(body),
                                operands=operands, body=body))
    return comps


def _shape_of(comp: Computation, ref: str) -> str:
    for ins in comp.instrs:
        if ins.name == ref:
            return ins.result
    return comp.params.get(ref, "")


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = 0
    for dt, dims in _SHAPE_RE.findall(ins.result):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out_elems += n
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.body)
    contract = 1
    if m and ins.operands:
        lhs_shape = _shape_of(comp, ins.operands[0])
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    # batch dims are part of both result and lhs; contraction covers the rest
    return 2.0 * out_elems * contract


def _replica_group_crosses(body: str, boundary: int) -> bool:
    """True if any replica group mixes devices from different pods
    (device id // boundary differs)."""
    m = _GROUPS_EXPLICIT.search(body)
    if m:
        groups = m.group(1).replace("{", " ").replace("}", " ").split()
        try:
            first = [int(x) for x in groups[0].split(",") if x]
        except ValueError:
            first = []
        gs: list[list[int]] = []
        for chunk in re.findall(r"[0-9][0-9, ]*", m.group(1)):
            ids = [int(x) for x in chunk.replace(" ", "").split(",") if x]
            if ids:
                gs.append(ids)
        return any(len({i // boundary for i in g}) > 1 for g in gs)
    m = _GROUPS_IOTA.search(body)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) \
            else list(range(len(dims)))
        ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm) \
            .reshape(g, s)
        return bool(any(len({int(i) // boundary for i in row}) > 1
                        for row in ids))
    return False


@dataclasses.dataclass
class HLOStats:
    matmul_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_result_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    collective_wire_bytes_ici: float = 0.0
    collective_wire_bytes_dcn: float = 0.0
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVE_KINDS})

    def add(self, other: "HLOStats", w: float) -> None:
        self.matmul_flops += other.matmul_flops * w
        self.hbm_bytes += other.hbm_bytes * w
        for k in COLLECTIVE_KINDS:
            self.collective_result_bytes[k] += \
                other.collective_result_bytes[k] * w
            self.collective_counts[k] += other.collective_counts[k] * w
        self.collective_wire_bytes_ici += other.collective_wire_bytes_ici * w
        self.collective_wire_bytes_dcn += other.collective_wire_bytes_dcn * w


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}


def analyze(text: str, *, pod_boundary: int = 256) -> HLOStats:
    comps = parse_hlo(text)
    memo: dict[str, HLOStats] = {}

    def comp_stats(name: str) -> HLOStats:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        st = HLOStats()
        memo[name] = st
        if comp is None:
            return st
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                trip = 1
                tm = _TRIP.search(ins.body)
                if tm:
                    trip = int(tm.group(1))
                bm = re.search(r"body=%?([\w.\-]+)", ins.body)
                if bm:
                    st.add(comp_stats(bm.group(1)), trip)
                continue
            if op in ("call", "fusion", "async-start"):
                cm = _CALLS.search(ins.body)
                if cm:
                    sub = comp_stats(cm.group(1))
                    st.matmul_flops += sub.matmul_flops
                    # collectives inside fusions/calls still count
                    st.add(dataclasses.replace(
                        sub, matmul_flops=0.0, hbm_bytes=0.0), 1.0)
                if op == "fusion":
                    # fusion = one read of operands + one write of result
                    b = _shape_bytes(ins.result)
                    for ref in ins.operands:
                        b += _shape_bytes(_shape_of(comp, ref))
                    st.hbm_bytes += b
                else:
                    cm2 = _CALLS.search(ins.body)
                    if cm2:
                        st.hbm_bytes += comp_stats(cm2.group(1)).hbm_bytes
                continue
            if op == "conditional":
                bm = _COND_BRANCHES.search(ins.body)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                    subs = [comp_stats(b) for b in branches if b in comps]
                    if subs:
                        worst = max(subs, key=lambda s: s.matmul_flops
                                    + s.hbm_bytes)
                        st.add(worst, 1.0)
                continue
            if op == "dot":
                st.matmul_flops += _dot_flops(comp, ins)
            base = op.replace("-start", "")
            if base in COLLECTIVE_KINDS:
                b = _shape_bytes(ins.result)
                st.collective_result_bytes[base] += b
                st.collective_counts[base] += 1
                wire = b * WIRE_FACTOR[base]
                if _replica_group_crosses(ins.body, pod_boundary):
                    st.collective_wire_bytes_dcn += wire
                else:
                    st.collective_wire_bytes_ici += wire
            if op in _SKIP_BYTES_OPS or op.endswith("-done"):
                continue
            if op == "dynamic-update-slice":
                # in-place slice write: read+write the update, not the
                # whole buffer (KV-cache updates would otherwise count the
                # entire cache per decode step)
                upd = _shape_of(comp, ins.operands[1]) if len(ins.operands) > 1 \
                    else ins.result
                st.hbm_bytes += 2 * _shape_bytes(upd)
                continue
            if op == "dynamic-slice":
                st.hbm_bytes += 2 * _shape_bytes(ins.result)
                continue
            b = _shape_bytes(ins.result)
            for ref in ins.operands:
                b += _shape_bytes(_shape_of(comp, ref))
            st.hbm_bytes += b
        return st

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(2)
            break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comp_stats(entry)
