import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder devices. Do not import
this module from tests (they must see one device) — run it as a script:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k --mesh single,multi --out results/dryrun

Per cell it records memory_analysis, cost_analysis (per-device FLOPs/bytes)
and the per-device collective-bytes breakdown parsed from the compiled HLO,
which launch/roofline.py turns into the three roofline terms.
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, arch_ids, cells, get_config
from repro.models import model as M
from repro.train.optimizer import init_opt_state
from repro.train.train_step import TrainConfig, make_train_step
from repro.launch.hloanalysis import analyze as hlo_analyze
from repro.launch.mesh import dp_size, make_production_mesh
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    param_shardings, replicated)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes produced by each collective kind (result shapes)."""
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            token = f" {kind}("
            if token not in line and f" {kind}-start(" not in line:
                continue
            lhs = line.split(" = ")
            if len(lhs) < 2:
                continue
            rhs = lhs[1]
            cut = rhs.find(kind)
            shapes = _SHAPE_RE.findall(rhs[:cut])
            for dt, dims in shapes:
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                out[kind] += n * _DTYPE_BYTES[dt]
            counts[kind] += 1
            break
    out["counts"] = counts
    return out


def pick_n_micro(cfg: ArchConfig, shape, mesh) -> int:
    """Gradient-accumulation depth: target ~4k (8k for small d_model) tokens
    per device per microbatch; must divide the per-device batch."""
    bd = max(1, shape.global_batch // dp_size(mesh))
    seq = shape.seq_len if not cfg.enc_dec else shape.seq_len
    target = 8192 if cfg.d_model <= 2048 else 4096
    n = max(1, min(bd, (bd * seq) // target))
    while bd % n:
        n -= 1
    return n


def lower_cell(arch: str, shape_name: str, mesh, *, n_micro: int | None = None,
               remat: bool = True, grad_rs: bool = False):
    """Returns (lowered, meta). Raises on sharding/compile errors."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    from repro.launch.mesh import data_axes as _da
    from repro.models import shardctx
    shardctx.set_mesh(mesh, _da(mesh))
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    max_enc = shape.seq_len
    max_dec = max(1, shape.seq_len // 8)

    def init_p(k):
        return M.init_params(cfg, k, max_enc=max_enc, max_dec=max_dec)

    params_sds = jax.eval_shape(init_p, key_sds)
    p_sh = param_shardings(mesh, params_sds)

    if shape.kind == "train":
        nm = n_micro if n_micro is not None else pick_n_micro(cfg, shape, mesh)
        tcfg = TrainConfig(n_microbatches=nm, remat=remat)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        o_sh = param_shardings(mesh, opt_sds)
        batch_sds = M.input_specs(cfg, shape)
        b_sh = batch_shardings(mesh, batch_sds)
        from repro.launch.mesh import data_axes
        step = make_train_step(cfg, tcfg, mesh=mesh, dp_axes=data_axes(mesh),
                               grad_shardings=p_sh if grad_rs else None)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        meta = {"mode": "train", "n_micro": nm}
    elif shape.kind == "prefill":
        batch_sds = M.input_specs(cfg, shape)
        b_sh = batch_shardings(mesh, batch_sds)
        fn = lambda p, b: M.prefill(cfg, p, b)
        # pin the output cache shardings: without them the compiler emits
        # unsharded (replicated) caches — tens of GB per device at 32k.
        out_sds = jax.eval_shape(fn, params_sds, batch_sds)
        logits_sh = batch_shardings(mesh, {"logits": out_sds[0]})["logits"]
        c_sh = cache_shardings(mesh, out_sds[1], cfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                         out_shardings=(logits_sh, c_sh))
        lowered = jitted.lower(params_sds, batch_sds)
        meta = {"mode": "prefill"}
    else:   # decode
        B = shape.global_batch
        caches_sds = jax.eval_shape(
            lambda: M.init_caches(cfg, B, shape.seq_len))
        c_sh = cache_shardings(mesh, caches_sds, cfg)
        tok_sds = M.input_specs(cfg, shape)["tokens"]
        t_sh = batch_shardings(mesh, {"tokens": tok_sds})["tokens"]
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        fn = lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
        jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh, replicated(mesh)),
                         out_shardings=(None, c_sh), donate_argnums=(1,))
        lowered = jitted.lower(params_sds, caches_sds, tok_sds, pos_sds)
        meta = {"mode": "decode"}
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             n_micro: int | None = None, remat: bool = True,
             grad_rs: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, n_micro=n_micro,
                                   remat=remat, grad_rs=grad_rs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        txt = compiled.as_text()
        st = hlo_analyze(txt, pod_boundary=256)
        rec.update(meta)
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            # xla cost_analysis (NOTE: counts loop bodies once — kept for
            # reference only; the hlo_* fields are trip-count weighted)
            "xla_flops_per_device": cost.get("flops", 0.0),
            "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
            "hlo_matmul_flops_per_device": st.matmul_flops,
            "hlo_hbm_bytes_per_device": st.hbm_bytes,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
            "collective_result_bytes": st.collective_result_bytes,
            "collective_counts": st.collective_counts,
            "collective_wire_bytes_ici": st.collective_wire_bytes_ici,
            "collective_wire_bytes_dcn": st.collective_wire_bytes_dcn,
        })
    except Exception as e:  # sharding mismatch / OOM at compile are bugs
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="comma list or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--grad-rs", action="store_true",
                    help="pin microbatch grads to the ZeRO sharding "
                         "(reduce-scatter instead of all-reduce)")
    args = ap.parse_args()

    archs = arch_ids() if args.arch == "all" else args.arch.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        valid = cells(arch)
        shapes = valid if args.shape == "all" else \
            [s for s in args.shape.split(",") if s in valid]
        for shape_name in shapes:
            for mesh_kind in meshes:
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_kind}.json")
                if os.path.exists(path):
                    print(f"skip (exists): {path}", flush=True)
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_kind}", flush=True)
                rec = run_cell(arch, shape_name, mesh_kind,
                               n_micro=args.n_micro,
                               remat=not args.no_remat,
                               grad_rs=args.grad_rs)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = "OK" if rec.get("ok") else "FAIL"
                print(f"    -> {status} lower={rec.get('lower_s')}s "
                      f"compile={rec.get('compile_s')}s "
                      f"flops/dev={rec.get('flops_per_device', 0):.3e} "
                      f"peak={rec.get('peak_bytes', 0)/1e9:.2f}GB", flush=True)
                if not rec.get("ok"):
                    print(rec.get("error"), flush=True)


if __name__ == "__main__":
    main()
