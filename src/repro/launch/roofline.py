"""Roofline analysis from the dry-run records (single-pod table).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (and a DCN-class cross-pod path reported separately for multi-pod
records). Terms, all per-device (= per-chip; the partitioned module is what
the dry-run analyzed):

  compute    = hlo_matmul_flops / PEAK_FLOPS
  memory     = hlo_hbm_bytes   / HBM_BW
  collective = wire_ici / ICI_BW  (+ wire_dcn / DCN_BW on the pod axis)

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per device, with
backward (3x fwd) and the per-group remat recompute (1x fwd on scanned
layers) as the *useful* training arithmetic convention. The ratio
MODEL_FLOPS / hlo_matmul_flops exposes replication/recompute waste.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import SHAPES, get_config
from repro.models.model import count_params_analytic

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 3.125e9
HBM_BYTES = 16e9
CHIPS = {"single": 256, "multi": 512}


def model_flops_per_device(arch: str, shape_name: str, mesh: str,
                           mode: str) -> float:
    """Useful arithmetic per device per step (6ND convention)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = count_params_analytic(cfg, active_only=True)
    # exclude the embedding table from N (standard 6ND convention), keep head
    n_active -= cfg.vocab * cfg.d_model
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.enc_dec:
            tokens = shape.global_batch * (shape.seq_len +
                                           shape.seq_len // 8) // 2
        flops = 6 * n_active * tokens          # fwd(2) + bwd(4)
        flops += 2 * n_active * tokens         # full remat: one extra fwd
    elif mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        flops = 2 * n_active * tokens
    return flops / CHIPS[mesh]


def load_records(results_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    compute = rec["hlo_matmul_flops_per_device"] / PEAK_FLOPS
    memory = rec["hlo_hbm_bytes_per_device"] / HBM_BW
    ici = rec["collective_wire_bytes_ici"] / ICI_BW
    dcn = rec["collective_wire_bytes_dcn"] / DCN_BW
    coll = ici + dcn
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    useful = model_flops_per_device(arch, shape, mesh, rec.get("mode", "train"))
    step = max(terms.values())
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "mode": rec.get("mode"),
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "collective_ici_s": ici, "collective_dcn_s": dcn,
        "dominant": dominant,
        "model_flops_per_dev": useful,
        "hlo_flops_per_dev": rec["hlo_matmul_flops_per_device"],
        "useful_ratio": useful / max(rec["hlo_matmul_flops_per_device"], 1.0),
        "peak_gb": rec["peak_bytes"] / 1e9,
        "fits_hbm": rec["peak_bytes"] <= HBM_BYTES,
        # roofline fraction: useful flops time over the actual bound
        "roofline_fraction": (useful / PEAK_FLOPS) / step if step else 0.0,
    }


def build_table(results_dir: str, mesh: str = "single") -> list[dict]:
    rows = []
    for rec in load_records(results_dir):
        if not rec.get("ok") or rec["mesh"] != mesh:
            continue
        rows.append(roofline_row(rec))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'md':3s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dom':>10s} {'useful%':>8s} "
           f"{'roofl%':>7s} {'peakGB':>7s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mode'][:3]:3s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
            f"{100*r['useful_ratio']:8.1f} {100*r['roofline_fraction']:7.1f} "
            f"{r['peak_gb']:7.2f} {str(r['fits_hbm'])[:5]:>5s}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="results/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.results, args.mesh)
    print(fmt_table(rows))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {args.json_out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
