"""Simulation CLI: run a registered scenario, or an ad-hoc grid, across
replication strategies.

  PYTHONPATH=src python -m repro.launch.simulate --strategy hrs bhr lru \
      --jobs 500 --wan-mbps 10
  PYTHONPATH=src python -m repro.launch.simulate --scenario cache_starved

Both forms build a ``ScenarioSpec`` and run it through
``repro.launch.experiments.run_spec`` — the same config-driven path the
benchmarks and the scenario runner use. For machine-readable multi-scenario
output use ``python -m repro.launch.experiments`` instead.
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.core import (ChurnSpec, ECON_BACKENDS, OBS_MODES, SCENARIOS,
                        STRATEGIES, STRATEGY_MODES, SCHEDULERS, ScenarioSpec,
                        get_scenario)
from repro.core.simulator import NETS
from repro.launch.experiments import run_spec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="run a registered scenario instead of the ad-hoc "
                         "grid flags below")
    ap.add_argument("--strategy", nargs="+", default=["hrs", "bhr", "lru"],
                    choices=list(STRATEGIES))
    ap.add_argument("--scheduler", default="dataaware",
                    choices=list(SCHEDULERS))
    ap.add_argument("--jobs", type=int, default=None,
                    help="job count (default: 500, or the scenario's)")
    ap.add_argument("--wan-mbps", type=float, default=10.0)
    ap.add_argument("--lan-mbps", type=float, default=1000.0)
    ap.add_argument("--regions", type=int, default=4)
    ap.add_argument("--sites", type=int, default=13)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--net", default=None, choices=list(NETS),
                    help="network-engine backend (default: the scenario's, "
                         "or 'numpy'; 'topmost' = legacy single-uplink model)")
    ap.add_argument("--econ", default=None, choices=list(ECON_BACKENDS),
                    help="replication-economy value-scoring backend "
                         "(default: the scenario's, or 'numpy')")
    ap.add_argument("--strategy-mode", default=None, choices=list(STRATEGY_MODES),
                    help="strategy planning engine (default: the scenario's, "
                         "or 'sequential'; 'batch' plans each arrival burst "
                         "in one strategy_plan kernel pass)")
    ap.add_argument("--econ-interval", type=float, default=None,
                    help="seconds between proactive-replication rounds "
                         "(default: auto — armed only for the economic/"
                         "predictive strategies; 0 disables)")
    ap.add_argument("--obs", default=None, choices=list(OBS_MODES),
                    help="telemetry mode (default: the scenario's, or off; "
                         "report/series/trace print the measured phase "
                         "breakdown per run — see docs/OBSERVABILITY.md)")
    ap.add_argument("--obs-interval", type=float, default=None,
                    help="sim-seconds between telemetry ring-buffer samples "
                         "(series/trace modes; default 300)")
    ap.add_argument("--failures", type=int, default=0,
                    help="number of random site failures to inject")
    args = ap.parse_args()

    if args.scenario is not None:
        spec = get_scenario(args.scenario)
        if args.failures:
            spec = dataclasses.replace(spec, churn=ChurnSpec(
                n_failures=args.failures,
                window=(2000.0, 2000.0 * (args.failures + 1)),
                mean_downtime_s=4000.0))
    else:
        churn = ChurnSpec(n_failures=args.failures,
                          window=(2000.0, 2000.0 * (args.failures + 1)),
                          mean_downtime_s=4000.0) if args.failures else ChurnSpec()
        spec = ScenarioSpec(
            name="cli", description="ad-hoc CLI grid",
            tier_fanouts=(args.regions, args.sites),
            lan_mbps=args.lan_mbps, uplink_mbps=(args.wan_mbps,),
            scheduler=args.scheduler, churn=churn, seeds=(args.seed,))
    if args.net is not None:
        spec = dataclasses.replace(spec, net=args.net)
    if args.econ is not None:
        spec = dataclasses.replace(spec, econ=args.econ)
    if args.strategy_mode is not None:
        spec = dataclasses.replace(spec, strategy_mode=args.strategy_mode)
    if args.econ_interval is not None:
        spec = dataclasses.replace(spec, econ_interval_s=args.econ_interval)
    if args.obs is not None:
        spec = dataclasses.replace(spec, obs=args.obs)
    if args.obs_interval is not None:
        spec = dataclasses.replace(spec, obs_interval_s=args.obs_interval)
    print(f"{'strategy':>14} {'avg_job_time':>13} {'inter/job':>10} "
          f"{'WAN GB':>8} {'makespan':>10}")
    for strat in args.strategy:
        r = run_spec(dataclasses.replace(spec, strategy=strat),
                     seed=args.seed, n_jobs=args.jobs)
        print(f"{strat:>14} {r.avg_job_time:>12.0f}s {r.avg_inter_comms:>10.2f} "
              f"{r.total_wan_gb:>8.1f} {r.makespan:>9.0f}s")
        if r.telemetry is not None:
            ph = r.telemetry.phase_breakdown()
            print(f"{'':>14} phases[s]: "
                  f"dispatch={ph['dispatch_s']:.3f} "
                  f"strategy_plan={ph['strategy_plan_s']:.3f} "
                  f"flush={ph['flush_s']:.3f} other={ph['other_s']:.3f} "
                  f"(wall={r.telemetry.wall_s:.3f}, "
                  f"samples={r.telemetry.n_samples})")


if __name__ == "__main__":
    main()
