"""Simulation CLI: run any (scheduler x strategy) on the paper's grid.

  PYTHONPATH=src python -m repro.launch.simulate --strategy hrs bhr lru \
      --jobs 500 --wan-mbps 10
"""

from __future__ import annotations

import argparse

from repro.core import SCHEDULERS, STRATEGIES, GridConfig, run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", nargs="+", default=["hrs", "bhr", "lru"],
                    choices=list(STRATEGIES))
    ap.add_argument("--scheduler", default="dataaware",
                    choices=list(SCHEDULERS))
    ap.add_argument("--jobs", type=int, default=500)
    ap.add_argument("--wan-mbps", type=float, default=10.0)
    ap.add_argument("--lan-mbps", type=float, default=1000.0)
    ap.add_argument("--regions", type=int, default=4)
    ap.add_argument("--sites", type=int, default=13)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--failures", type=int, default=0,
                    help="number of random site failures to inject")
    args = ap.parse_args()

    cfg = GridConfig(n_regions=args.regions, sites_per_region=args.sites,
                     wan_bandwidth=args.wan_mbps * 1e6 / 8,
                     lan_bandwidth=args.lan_mbps * 1e6 / 8,
                     n_jobs=args.jobs, seed=args.seed)
    n_sites = args.regions * args.sites
    failures = [((3 + 7 * i) % n_sites, 2000.0 * (i + 1), 4000.0)
                for i in range(args.failures)]
    print(f"{'strategy':>14} {'avg_job_time':>13} {'inter/job':>10} "
          f"{'WAN GB':>8} {'makespan':>10}")
    for strat in args.strategy:
        r = run_experiment(cfg, scheduler=args.scheduler, strategy=strat,
                           n_jobs=args.jobs, failures=failures or None)
        print(f"{strat:>14} {r.avg_job_time:>12.0f}s {r.avg_inter_comms:>10.2f} "
              f"{r.total_wan_gb:>8.1f} {r.makespan:>9.0f}s")


if __name__ == "__main__":
    main()
