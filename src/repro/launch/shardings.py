"""Sharding rules: parameter / batch / cache PartitionSpecs per mesh.

Strategy (DESIGN.md §4): FSDP(ZeRO-3) over ``data`` x tensor parallel over
``model``; batch over (pod, data); experts over ``model`` (EP); KV caches
shard batch over (pod, data) and KV-heads-or-head-dim over ``model``; the
long-context (batch=1) cells shard the cache *sequence* over the data axes
(flash-decoding style — XLA inserts the partial-softmax reductions).

Every spec passes a divisibility guard: a mesh axis that does not divide the
dim is dropped (replicated) rather than failing, with documented fallbacks
for the big tables (embed/lm_head shard d_model when vocab is odd-sized).
"""

from __future__ import annotations

import os
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import data_axes, dp_size, model_size

# trailing-dims spec tables keyed by parameter leaf name ------------------
_DENSE_2D = {
    "wq": ("data", "model"), "wk": ("data", "model"), "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w1": ("data", "model"), "w2": ("model", "data"),
    "in_proj": ("data", "model"), "x_proj": ("model", None),
    "dt_proj": (None, "model"), "out_proj": ("model", "data"),
    "in_z": ("data", "model"), "in_x": ("data", "model"),
    "in_B": ("data", None), "in_C": ("data", None), "in_dt": ("data", "model"),
    "conv_w": ("model", None), "conv_x_w": ("model", None),
    "conv_B_w": (None, None), "conv_C_w": (None, None),
    "router": ("data", None),
    # embed: vocab over model, d replicated — the lookup is a masked local
    # gather + all-reduce and the logits matmul shards the vocab axis
    # without gathering the table.
    "embed": ("model", None), "lm_head": (None, "model"),
    "enc_pos": (None, None), "dec_pos": (None, None),
}
_VEC = {
    "bq": ("model",), "bk": ("model",), "bv": ("model",), "bo": (None,),
    "b1": ("model",), "b2": (None,),
    "conv_b": ("model",), "conv_x_b": ("model",),
    "conv_B_b": (None,), "conv_C_b": (None,),
    "dt_bias": ("model",), "D_skip": ("model",), "norm_w": ("model",),
}
# fallbacks when the primary spec does not divide (vocab not % 16)
_FALLBACK_2D = {
    "embed": (None, "model"),       # shard d_model instead
    "lm_head": ("data", None),
}


def _keystr(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _fits(spec: tuple, shape: tuple, axis_sizes: dict) -> tuple:
    """Drop axes that don't divide their dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        else:
            size = axis_sizes.get(ax, 1)
            out.append(ax if dim % size == 0 else None)
    return tuple(out)


def param_pspec(path: tuple[str, ...], shape: tuple[int, ...],
                axis_sizes: dict) -> P:
    name = path[-1]
    in_moe = "moe" in path and "res" not in path
    spec: tuple | None = None
    if name in ("wg", "wu"):
        spec = ("model", "data", None) if in_moe else ("data", "model")
    elif name == "wd":
        spec = ("model", None, "data") if in_moe else ("model", "data")
    elif name == "A_log":
        # mamba1: (..., Di, N) with Di >> N; mamba2: (..., nh)
        spec = ("model", None) if len(shape) >= 2 and shape[-2] > shape[-1] \
            else ("model",)
    elif name in _DENSE_2D:
        spec = _DENSE_2D[name]
    elif name in _VEC:
        spec = _VEC[name]
    if spec is None:
        return P()                               # norms, scalars: replicate
    # pad leading stacked dims (group/layer axes) with None
    lead = len(shape) - len(spec)
    if lead < 0:
        return P()
    full = (None,) * lead + tuple(spec)
    fitted = _fits(full, shape, axis_sizes)
    if name in _FALLBACK_2D and all(a is None for a in fitted[lead:]):
        fb = (None,) * lead + _FALLBACK_2D[name]
        fitted = _fits(fb, shape, axis_sizes)
    return P(*fitted)


def param_shardings(mesh, params_tree):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def f(kp, leaf):
        names = tuple(_keystr(e) for e in kp)
        return NamedSharding(mesh, param_pspec(names, leaf.shape, axis_sizes))

    return jax.tree_util.tree_map_with_path(f, params_tree)


def opt_shardings(mesh, opt_tree):
    """master/m/v mirror params; step is replicated."""
    return param_shardings(mesh, opt_tree)       # same name-based rules apply


def batch_pspec(name: str, shape: tuple[int, ...], mesh) -> P:
    dp = data_axes(mesh)
    n = dp_size(mesh)
    b_ax = dp if shape[0] % n == 0 and shape[0] >= n else None
    rest = (None,) * (len(shape) - 1)
    return P(b_ax, *rest)


def batch_shardings(mesh, batch_tree):
    def f(kp, leaf):
        name = _keystr(kp[-1]) if kp else ""
        return NamedSharding(mesh, batch_pspec(name, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(f, batch_tree)


def cache_pspec(path: tuple[str, ...], shape: tuple[int, ...], mesh,
                cfg) -> P:
    """KV / state cache shardings (decode & prefill outputs)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = data_axes(mesh)
    n_dp = dp_size(mesh)
    m = model_size(mesh)
    name = path[-1]
    if name in ("k", "v") or name.startswith(("self_", "cross_")):
        # trailing dims: (B, S, KV, hd)
        B, S, KV, hd = shape[-4], shape[-3], shape[-2], shape[-1]
        b_ax = dp if B % n_dp == 0 and B >= n_dp else None
        s_ax = dp if b_ax is None and S % n_dp == 0 else None
        kv_ax = "model" if KV % m == 0 else None
        hd_ax = None
        if kv_ax is None and s_ax is None and S % m == 0:
            # GQA with kv_heads < model axis: shard the cache SEQUENCE over
            # the model axis (flash-decoding style partial softmax). The
            # alternative — sharding head_dim — forces XLA to all-gather
            # whole caches around the score contraction: §Perf it.5
            # measured 33x collective reduction from this choice.
            s_ax = "model"
        elif kv_ax is None and hd % m == 0:
            hd_ax = "model"
        spec = (b_ax, s_ax, kv_ax, hd_ax)
        lead = len(shape) - 4
        return P(*((None,) * lead + spec))
    if name == "ssm" or "ssm" in path:
        # mamba1: (..., B, Di, N); mamba2: (..., B, nh, P, N)
        trailing = 4 if (cfg.ssm is not None and cfg.ssm.version == 2) else 3
        lead = len(shape) - trailing
        body = shape[lead:]
        b_ax = dp if body[0] % n_dp == 0 and body[0] >= n_dp else None
        c_ax = "model" if body[1] % m == 0 else None
        spec = (b_ax, c_ax) + (None,) * (trailing - 2)
        return P(*((None,) * lead + spec))
    if "conv" in path or name.startswith("conv"):
        # (B, K-1, C)
        lead = len(shape) - 3
        B, _, C = shape[lead:]
        b_ax = dp if B % n_dp == 0 and B >= n_dp else None
        c_ax = "model" if C % m == 0 else None
        return P(*((None,) * lead + (b_ax, None, c_ax)))
    return P()


def cache_shardings(mesh, cache_tree, cfg):
    def f(kp, leaf):
        names = tuple(_keystr(e) for e in kp)
        return NamedSharding(mesh, cache_pspec(names, leaf.shape, mesh, cfg))

    return jax.tree_util.tree_map_with_path(f, cache_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
