"""Config-driven experiment runner: fan named scenarios through the engine.

Every experiment in this repo — the paper figures, the beyond-paper
regimes, ad-hoc CLI runs — is one :class:`repro.core.ScenarioSpec` lowered
to a ``run_experiment`` call. This module is the single place that does the
lowering (:func:`run_spec`), sweeps an axis of specs for the figure
benchmarks (:func:`sweep`), and runs the registry end to end:

    PYTHONPATH=src python -m repro.launch.experiments --list
    PYTHONPATH=src python -m repro.launch.experiments --scenario paper_baseline bulk_diana
    PYTHONPATH=src python -m repro.launch.experiments --all

``--all`` (or an explicit ``--scenario`` list) writes machine-readable
``results/BENCH_scenarios.json``: per scenario the full spec plus one row
per seed with ``wall_s`` / ``avg_job_time_s`` / ``avg_inter_comms`` /
``completed_jobs`` / ``makespan_s``. ``--jobs N`` overrides every
scenario's job count for quick smoke passes.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Iterable, Sequence

from repro.core import (ExperimentResult, SCENARIOS, ScenarioSpec,
                        arrival_schedule, get_scenario, injections,
                        run_experiment, to_grid_config)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")
ROW_KEYS = ("wall_s", "avg_job_time_s", "avg_inter_comms", "completed_jobs",
            "makespan_s")


def run_spec(spec: ScenarioSpec, *, seed: int | None = None,
             n_jobs: int | None = None) -> ExperimentResult:
    """Lower one spec (at one seed) to ``run_experiment`` and run it."""
    seed = spec.seeds[0] if seed is None else seed
    n = spec.n_jobs if n_jobs is None else n_jobs
    cfg = to_grid_config(spec, seed)
    failures, slowdowns = injections(spec, seed=seed)
    return run_experiment(
        cfg, scheduler=spec.scheduler, strategy=spec.strategy, n_jobs=n,
        failures=failures or None, slowdowns=slowdowns or None,
        broker=spec.broker, batch_window=spec.batch_window_s,
        arrival_burst=spec.arrival_burst,
        arrival_times=arrival_schedule(spec, n, seed=seed),
        net=spec.net,
    )


def run_scenario(spec: ScenarioSpec, *, n_jobs: int | None = None,
                 seeds: Sequence[int] | None = None) -> list[dict]:
    """Run a spec once per seed; one machine-readable row per run."""
    rows = []
    for seed in (spec.seeds if seeds is None else seeds):
        t0 = time.perf_counter()
        r = run_spec(spec, seed=seed, n_jobs=n_jobs)
        rows.append({
            "scenario": spec.name, "seed": seed, "n_jobs": r.n_jobs,
            "wall_s": round(time.perf_counter() - t0, 3),
            "avg_job_time_s": r.avg_job_time,
            "avg_inter_comms": r.avg_inter_comms,
            "completed_jobs": r.completed_jobs,
            "makespan_s": r.makespan,
            "total_wan_gb": r.total_wan_gb,
        })
    return rows


def run_scenarios(names: Iterable[str], *, n_jobs: int | None = None,
                  out_path: str | None = None, quiet: bool = False) -> dict:
    """Run each named scenario and write ``BENCH_scenarios.json``."""
    payload: dict = {"n_jobs_override": n_jobs, "scenarios": {}}
    for name in names:
        spec = get_scenario(name)
        rows = run_scenario(spec, n_jobs=n_jobs)
        payload["scenarios"][name] = {"spec": spec.to_dict(), "rows": rows}
        if not quiet:
            r = rows[0]
            print(f"{name:>16} seeds={len(rows)} wall={r['wall_s']:7.2f}s "
                  f"avg_job_time={r['avg_job_time_s']:9.0f}s "
                  f"inter/job={r['avg_inter_comms']:6.2f} "
                  f"completed={r['completed_jobs']}/{r['n_jobs']} "
                  f"makespan={r['makespan_s']:9.0f}s")
    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_scenarios.json")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    if not quiet:
        print(f"wrote {os.path.relpath(out_path)}")
    return payload


# -- figure sweeps (used by benchmarks/run.py) ------------------------------
def _with_axis(spec: ScenarioSpec, axis: str, value) -> ScenarioSpec:
    if axis == "n_jobs":
        return dataclasses.replace(spec, n_jobs=int(value))
    if axis == "wan_mbps":
        return dataclasses.replace(
            spec, uplink_mbps=(float(value),) + spec.uplink_mbps[1:])
    if axis == "scheduler":
        return dataclasses.replace(spec, scheduler=str(value))
    if axis == "net":
        return dataclasses.replace(spec, net=str(value))
    raise ValueError(f"unknown sweep axis {axis!r}")


def sweep(base: ScenarioSpec, *, axis: str, values: Sequence,
          strategies: Sequence[str]) -> dict[tuple, ExperimentResult]:
    """Cross an axis (``n_jobs`` | ``wan_mbps`` | ``scheduler`` | ``net``)
    with a set of replication strategies; returns
    ``{(value, strategy): result}``.

    This is the config-driven backbone of the per-figure benchmarks: each
    cell is ``run_spec`` of the base scenario with two fields replaced.
    """
    out = {}
    for v in values:
        spec = _with_axis(base, axis, v)
        for s in strategies:
            out[(v, s)] = run_spec(dataclasses.replace(spec, strategy=s))
    return out


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="Run named scenarios from the repro.core.scenarios "
                    "registry and write results/BENCH_scenarios.json")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--scenario", nargs="+", metavar="NAME",
                   help="scenario names to run (see --list)")
    g.add_argument("--all", action="store_true",
                   help="run every registered scenario")
    g.add_argument("--list", action="store_true",
                   help="list registered scenarios and exit")
    ap.add_argument("--jobs", type=int, default=None,
                    help="override every scenario's job count")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default results/BENCH_scenarios.json)")
    args = ap.parse_args(argv)

    if args.list:
        for name, spec in sorted(SCENARIOS.items()):
            fan = "x".join(str(f) for f in spec.tier_fanouts)
            print(f"{name:>16}  [{fan} sites={spec.n_sites} "
                  f"arrival={spec.arrival} strategy={spec.strategy} "
                  f"broker={spec.broker}]  {spec.description}")
        return
    names = sorted(SCENARIOS) if args.all else args.scenario
    for name in names:
        get_scenario(name)      # fail fast on typos before running anything
    run_scenarios(names, n_jobs=args.jobs, out_path=args.out)


if __name__ == "__main__":
    main()
