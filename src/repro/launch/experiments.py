"""Config-driven experiment runner: fan named scenarios through the engine.

Every experiment in this repo — the paper figures, the beyond-paper
regimes, ad-hoc CLI runs — is one :class:`repro.core.ScenarioSpec` lowered
to a ``run_experiment`` call. This module is the single place that does the
lowering (:func:`run_spec`), sweeps an axis of specs for the figure
benchmarks (:func:`sweep`), and runs the registry end to end:

    PYTHONPATH=src python -m repro.launch.experiments --list
    PYTHONPATH=src python -m repro.launch.experiments --scenario paper_baseline bulk_diana
    PYTHONPATH=src python -m repro.launch.experiments --scenario drift_strategies   # a named sweep
    PYTHONPATH=src python -m repro.launch.experiments --all

``--all`` (or an explicit ``--scenario`` list) writes machine-readable
``results/BENCH_scenarios.json``: per scenario the full spec plus one row
per seed with ``wall_s`` / ``avg_job_time_s`` / ``avg_inter_comms`` /
``completed_jobs`` / ``makespan_s``. ``--scenario`` also accepts named
:class:`repro.core.SweepSpec` grids (``--list`` shows both registries) —
a sweep's whole (axis value x seed) grid lands under the payload's
``"sweeps"`` key, one row per run with the axis value attached.
``--jobs N`` overrides every scenario's job count for quick smoke passes.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Iterable, Sequence

from repro.core import (ExperimentResult, SCENARIOS, SWEEPS, ScenarioSpec,
                        SweepSpec, arrival_schedule, get_scenario, get_sweep,
                        injections, run_experiment, to_grid_config,
                        with_axis)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")
ROW_KEYS = ("wall_s", "avg_job_time_s", "avg_inter_comms", "completed_jobs",
            "makespan_s")


def run_spec(spec: ScenarioSpec, *, seed: int | None = None,
             n_jobs: int | None = None) -> ExperimentResult:
    """Lower one spec (at one seed) to ``run_experiment`` and run it."""
    seed = spec.seeds[0] if seed is None else seed
    n = spec.n_jobs if n_jobs is None else n_jobs
    cfg = to_grid_config(spec, seed)
    failures, slowdowns = injections(spec, seed=seed)
    return run_experiment(
        cfg, scheduler=spec.scheduler, strategy=spec.strategy, n_jobs=n,
        failures=failures or None, slowdowns=slowdowns or None,
        broker=spec.broker, batch_window=spec.batch_window_s,
        strategy_mode=spec.strategy_mode,
        arrival_burst=spec.arrival_burst,
        arrival_times=arrival_schedule(spec, n, seed=seed),
        net=spec.net, econ=spec.econ, econ_interval=spec.econ_interval_s,
        # "off" lowers to None so the REPRO_OBS env override still applies
        # to registry scenarios that don't pin a telemetry mode
        obs=None if spec.obs == "off" else spec.obs,
        obs_interval=spec.obs_interval_s,
    )


def run_scenario(spec: ScenarioSpec, *, n_jobs: int | None = None,
                 seeds: Sequence[int] | None = None,
                 obs_dir: str | None = None) -> list[dict]:
    """Run a spec once per seed; one machine-readable row per run.

    When the run carries telemetry (``spec.obs`` or the ``REPRO_OBS``
    override), each row additionally gets the measured four-phase wall
    breakdown (``"phases"``: dispatch / strategy_plan / flush / other
    seconds, partitioning ``wall_s``) and the probe counters. With
    ``obs_dir`` set, the full telemetry JSON — and in trace mode the
    Perfetto trace + JSONL event log — is written there per run.
    """
    rows = []
    for seed in (spec.seeds if seeds is None else seeds):
        t0 = time.perf_counter()
        r = run_spec(spec, seed=seed, n_jobs=n_jobs)
        row = {
            "scenario": spec.name, "seed": seed, "n_jobs": r.n_jobs,
            "wall_s": round(time.perf_counter() - t0, 3),
            "avg_job_time_s": r.avg_job_time,
            "avg_inter_comms": r.avg_inter_comms,
            "completed_jobs": r.completed_jobs,
            "makespan_s": r.makespan,
            "total_wan_gb": r.total_wan_gb,
        }
        tel = r.telemetry
        if tel is not None:
            row["phases"] = tel.phase_breakdown(row["wall_s"])
            row["counters"] = dict(sorted(tel.counters.items()))
            if obs_dir is not None:
                os.makedirs(obs_dir, exist_ok=True)
                stem = os.path.join(obs_dir, f"{spec.name}_s{seed}")
                with open(stem + ".telemetry.json", "w") as f:
                    json.dump(tel.to_dict(), f, indent=1)
                if tel.trace is not None:
                    tel.save_trace(stem + ".trace.json")
                    tel.save_events_jsonl(stem + ".events.jsonl")
        rows.append(row)
    return rows


def run_sweep_spec(sweep: SweepSpec, *, n_jobs: int | None = None) -> dict:
    """Run a named sweep: every (axis value, seed) cell of the grid.

    Returns the sweep's ``BENCH_scenarios.json`` entry: the sweep spec,
    the base scenario, and one row per run with the axis value attached —
    a grid, not a point.
    """
    rows = []
    for value, cell in sweep.expand():
        for row in run_scenario(cell, n_jobs=n_jobs):
            rows.append({sweep.axis: value, **row})
    return {"sweep": sweep.to_dict(),
            "base_spec": get_scenario(sweep.base).to_dict(), "rows": rows}


def run_scenarios(names: Iterable[str], *, n_jobs: int | None = None,
                  out_path: str | None = None, quiet: bool = False,
                  obs: str | None = None,
                  obs_dir: str | None = None) -> dict:
    """Run each named scenario *or sweep* and write
    ``BENCH_scenarios.json`` (scenarios as points under ``"scenarios"``,
    sweeps as grids under ``"sweeps"``). ``obs`` overrides every
    scenario's telemetry mode; ``obs_dir`` receives the per-run
    telemetry/trace exports (see :func:`run_scenario`)."""
    payload: dict = {"n_jobs_override": n_jobs, "scenarios": {}, "sweeps": {}}
    for name in names:
        if name in SWEEPS:
            entry = run_sweep_spec(get_sweep(name), n_jobs=n_jobs)
            payload["sweeps"][name] = entry
            if not quiet:
                sw = entry["sweep"]
                print(f"{name:>16} sweep {sw['base']} x {sw['axis']}="
                      f"{sw['values']} rows={len(entry['rows'])}")
            continue
        spec = get_scenario(name)
        if obs is not None:
            spec = dataclasses.replace(spec, obs=obs)
        rows = run_scenario(spec, n_jobs=n_jobs, obs_dir=obs_dir)
        payload["scenarios"][name] = {"spec": spec.to_dict(), "rows": rows}
        if not quiet:
            r = rows[0]
            print(f"{name:>16} seeds={len(rows)} wall={r['wall_s']:7.2f}s "
                  f"avg_job_time={r['avg_job_time_s']:9.0f}s "
                  f"inter/job={r['avg_inter_comms']:6.2f} "
                  f"completed={r['completed_jobs']}/{r['n_jobs']} "
                  f"makespan={r['makespan_s']:9.0f}s")
    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_scenarios.json")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    if not quiet:
        print(f"wrote {os.path.relpath(out_path)}")
    return payload


# -- figure sweeps (used by benchmarks/run.py) ------------------------------
def sweep(base: ScenarioSpec, *, axis: str, values: Sequence,
          strategies: Sequence[str]) -> dict[tuple, ExperimentResult]:
    """Cross an axis with a set of replication strategies; returns
    ``{(value, strategy): result}``.

    This is the config-driven backbone of the per-figure benchmarks: each
    cell is ``run_spec`` of the base scenario with two fields replaced
    (:func:`repro.core.scenarios.with_axis` defines the axis vocabulary —
    every spec field plus ``wan_mbps``). Named grids live in
    :data:`repro.core.SWEEPS` (:class:`SweepSpec`) and run via
    ``--scenario NAME`` / :func:`run_sweep_spec`.
    """
    out = {}
    for v in values:
        spec = with_axis(base, axis, v)
        for s in strategies:
            out[(v, s)] = run_spec(dataclasses.replace(spec, strategy=s))
    return out


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="Run named scenarios from the repro.core.scenarios "
                    "registry and write results/BENCH_scenarios.json")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--scenario", nargs="+", metavar="NAME",
                   help="scenario or sweep names to run (see --list)")
    g.add_argument("--all", action="store_true",
                   help="run every registered scenario (sweeps only by name)")
    g.add_argument("--list", action="store_true",
                   help="list registered scenarios + sweeps and exit")
    ap.add_argument("--jobs", type=int, default=None,
                    help="override every scenario's job count")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default results/BENCH_scenarios.json)")
    ap.add_argument("--obs", default=None, metavar="MODE",
                    help="telemetry mode override for every scenario "
                         "(off|report|series|trace; see docs/OBSERVABILITY.md)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="write per-run telemetry JSON (and, with "
                         "--obs trace, Perfetto trace + JSONL event log) "
                         "into DIR")
    args = ap.parse_args(argv)

    if args.list:
        for name, spec in sorted(SCENARIOS.items()):
            fan = "x".join(str(f) for f in spec.tier_fanouts)
            print(f"{name:>16}  [{fan} sites={spec.n_sites} "
                  f"arrival={spec.arrival} strategy={spec.strategy} "
                  f"broker={spec.broker}]  {spec.description}")
        for name, sw in sorted(SWEEPS.items()):
            print(f"{name:>16}  [sweep {sw.base} x {sw.axis}="
                  f"{list(sw.values)}]  {sw.description}")
        return
    names = sorted(SCENARIOS) if args.all else args.scenario
    for name in names:
        if name not in SWEEPS:
            get_scenario(name)  # fail fast on typos before running anything
    run_scenarios(names, n_jobs=args.jobs, out_path=args.out,
                  obs=args.obs, obs_dir=args.obs_dir)


if __name__ == "__main__":
    main()
