"""Training launcher: ``--arch <id>`` selects any assigned architecture.

Real runs use the production mesh; on this CPU container use
``--reduced`` (smoke-scale model, 1 device) — the full configs are
exercised by launch/dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
      --steps 50
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import arch_ids, get_config
from repro.core import GridTopology
from repro.data.pipeline import (DataConfig, GridDataLoader,
                                 SyntheticShardedDataset)
from repro.fault.failures import FailurePlan, TrainingSupervisor
from repro.grid.datagrid import DataGridService
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=arch_ids())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab} reduced={args.reduced}")

    topo = GridTopology(2, 4, lan_bandwidth=50e9, wan_bandwidth=3.125e9,
                        storage_capacity=256e9)
    grid = DataGridService(topo)
    ds = SyntheticShardedDataset(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        n_shards=16))
    loader = GridDataLoader(ds, grid)
    tcfg = TrainConfig(
        n_microbatches=args.microbatches,
        opt=OptimizerConfig(peak_lr=3e-4, warmup_steps=10,
                            total_steps=args.steps,
                            compress_grads=args.compress_grads))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    jstep = jax.jit(make_train_step(cfg, tcfg))

    def step_fn(state, i):
        p, o = state
        if cfg.enc_dec or cfg.vision_tokens:
            batch, _ = loader.next_batch()
            tok = jnp.asarray(batch["tokens"])
            b = {"tokens": tok[:, : args.seq // 8] if cfg.enc_dec else tok,
                 "labels": jnp.asarray(batch["labels"])[:, : args.seq // 8]
                 if cfg.enc_dec else jnp.asarray(batch["labels"])}
            if cfg.enc_dec:
                b["frames"] = jnp.ones((args.batch, args.seq, cfg.d_model),
                                       jnp.bfloat16)
            if cfg.vision_tokens:
                b["vision_embeds"] = jnp.ones(
                    (args.batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        else:
            batch, _ = loader.next_batch()
            b = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = jstep(p, o, b)
        return (p, o), {"loss": m["loss"]}

    sup = TrainingSupervisor(step_fn, args.ckpt_dir,
                             ckpt_every=args.ckpt_every)
    state, hist = sup.run((params, opt), args.steps)
    for h in hist[:: max(1, len(hist) // 8)]:
        print(f"step {h['step']:4d} loss {h['loss']:.4f}")
    print(f"done. final loss {hist[-1]['loss']:.4f}; "
          f"grid inter-pod={grid.inter_comm_count()}")


if __name__ == "__main__":
    main()
