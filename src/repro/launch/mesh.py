"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests and benches must keep seeing the single real CPU device.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """Version-compat: ``jax.sharding.AxisType`` (and the ``axis_types``
    kwarg of ``jax.make_mesh``) only exist on newer JAX. Older versions
    default every axis to Auto anyway, so omitting the kwarg is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0):
    """Small mesh for CPU tests (requires >= n_data*n_model fake devices)."""
    if pod:
        return _make_mesh((pod, n_data, n_model), ("pod", "data", "model"))
    return _make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the batch dimension shards over (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in data_axes(mesh):
        out *= sizes[a]
    return out


def model_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1)
