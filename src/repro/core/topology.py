"""Hierarchical two-level grid topology (paper §3.1).

Regions are connected by slow inter-region links (WAN in the paper; cross-pod
DCN on a TPU cluster). Sites inside a region share a fast intra-region fabric
(LAN; ICI on a pod). Every site has a Computing Element (capacity) and a
Storage Element (capacity in bytes).

Bandwidth model: each site has an outbound NIC at LAN speed; each region has
a WAN uplink. An intra-region transfer is bottlenecked by the source NIC; an
inter-region transfer traverses {source NIC, source-region WAN uplink} and is
bottlenecked by the slower (in the paper's configuration always the WAN,
10 Mbps vs 1000 Mbps). Links are fair-shared among concurrent transfers.

Units are abstract but consistent: bandwidth in bytes/sec, storage in bytes,
compute in ops/sec ("MIPS" in the paper, FLOP/s on a TPU cluster).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass
class Site:
    """A grid site: CE + SE (paper Fig. 1). Maps to a TPU host."""

    site_id: int
    region_id: int
    compute_capacity: float          # ops/sec (paper: MIPS; here FLOP/s)
    storage_capacity: float          # bytes (paper: 10 GB per SE)
    # -- dynamic state, owned by the simulator / runtime --
    used_storage: float = 0.0
    queued_work: float = 0.0         # ops queued (paper: SizeofJobs_i, in MI)
    online: bool = True

    @property
    def free_storage(self) -> float:
        return self.storage_capacity - self.used_storage

    def relative_load(self) -> float:
        """Paper eq. (2): RelativeLoad_i = SizeofJobs_i / C_i."""
        return self.queued_work / self.compute_capacity


@dataclasses.dataclass
class Region:
    region_id: int
    site_ids: list[int]


@dataclasses.dataclass
class Link:
    """A shared fair-share link. Transfers on it split bandwidth equally."""

    name: str
    bandwidth: float                 # bytes/sec aggregate
    active: int = 0                  # number of concurrent transfers

    def share(self, n: int | None = None) -> float:
        n = self.active if n is None else n
        return self.bandwidth / max(1, n)


class GridTopology:
    """Two-level hierarchy: regions of sites (see module docstring)."""

    def __init__(
        self,
        n_regions: int,
        sites_per_region: int,
        *,
        lan_bandwidth: float,
        wan_bandwidth: float,
        storage_capacity: float,
        compute_capacities: Iterable[float] | None = None,
        seed: int = 0,
    ) -> None:
        self.n_regions = n_regions
        self.sites_per_region = sites_per_region
        self.lan_bandwidth = lan_bandwidth
        self.wan_bandwidth = wan_bandwidth
        self.sites: list[Site] = []
        self.regions: list[Region] = []
        caps = list(compute_capacities) if compute_capacities is not None else None
        # Deterministic heterogeneous capacities when not given: the paper
        # assumes heterogeneous MIPS but gives no table; spread 1x..4x.
        sid = 0
        for r in range(n_regions):
            ids = []
            for _ in range(sites_per_region):
                if caps is not None:
                    cap = caps[sid % len(caps)]
                else:
                    cap = 1e9 * (1 + ((sid * 2654435761 + seed) % 4))
                self.sites.append(
                    Site(site_id=sid, region_id=r, compute_capacity=cap,
                         storage_capacity=storage_capacity)
                )
                ids.append(sid)
                sid += 1
            self.regions.append(Region(region_id=r, site_ids=ids))
        self.nic_links = [Link(f"nic{s.site_id}", lan_bandwidth) for s in self.sites]
        self.wan_links = [Link(f"wan{r}", wan_bandwidth) for r in range(n_regions)]

    # -- structure queries ------------------------------------------------
    @property
    def n_sites(self) -> int:
        return len(self.sites)

    def region_of(self, site_id: int) -> int:
        return self.sites[site_id].region_id

    def same_region(self, a: int, b: int) -> bool:
        return self.region_of(a) == self.region_of(b)

    def sites_in_region(self, region_id: int) -> list[int]:
        return list(self.regions[region_id].site_ids)

    def online_sites(self) -> list[int]:
        return [s.site_id for s in self.sites if s.online]

    # -- bandwidth model ---------------------------------------------------
    def links_for(self, src: int, dst: int) -> list[Link]:
        """Links traversed by a src->dst transfer (source-side model)."""
        if self.same_region(src, dst):
            return [self.nic_links[src]]
        return [self.nic_links[src], self.wan_links[self.region_of(src)]]

    def point_bandwidth(self, src: int, dst: int) -> float:
        """Available bandwidth if one more transfer joined src->dst.

        This is what HRS uses for "maximum bandwidth available" replica
        selection: the bottleneck link's equal share with one more flow.
        (Open-coded ``min over links_for`` — this is the replica-selection
        inner loop.)
        """
        nic = self.nic_links[src]
        bw = nic.bandwidth / max(1, nic.active + 1)
        sreg = self.sites[src].region_id
        if sreg != self.sites[dst].region_id:
            wan = self.wan_links[sreg]
            wbw = wan.bandwidth / max(1, wan.active + 1)
            if wbw < bw:
                bw = wbw
        return bw

    def is_inter_region(self, src: int, dst: int) -> bool:
        return not self.same_region(src, dst)
