"""Hierarchical n-tier grid topology (paper §3.1, generalized).

The paper studies one fixed two-level hierarchy: regions connected by slow
inter-region links (WAN; cross-pod DCN on a TPU cluster), each containing
sites that share a fast intra-region fabric (LAN; ICI on a pod). This module
generalizes that to an arbitrary tier *tree* described by ``tier_fanouts``:

    (4, 13)      -> the paper's grid: 4 regions x 13 sites
    (2, 4, 7)    -> 2 clusters, each 4 groups of 7 sites (56 sites, 4 tiers
                    counting the root)
    (2, 3, 3, 3) -> a 5-tier hierarchy, 54 sites

Leaves are sites; every internal node below the root owns an *uplink* whose
bandwidth is given per level (top-down) by ``uplink_bandwidths``. The
innermost groups (the leaf's immediate parent) play the paper's "region"
role: replica strategies treat them as the locality domain and the paper's
inter-communication metric counts transfers that leave them.

Bandwidth model (source-side): each site has an outbound NIC at LAN speed.
A transfer that stays inside its leaf group is bottlenecked by the source
NIC. A transfer that leaves the group is accounted on the source NIC plus
**every** uplink it crosses on the source side (``uplink_path``): its rate
is the min over those links of each link's fair share, so a thin mid-tier
uplink saturated by through-traffic throttles transfers even when the
topmost crossed link is fat. For two-level trees the path is just {source
NIC, source-region WAN uplink} — exactly the paper's rule. Links are
fair-shared among concurrent transfers.

``path_model`` selects the accounting: ``"full"`` (default, the per-link
path above) or ``"topmost"`` — the pre-refactor legacy model that contends
only on the topmost crossed uplink, kept so the fidelity gap is measurable
(``benchmarks/run.py net_sweep``; the ``net="topmost"`` engine flag).

Heterogeneity knobs (all optional, defaults reproduce the paper):
  * ``uplink_scale``: per-uplink bandwidth multipliers, e.g. a "fat region"
    whose WAN uplink is 10x the others (DIANA-style network awareness);
  * ``storage_scale``: per-region SE-capacity multipliers (cache-starved or
    storage-rich regions);
  * ``compute_capacities`` / ``storage_capacities``: explicit per-site
    overrides.

Units are abstract but consistent: bandwidth in bytes/sec, storage in bytes,
compute in ops/sec ("MIPS" in the paper, FLOP/s on a TPU cluster).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence


@dataclasses.dataclass
class Site:
    """A grid site: CE + SE (paper Fig. 1). Maps to a TPU host."""

    site_id: int
    region_id: int
    compute_capacity: float          # ops/sec (paper: MIPS; here FLOP/s)
    storage_capacity: float          # bytes (paper: 10 GB per SE)
    # -- dynamic state, owned by the simulator / runtime --
    used_storage: float = 0.0
    queued_work: float = 0.0         # ops queued (paper: SizeofJobs_i, in MI)
    online: bool = True

    @property
    def free_storage(self) -> float:
        return self.storage_capacity - self.used_storage

    def relative_load(self) -> float:
        """Paper eq. (2): RelativeLoad_i = SizeofJobs_i / C_i."""
        return self.queued_work / self.compute_capacity


@dataclasses.dataclass
class Region:
    region_id: int
    site_ids: list[int]


@dataclasses.dataclass
class Link:
    """A shared fair-share link. Transfers on it split bandwidth equally."""

    name: str
    bandwidth: float                 # bytes/sec aggregate
    active: int = 0                  # number of concurrent transfers

    def share(self, n: int | None = None) -> float:
        n = self.active if n is None else n
        return self.bandwidth / max(1, n)


class GridTopology:
    """n-tier hierarchy of sites (see module docstring).

    The two-positional-argument form ``GridTopology(n_regions,
    sites_per_region, ...)`` builds the paper's two-level tree and is
    unchanged from the original API. Deeper trees are requested with
    ``tier_fanouts`` (which overrides the two positional counts) plus
    ``uplink_bandwidths``, one bandwidth per internal level, top-down;
    for two-level trees ``uplink_bandwidths`` defaults to
    ``(wan_bandwidth,)``.
    """

    def __init__(
        self,
        n_regions: int,
        sites_per_region: int,
        *,
        lan_bandwidth: float,
        wan_bandwidth: float,
        storage_capacity: float,
        compute_capacities: Iterable[float] | None = None,
        seed: int = 0,
        tier_fanouts: Sequence[int] | None = None,
        uplink_bandwidths: Sequence[float] | None = None,
        uplink_scale: Sequence[tuple[int, int, float]] = (),
        storage_scale: Sequence[tuple[int, float]] = (),
        storage_capacities: Iterable[float] | None = None,
        path_model: str = "full",
    ) -> None:
        if path_model not in ("full", "topmost"):
            raise ValueError(f"path_model must be 'full' or 'topmost', "
                             f"got {path_model!r}")
        self.path_model = path_model
        fanouts = (tuple(tier_fanouts) if tier_fanouts is not None
                   else (n_regions, sites_per_region))
        if len(fanouts) < 2 or any(f < 1 for f in fanouts):
            raise ValueError(f"tier_fanouts must be >=2 positive levels, "
                             f"got {fanouts!r}")
        if uplink_bandwidths is None:
            if len(fanouts) != 2:
                raise ValueError(
                    "uplink_bandwidths (one per internal level, top-down) is "
                    f"required for {len(fanouts)}-level fanouts {fanouts!r}")
            uplinks_bw = (wan_bandwidth,)
        else:
            uplinks_bw = tuple(uplink_bandwidths)
            if len(uplinks_bw) != len(fanouts) - 1:
                raise ValueError(
                    f"need {len(fanouts) - 1} uplink bandwidths for fanouts "
                    f"{fanouts!r}, got {len(uplinks_bw)}")
        self.tier_fanouts = fanouts
        self.n_regions = 1
        for f in fanouts[:-1]:
            self.n_regions *= f
        self.sites_per_region = fanouts[-1]
        self.lan_bandwidth = lan_bandwidth
        self.wan_bandwidth = uplinks_bw[0]
        self.uplink_bandwidths = uplinks_bw

        n_sites = self.n_regions * self.sites_per_region
        storage_caps = (list(storage_capacities)
                        if storage_capacities is not None else None)
        region_storage_factor: dict[int, float] = {}
        for region, factor in storage_scale:
            if not 0 <= region < self.n_regions:
                raise ValueError(
                    f"storage_scale region {region} out of range "
                    f"(0..{self.n_regions - 1})")
            region_storage_factor[region] = factor

        self.sites: list[Site] = []
        self.regions: list[Region] = []
        caps = list(compute_capacities) if compute_capacities is not None else None
        # Deterministic heterogeneous capacities when not given: the paper
        # assumes heterogeneous MIPS but gives no table; spread 1x..4x.
        sid = 0
        for r in range(self.n_regions):
            ids = []
            for _ in range(self.sites_per_region):
                if caps is not None:
                    cap = caps[sid % len(caps)]
                else:
                    cap = 1e9 * (1 + ((sid * 2654435761 + seed) % 4))
                if storage_caps is not None:
                    store = storage_caps[sid % len(storage_caps)]
                else:
                    store = storage_capacity * region_storage_factor.get(r, 1.0)
                self.sites.append(
                    Site(site_id=sid, region_id=r, compute_capacity=cap,
                         storage_capacity=store)
                )
                ids.append(sid)
                sid += 1
            self.regions.append(Region(region_id=r, site_ids=ids))
        assert sid == n_sites

        # -- link fabric ---------------------------------------------------
        # Ancestor table: _anc[site] = global node index of the site's
        # ancestor at each internal level, top-down (level 1 .. depth-1).
        # For the two-level tree this is just ``(region_id,)``.
        depth = len(fanouts)
        self._n_uplink_levels = depth - 1
        self._anc: list[tuple[int, ...]] = []
        for s in range(n_sites):
            anc = []
            nodes_below = n_sites
            for level in range(1, depth):
                nodes_below //= fanouts[level - 1]
                anc.append(s // nodes_below)
            self._anc.append(tuple(anc))
        # Flatten uplinks by level (top-down), so for two-level trees the
        # uplink index of a region's WAN link equals its region id.
        self._uplink_offset: list[int] = []
        self.wan_links: list[Link] = []
        n_nodes = 1
        scale: dict[tuple[int, int], float] = {}
        nodes_at = [1]
        for f in fanouts[:-1]:
            nodes_at.append(nodes_at[-1] * f)
        for level, node, factor in uplink_scale:
            if not 1 <= level <= depth - 1:
                raise ValueError(
                    f"uplink_scale level {level} out of range (1-based, "
                    f"1..{depth - 1})")
            if not 0 <= node < nodes_at[level]:
                raise ValueError(
                    f"uplink_scale node {node} out of range for level "
                    f"{level} (0..{nodes_at[level] - 1})")
            scale[(level, node)] = factor
        for level in range(1, depth):
            n_nodes *= fanouts[level - 1]
            self._uplink_offset.append(len(self.wan_links))
            bw = uplinks_bw[level - 1]
            for node in range(n_nodes):
                self.wan_links.append(
                    Link(f"up{level}.{node}", bw * scale.get((level, node), 1.0)))
        self.nic_links = [Link(f"nic{s.site_id}", lan_bandwidth) for s in self.sites]
        # Per-site uplink ids, top-down: _site_uplinks[s][lvl] is the index
        # into wan_links of the uplink owned by s's ancestor at internal
        # level lvl+1. uplink_path slices this table from the divergence
        # level, so path queries stay O(depth).
        self._site_uplinks: list[tuple[int, ...]] = [
            tuple(off + a for off, a in zip(self._uplink_offset, self._anc[s]))
            for s in range(n_sites)
        ]
        # flat region-id table: region_of is the replica strategies' inner
        # loop (millions of calls per run at the 500-site scale point)
        self._region_ids: list[int] = [s.region_id for s in self.sites]

    # -- structure queries ------------------------------------------------
    @property
    def n_sites(self) -> int:
        return len(self.sites)

    @property
    def depth(self) -> int:
        """Number of tier levels, counting the leaf (site) level."""
        return len(self.tier_fanouts)

    def region_of(self, site_id: int) -> int:
        return self._region_ids[site_id]

    def same_region(self, a: int, b: int) -> bool:
        return self.region_of(a) == self.region_of(b)

    def sites_in_region(self, region_id: int) -> list[int]:
        return list(self.regions[region_id].site_ids)

    def online_sites(self) -> list[int]:
        return [s.site_id for s in self.sites if s.online]

    def ancestors(self, site_id: int) -> tuple[int, ...]:
        """Global node index of each internal-level ancestor, top-down."""
        return self._anc[site_id]

    # -- bandwidth model ---------------------------------------------------
    def uplink_index(self, src: int, dst: int) -> int:
        """Index into ``wan_links`` of the topmost uplink a src->dst transfer
        crosses on the source side, or -1 for an intra-region transfer.

        For two-level trees this is exactly ``region_of(src)`` whenever the
        regions differ (one uplink per region, level-ordered flattening).
        """
        a = self._anc[src]
        b = self._anc[dst]
        if a[-1] == b[-1]:
            return -1
        for off, x, y in zip(self._uplink_offset, a, b):
            if x != y:
                return off + x
        raise AssertionError("ancestor tables inconsistent")

    def uplink_path(self, src: int, dst: int) -> tuple[int, ...]:
        """Indices into ``wan_links`` of every uplink a src->dst transfer
        crosses on the source side, topmost first; ``()`` for intra-region.

        Under ``path_model="topmost"`` this degrades to the legacy
        single-uplink accounting (the topmost crossed link only). For
        two-level trees both models return the same one-element path.
        """
        a = self._anc[src]
        b = self._anc[dst]
        if a[-1] == b[-1]:
            return ()
        for lvl, (x, y) in enumerate(zip(a, b)):
            if x != y:
                if self.path_model == "topmost":
                    return (self._site_uplinks[src][lvl],)
                return self._site_uplinks[src][lvl:]
        raise AssertionError("ancestor tables inconsistent")

    def links_for(self, src: int, dst: int) -> list[Link]:
        """Links traversed by a src->dst transfer (source-side model):
        the source NIC plus every crossed uplink (see ``uplink_path``)."""
        return [self.nic_links[src]] + [
            self.wan_links[u] for u in self.uplink_path(src, dst)]

    def link_ids_for(self, src: int, dst: int) -> tuple[int, ...]:
        """``links_for`` as indices into the unified link space used by
        :class:`repro.core.network.NetworkEngine`: NICs occupy ids
        ``0..n_sites-1`` (id == site id) and ``wan_links[i]`` is id
        ``n_sites + i``."""
        n = len(self.sites)
        return (src,) + tuple(n + u for u in self.uplink_path(src, dst))

    def pair_link_matrix(self) -> "np.ndarray":
        """Every pair's :meth:`link_ids_for` row as one ``(n_sites,
        n_sites, depth)`` int tensor, -1 where no link is crossed
        (``[src, dst, 0]`` is always the source NIC). Built vectorized
        from the ancestor tables — at 500 sites the per-pair Python loop
        is 250k ``link_ids_for`` calls, which used to dominate broker
        construction. This is the shared path-tensor snapshot behind both
        :meth:`repro.core.network.NetworkEngine.point_bandwidth_matrix`
        and the jitted shortest-transfer broker; consumers mask on
        ``>= 0``, so hole positions within a row carry no meaning."""
        import numpy as np
        n = len(self.sites)
        levels = self._n_uplink_levels
        anc = np.asarray(self._anc, dtype=np.intp).reshape(n, levels)
        uplinks = np.asarray(self._site_uplinks,
                             dtype=np.intp).reshape(n, levels)
        out = np.full((n, n, self.depth), -1, np.intp)
        out[:, :, 0] = np.arange(n)[:, None]           # source NIC
        differs = anc[:, None, :] != anc[None, :, :]   # (S, S, levels)
        crosses = differs[:, :, -1]                    # leaf-group differs
        # first divergent level; meaningless where nothing differs, but
        # those pairs are masked by ``crosses`` below
        div = np.argmax(differs, axis=2)
        lvl = np.arange(levels)[None, None, :]
        if self.path_model == "topmost":
            use = crosses[:, :, None] & (lvl == div[:, :, None])
        else:
            use = crosses[:, :, None] & (lvl >= div[:, :, None])
        out[:, :, 1:] = np.where(use, uplinks[:, None, :] + n, -1)
        return out

    def point_bandwidth(self, src: int, dst: int) -> float:
        """Available bandwidth if one more transfer joined src->dst.

        This is what HRS uses for "maximum bandwidth available" replica
        selection: the bottleneck link's equal share with one more flow.
        (Open-coded ``min over links_for`` — this is the replica-selection
        inner loop.)
        """
        nic = self.nic_links[src]
        bw = nic.bandwidth / max(1, nic.active + 1)
        for u in self.uplink_path(src, dst):
            wan = self.wan_links[u]
            wbw = wan.bandwidth / max(1, wan.active + 1)
            if wbw < bw:
                bw = wbw
        return bw

    def is_inter_region(self, src: int, dst: int) -> bool:
        return not self.same_region(src, dst)
