"""The replication economy: file valuation + proactive replica placement.

The paper's strategies are *reactive* — a replica is created only as a
side effect of a job fetch. This module makes replication a first-class
periodic decision, in the spirit of OptorSim's economic model and the
CMS access-pattern study: a :class:`ReplicationOptimizer` wakes up as a
DES event (``ECON`` in :class:`repro.core.simulator.GridSimulator`),
scores the full ``(sites, files)`` value matrix from the observed
:class:`repro.core.access.AccessHistory`, and *auctions* the top-valued
files to sites with space — evicting only replicas whose retention value
is lower than what the incoming file brings (never a net-negative trade).

Valuation is pluggable (:data:`VALUE_MODELS`):

``economic``
    OptorSim-style pricing: ``value[s, f] = predicted future accesses x
    transfer seconds per access`` — demand times ``size / bestbw`` where
    ``bestbw`` is the best point bandwidth from any *other* fetchable
    holder (:meth:`repro.core.network.NetworkEngine.
    point_bandwidth_matrix`). A replica is worth exactly the transfer
    time it is predicted to save.

``popularity``
    Pure decayed-popularity prediction: ``value[s, f] = predicted future
    accesses`` (region-pooled), masked to pairs with a live source.

Both models pool demand across the region (a site profits from staging a
file its region-mates keep fetching — the replica serves them over the
LAN), and both are scored by the vectorized
:mod:`repro.kernels.value_score` backend selected with the ``econ=``
engine flag (``numpy`` | ``pallas`` | ``pallas-interpret``), mirroring
``net=``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .access import AccessHistory
from .catalog import ReplicaCatalog
from .network import NetworkEngine
from .replica import FetchPlan, StorageState
from .topology import GridTopology

#: Values the ``econ=`` engine flag accepts, mirroring ``net=``:
#: ``numpy`` scores with the float64 oracle, ``pallas`` routes through
#: the kernel op (compiled on TPU, the identical oracle on CPU),
#: ``pallas-interpret`` runs the kernel under the Pallas interpreter
#: (slow; bit-identical to numpy under x64).
ECON_BACKENDS = ("numpy", "pallas", "pallas-interpret")

#: kernel-op backend name per engine flag
_OP_BACKEND = {"numpy": "numpy", "pallas": "auto",
               "pallas-interpret": "interpret"}

#: Default period (seconds of sim time) between optimizer rounds when a
#: strategy enables the economy — 15 simulated minutes (~15 paper-baseline
#: job arrivals). Tuned on ``hotset_drift`` at 2k jobs: 900 s reacts to a
#: hot-set shift fast enough to matter while keeping prefetch WAN traffic
#: a small fraction of job traffic; 1800/3600 s were consistently worse
#: for the predictive strategy and no better for the economic one.
DEFAULT_INTERVAL_S = 900.0


class FileValue:
    """Base valuation model: turns an :class:`AccessHistory` into the
    demand matrix the scorer consumes, and names the scoring mode."""

    name = "base"
    mode = "cost"            # kernels.value_score mode
    #: replicate only when the predicted value clears this floor (units
    #: follow the mode: seconds saved for "cost", accesses for "plain")
    min_value = 0.0

    def __init__(self, access: AccessHistory, topology: GridTopology, *,
                 region_weight: float = 0.5) -> None:
        self.access = access
        self.topology = topology
        self.region_weight = region_weight

    def demand(self, now: float) -> np.ndarray:
        """Predicted future accesses per (site, file): the site's own
        decayed count plus ``region_weight`` times its region-mates' —
        a replica at ``s`` also serves the rest of the region over the
        LAN, so pooled demand is part of the price."""
        local = self.access.snapshot(now)
        if self.region_weight == 0.0:
            return local
        region_rows = np.empty_like(local)
        for region in self.topology.regions:
            region_rows[region.site_ids] = local[region.site_ids].sum(axis=0)
        return local + self.region_weight * (region_rows - local)


class EconomicValue(FileValue):
    """OptorSim-style economic valuation (``value = demand x transfer
    seconds``, see module docstring)."""

    name = "economic"
    mode = "cost"
    min_value = 60.0         # don't trade for < 1 predicted minute saved


class PopularityValue(FileValue):
    """Decayed-popularity prediction (``value = pooled demand``)."""

    name = "popularity"
    mode = "plain"
    min_value = 0.75         # < one predicted access isn't worth staging


#: Valuation-model registry, keyed by each model's ``name``.
VALUE_MODELS: dict[str, type[FileValue]] = {
    c.name: c for c in (EconomicValue, PopularityValue)
}


@dataclasses.dataclass
class ProposedReplication:
    """One auction outcome: stage ``lfn`` at ``dst`` from ``src``,
    evicting ``evictions`` (all strictly lower-valued than the incoming
    file). ``value``/``evicted_value`` are kept for introspection."""

    lfn: str
    src: int
    dst: int
    evictions: list[str]
    value: float
    evicted_value: float

    def to_plan(self, topology: GridTopology) -> FetchPlan:
        return FetchPlan(self.lfn, self.src, self.dst, store=True,
                         evictions=list(self.evictions),
                         inter_region=topology.is_inter_region(self.src,
                                                               self.dst))


class ReplicationOptimizer:
    """Periodic proactive-replication auction (see module docstring).

    ``step(now)`` returns the round's winning :class:`ProposedReplication`
    list; the simulator executes them as ordinary store transfers (they
    occupy links and contend with job traffic — the cost side of the
    economy is physically real). Deterministic: value ties resolve by
    (site, file) index, sources by (bandwidth, lowest id).
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState, access: AccessHistory,
                 network: NetworkEngine, *, model: str = "economic",
                 backend: str = "numpy",
                 max_transfers: int = 8, per_site: int = 1,
                 region_weight: float = 0.5) -> None:
        if backend not in ECON_BACKENDS:
            raise ValueError(f"unknown econ backend {backend!r} "
                             f"(want one of {ECON_BACKENDS})")
        if model not in VALUE_MODELS:
            raise ValueError(f"unknown value model {model!r} "
                             f"(want one of {sorted(VALUE_MODELS)})")
        self.catalog = catalog
        self.topology = topology
        self.storage = storage
        self.access = access
        self.network = network
        self.model = VALUE_MODELS[model](access, topology,
                                         region_weight=region_weight)
        self.backend = backend
        self.max_transfers = max_transfers
        self.per_site = per_site
        self.rounds = 0
        self.proposed = 0

    # file axis: always the access history's (synced to the catalog)
    @property
    def lfns(self) -> list[str]:
        return self.access.lfns

    @property
    def sizes(self) -> np.ndarray:
        return self.access.sizes

    # -- matrix assembly ---------------------------------------------------
    def _holder_masks(self) -> tuple[np.ndarray, np.ndarray]:
        """(held, fetchable): bool (sites, files). ``held`` is every
        holder; ``fetchable`` keeps online holders plus durable masters
        (the same rule job fetches use)."""
        n_sites = self.topology.n_sites
        held = np.zeros((n_sites, len(self.lfns)), bool)
        for j, lfn in enumerate(self.lfns):
            for h in sorted(self.catalog.holders(lfn)):
                held[h, j] = True
        online = np.array([s.online for s in self.topology.sites], bool)
        fetchable = held & online[:, None]
        masters = np.array([self.catalog.files[l].master_site
                            for l in self.lfns], np.intp)
        files = np.arange(len(self.lfns))
        fetchable[masters, files] |= held[masters, files]
        return held, fetchable

    def value_matrix(self, now: float) -> tuple[np.ndarray, np.ndarray]:
        """Score every (site, file) pair; returns ``(V, held)``.

        ``V[s, f]`` excludes self-supply, so for a held file it reads as
        *retention* value (what evicting it would cost) and for a missing
        file as *acquisition* value — one matrix prices both sides of
        the auction."""
        from repro.kernels.value_score import value_score
        held, fetchable = self._holder_masks()
        bw = self.network.point_bandwidth_matrix()
        demand = self.model.demand(now)
        v = value_score(demand, self.sizes, fetchable, bw,
                        mode=self.model.mode,
                        backend=_OP_BACKEND[self.backend])
        return v, held

    # -- the auction -------------------------------------------------------
    def step(self, now: float) -> list[ProposedReplication]:
        self.access.sync()             # pick up late-registered files
        v, held = self.value_matrix(now)
        online = np.array([s.online for s in self.topology.sites], bool)
        wanted = (~held) & online[:, None] & (v >= self.model.min_value)
        self.rounds += 1
        if not wanted.any():
            return []
        n_files = len(self.lfns)
        out: list[ProposedReplication] = []
        per_site_used: dict[int, int] = {}
        # descending value; ties by flat (site, file) index — deterministic
        order = np.argsort(-v, axis=None, kind="stable")
        for flat in order:
            if len(out) >= self.max_transfers:
                break
            s, f = divmod(int(flat), n_files)
            if v[s, f] < self.model.min_value:
                break                      # sorted: everything below is too
            if not wanted[s, f]:
                continue
            if per_site_used.get(s, 0) >= self.per_site:
                continue
            prop = self._try_acquire(s, f, v)
            if prop is not None:
                out.append(prop)
                per_site_used[s] = per_site_used.get(s, 0) + 1
        self.proposed += len(out)
        return out

    def _try_acquire(self, s: int, f: int,
                     v: np.ndarray) -> ProposedReplication | None:
        lfn = self.lfns[f]
        size = float(self.sizes[f])
        holders = [h for h in
                   self.catalog.fetchable_holders(lfn, self.topology)
                   if h != s]
        if not holders:
            return None
        src = max(holders,
                  key=lambda h: (self.network.point_bandwidth(h, s), -h))
        free = self.storage.free(s)
        evictions: list[str] = []
        evicted_value = 0.0
        if free < size:
            # cheapest-first among evictable residents; abort the trade if
            # the evicted side would out-value the incoming file
            resident = [l for l in self.storage.site_contents(s)
                        if self.storage.evictable(s, l)]
            if not resident:
                return None
            scores = np.array([v[s, self.access.lfn_index[l]]
                               for l in resident])
            for i in np.argsort(scores, kind="stable"):
                l = resident[int(i)]
                evictions.append(l)
                evicted_value += float(scores[int(i)])
                free += self.catalog.size(l)
                if free >= size:
                    break
            if free < size or evicted_value >= v[s, f]:
                return None                # not enough space, or a net loss
        return ProposedReplication(lfn=lfn, src=src, dst=s,
                                   evictions=evictions,
                                   value=float(v[s, f]),
                                   evicted_value=evicted_value)
