"""Replica management strategies: HRS (paper §3.3), BHR, LRU baselines.

A strategy answers one question: *given that site ``dst`` needs file ``lfn``
which it does not hold, where do we fetch it from and what happens to local
storage?* The simulator (or the real runtime's DataGridService) executes the
returned plan.

Storage bookkeeping (LRU clocks, pinning of in-use files) lives in
``StorageState`` so strategies stay pure decision functions.
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import Iterable, Optional

from .catalog import ReplicaCatalog
from .topology import GridTopology


@dataclasses.dataclass
class FetchPlan:
    lfn: str
    src: int
    dst: int
    store: bool                    # keep in dst's SE (vs temporary buffer)
    evictions: list[str]           # lfns to delete from dst's SE first
    inter_region: bool             # paper's "inter-communication" metric
    remote_access: bool = False    # BHR: stream without storing


class StorageState:
    """Per-site SE contents with LRU clocks and pins.

    Recency is kept as a per-site list sorted by ``(last_access, add_seq)``
    maintained incrementally with bisect, so ``lru_order`` is a copy instead
    of a full sort per call. ``add_seq`` (monotonic registration counter)
    reproduces exactly the seed engine's tie-break: a stable sort by access
    time over dict-insertion order.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology) -> None:
        self.catalog = catalog
        self.topology = topology
        # site -> {lfn: last_access_time}; insertion kept, times updated
        self._contents: dict[int, dict[str, float]] = {
            s.site_id: {} for s in topology.sites
        }
        self._pins: dict[int, dict[str, int]] = {s.site_id: {} for s in topology.sites}
        self._add_seq: dict[int, dict[str, int]] = {
            s.site_id: {} for s in topology.sites
        }
        self._lru: dict[int, list[tuple[float, int, str]]] = {
            s.site_id: [] for s in topology.sites
        }
        self._seq = 0

    def _lru_insert(self, site: int, lfn: str, now: float) -> None:
        self._seq += 1
        self._add_seq[site][lfn] = self._seq
        bisect.insort(self._lru[site], (now, self._seq, lfn))

    def _lru_discard(self, site: int, lfn: str) -> None:
        key = (self._contents[site][lfn], self._add_seq[site][lfn], lfn)
        lst = self._lru[site]
        i = bisect.bisect_left(lst, key)
        if i < len(lst) and lst[i] == key:
            lst.pop(i)

    # -- mutation ----------------------------------------------------------
    def add(self, site: int, lfn: str, now: float) -> None:
        size = self.catalog.size(lfn)
        st = self.topology.sites[site]
        assert st.free_storage >= size - 1e-9, (
            f"SE overflow at site {site}: need {size}, free {st.free_storage}"
        )
        if lfn in self._contents[site]:
            # Re-add of a file already on the SE (two store transfers can
            # race for the same key when a temp fetch pops the in-flight
            # entry): behave like the dict overwrite always did — refresh
            # the clock, keep the original insertion rank, re-count the
            # reservation.
            self.touch(site, lfn, now)
        else:
            self._contents[site][lfn] = now
            self._lru_insert(site, lfn, now)
        st.used_storage += size
        self.catalog.add_replica(lfn, site)

    def bootstrap(self, site: int, lfn: str, now: float = 0.0) -> None:
        """Place an initial (master) copy that is already registered in the
        catalog — fills SE bookkeeping without re-registering."""
        if lfn in self._contents[site]:
            self.touch(site, lfn, now)   # re-bootstrap: refresh, don't dup
        else:
            self._contents[site][lfn] = now
            self._lru_insert(site, lfn, now)
        self.topology.sites[site].used_storage += self.catalog.size(lfn)

    def remove(self, site: int, lfn: str) -> None:
        assert not self.is_pinned(site, lfn), f"evicting pinned {lfn}@{site}"
        self._lru_discard(site, lfn)
        del self._contents[site][lfn]
        del self._add_seq[site][lfn]
        self.topology.sites[site].used_storage -= self.catalog.size(lfn)
        self.catalog.remove_replica(lfn, site)

    def lose(self, site: int, lfn: str) -> None:
        """Failure path: the SE is gone, so the replica disappears no matter
        what pins were held."""
        self._pins[site].pop(lfn, None)
        self.remove(site, lfn)

    def touch(self, site: int, lfn: str, now: float) -> None:
        if lfn in self._contents[site]:
            if self._contents[site][lfn] != now:
                key = (self._contents[site][lfn], self._add_seq[site][lfn], lfn)
                lst = self._lru[site]
                i = bisect.bisect_left(lst, key)
                if i < len(lst) and lst[i] == key:
                    lst.pop(i)
                    bisect.insort(lst, (now, self._add_seq[site][lfn], lfn))
            self._contents[site][lfn] = now

    def pin(self, site: int, lfn: str) -> None:
        self._pins[site][lfn] = self._pins[site].get(lfn, 0) + 1

    def unpin(self, site: int, lfn: str) -> None:
        n = self._pins[site].get(lfn, 0) - 1
        if n <= 0:
            self._pins[site].pop(lfn, None)
        else:
            self._pins[site][lfn] = n

    def is_pinned(self, site: int, lfn: str) -> bool:
        return self._pins[site].get(lfn, 0) > 0

    # -- queries -----------------------------------------------------------
    def holds(self, site: int, lfn: str) -> bool:
        return lfn in self._contents[site]

    def site_contents(self, site: int) -> list[str]:
        """All lfns currently in the site's SE (snapshot copy)."""
        return list(self._contents[site])

    def lru_order(self, site: int) -> list[str]:
        """Site contents, least-recently-used first."""
        return [lfn for _, _, lfn in self._lru[site]]

    def evictable(self, site: int, lfn: str) -> bool:
        """Masters and pinned (in-use) files are never evicted."""
        return not self.catalog.is_master(lfn, site) and not self.is_pinned(site, lfn)

    def free(self, site: int) -> float:
        return self.topology.sites[site].free_storage


def _best_bandwidth_source(
    candidates: list[int], dst: int, topology: GridTopology
) -> int:
    """Max available-bandwidth source (HRS's replica-selection criterion)."""
    return max(candidates, key=lambda s: (topology.point_bandwidth(s, dst), -s))


class ReplicaStrategy:
    """Base interface. Subclasses implement ``plan_fetch``."""

    name = "base"

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState) -> None:
        self.catalog = catalog
        self.topology = topology
        self.storage = storage

    def _online_holders(self, lfn: str) -> list[int]:
        """Holders we may fetch from (see ReplicaCatalog.fetchable_holders)."""
        return self.catalog.fetchable_holders(lfn, self.topology)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        raise NotImplementedError

    # Shared helper: evict files in ``order`` (already filtered; any
    # iterable, consumed only as far as needed) until ``need`` bytes are
    # free at ``site``. Returns evicted list or [] when impossible.
    def _evict_until(self, site: int, need: float,
                     order: "Iterable[str]") -> list[str]:
        freed = self.storage.free(site)
        out: list[str] = []
        for lfn in order:
            if freed >= need:
                break
            out.append(lfn)
            freed += self.catalog.size(lfn)
        return out if freed >= need else []


class HRSStrategy(ReplicaStrategy):
    """Hierarchical Replication Strategy — the paper's contribution (§3.3).

    1. Prefer replicas in the local region; pick the max-available-bandwidth
       candidate.
    2. Intra-region fetch with insufficient space -> temporary buffer (the
       replica is NOT stored; it is dropped when the job completes).
    3. Inter-region fetch with insufficient space -> two-phase LRU eviction:
       first local replicas duplicated elsewhere in the same region, then
       local replicas duplicated in other regions. Masters/pinned are safe.
       If space still cannot be made, fall back to the temporary buffer.
    """

    name = "hrs"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        size = self.catalog.size(lfn)
        if local:
            src = _best_bandwidth_source(local, dst, self.topology)
            store = self.storage.free(dst) >= size
            return FetchPlan(lfn, src, dst, store=store, evictions=[],
                             inter_region=False)
        src = _best_bandwidth_source(holders, dst, self.topology)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=True)
        # two-phase LRU eviction, scanned lazily: phase 1 (region-duplicated
        # replicas) in LRU order, then phase 2 (the rest) in LRU order —
        # `_evict_until` stops consuming once enough space is freed
        lru = [f for f in self.storage.lru_order(dst) if self.storage.evictable(dst, f)]
        dup = self.catalog.duplicated_in_region
        evictions = self._evict_until(dst, size, itertools.chain(
            (f for f in lru if dup(f, dst, self.topology)),
            (f for f in lru if not dup(f, dst, self.topology))))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=True)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=True)


class HRSSinglePhaseStrategy(HRSStrategy):
    """Ablation: HRS with its two-phase eviction collapsed to plain LRU.

    Isolates the contribution of the paper's novel eviction order (evict
    region-duplicated replicas first, protecting sole-in-region copies
    whose re-fetch would cross the WAN) from the rest of HRS (region-
    priority source selection + temp buffer)."""

    name = "hrs_singlephase"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        size = self.catalog.size(lfn)
        if local:
            src = _best_bandwidth_source(local, dst, self.topology)
            store = self.storage.free(dst) >= size
            return FetchPlan(lfn, src, dst, store=store, evictions=[],
                             inter_region=False)
        src = _best_bandwidth_source(holders, dst, self.topology)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=True)
        evictions = self._evict_until(       # single phase, lazy LRU scan
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=True)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=True)


class BHRStrategy(ReplicaStrategy):
    """Bandwidth Hierarchy based Replication (Park et al. [5]), as described
    in the paper §2/§4.2: replicate if there is space; if the file is
    available within the same region, access it remotely (no replication);
    otherwise make room with plain LRU and replicate. Source selection
    searches *all* sites for the best (max-bandwidth) replica, with no
    intra-region priority.
    """

    name = "bhr"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        region = self.topology.region_of(dst)
        in_region = [h for h in holders if self.topology.region_of(h) == region]
        if in_region:
            rsrc = _best_bandwidth_source(in_region, dst, self.topology)
            return FetchPlan(lfn, rsrc, dst, store=False, evictions=[],
                             inter_region=False, remote_access=True)
        evictions = self._evict_until(
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)


class LRUStrategy(ReplicaStrategy):
    """Plain LRU replication (paper §4.2): always replicate, evicting the
    least-recently-used files to make room. No region awareness anywhere;
    the source is simply the max-bandwidth holder over all sites."""

    name = "lru"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        evictions = self._evict_until(
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)


class NoReplicationStrategy(ReplicaStrategy):
    """Always stream remotely, never store. Lower bound for replication."""

    name = "noreplication"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=self.topology.is_inter_region(src, dst))


#: Replication-strategy registry, keyed by each strategy's ``name``
#: attribute: ``hrs`` (the paper's contribution), ``hrs_singlephase``
#: (eviction ablation), ``bhr``, ``lru``, ``noreplication``. These names are
#: what ``GridSimulator``, ``run_experiment`` and ``ScenarioSpec.strategy``
#: accept.
STRATEGIES: dict[str, type[ReplicaStrategy]] = {
    c.name: c for c in (HRSStrategy, HRSSinglePhaseStrategy, BHRStrategy,
                        LRUStrategy, NoReplicationStrategy)
}


def make_strategy(name: str, catalog: ReplicaCatalog, topology: GridTopology,
                  storage: StorageState) -> ReplicaStrategy:
    """Instantiate a replication strategy from :data:`STRATEGIES` by name.

    Strategies are pure decision functions over the shared ``catalog`` /
    ``topology`` / ``storage`` state — the simulator executes the
    :class:`FetchPlan` they return. Raises ``KeyError`` for unknown names.
    """
    return STRATEGIES[name](catalog, topology, storage)
