"""Replica management strategies: HRS (paper §3.3), BHR, LRU baselines.

A strategy answers one question: *given that site ``dst`` needs file ``lfn``
which it does not hold, where do we fetch it from and what happens to local
storage?* The simulator (or the real runtime's DataGridService) executes the
returned plan.

Storage bookkeeping (LRU clocks, pinning of in-use files) lives in
``StorageState`` so strategies stay pure decision functions.
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import Iterable, Optional

import numpy as np

from .catalog import ReplicaCatalog
from .topology import GridTopology


@dataclasses.dataclass
class FetchPlan:
    lfn: str
    src: int
    dst: int
    store: bool                    # keep in dst's SE (vs temporary buffer)
    evictions: list[str]           # lfns to delete from dst's SE first
    inter_region: bool             # paper's "inter-communication" metric
    remote_access: bool = False    # BHR: stream without storing


class StorageState:
    """Per-site SE contents with LRU clocks and pins.

    Recency is kept as a per-site list sorted by ``(last_access, add_seq)``
    maintained incrementally with bisect, so ``lru_order`` is a copy instead
    of a full sort per call. ``add_seq`` (monotonic registration counter)
    reproduces exactly the seed engine's tie-break: a stable sort by access
    time over dict-insertion order.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology) -> None:
        self.catalog = catalog
        self.topology = topology
        # site -> {lfn: last_access_time}; insertion kept, times updated
        self._contents: dict[int, dict[str, float]] = {
            s.site_id: {} for s in topology.sites
        }
        self._pins: dict[int, dict[str, int]] = {s.site_id: {} for s in topology.sites}
        self._add_seq: dict[int, dict[str, int]] = {
            s.site_id: {} for s in topology.sites
        }
        self._lru: dict[int, list[tuple[float, int, str]]] = {
            s.site_id: [] for s in topology.sites
        }
        self._seq = 0

    def _lru_insert(self, site: int, lfn: str, now: float) -> None:
        self._seq += 1
        self._add_seq[site][lfn] = self._seq
        bisect.insort(self._lru[site], (now, self._seq, lfn))

    def _lru_discard(self, site: int, lfn: str) -> None:
        key = (self._contents[site][lfn], self._add_seq[site][lfn], lfn)
        lst = self._lru[site]
        i = bisect.bisect_left(lst, key)
        if i < len(lst) and lst[i] == key:
            lst.pop(i)

    # -- mutation ----------------------------------------------------------
    def add(self, site: int, lfn: str, now: float) -> None:
        size = self.catalog.size(lfn)
        st = self.topology.sites[site]
        assert st.free_storage >= size - 1e-9, (
            f"SE overflow at site {site}: need {size}, free {st.free_storage}"
        )
        if lfn in self._contents[site]:
            # Re-add of a file already on the SE (two store transfers can
            # race for the same key when a temp fetch pops the in-flight
            # entry): behave like the dict overwrite always did — refresh
            # the clock, keep the original insertion rank, re-count the
            # reservation.
            self.touch(site, lfn, now)
        else:
            self._contents[site][lfn] = now
            self._lru_insert(site, lfn, now)
        st.used_storage += size
        self.catalog.add_replica(lfn, site)

    def bootstrap(self, site: int, lfn: str, now: float = 0.0) -> None:
        """Place an initial (master) copy that is already registered in the
        catalog — fills SE bookkeeping without re-registering."""
        if lfn in self._contents[site]:
            self.touch(site, lfn, now)   # re-bootstrap: refresh, don't dup
        else:
            self._contents[site][lfn] = now
            self._lru_insert(site, lfn, now)
        self.topology.sites[site].used_storage += self.catalog.size(lfn)

    def remove(self, site: int, lfn: str) -> None:
        assert not self.is_pinned(site, lfn), f"evicting pinned {lfn}@{site}"
        self._lru_discard(site, lfn)
        del self._contents[site][lfn]
        del self._add_seq[site][lfn]
        self.topology.sites[site].used_storage -= self.catalog.size(lfn)
        self.catalog.remove_replica(lfn, site)

    def lose(self, site: int, lfn: str) -> None:
        """Failure path: the SE is gone, so the replica disappears no matter
        what pins were held."""
        self._pins[site].pop(lfn, None)
        self.remove(site, lfn)

    def touch(self, site: int, lfn: str, now: float) -> None:
        if lfn in self._contents[site]:
            if self._contents[site][lfn] != now:
                key = (self._contents[site][lfn], self._add_seq[site][lfn], lfn)
                lst = self._lru[site]
                i = bisect.bisect_left(lst, key)
                if i < len(lst) and lst[i] == key:
                    lst.pop(i)
                    bisect.insort(lst, (now, self._add_seq[site][lfn], lfn))
            self._contents[site][lfn] = now

    def pin(self, site: int, lfn: str) -> None:
        self._pins[site][lfn] = self._pins[site].get(lfn, 0) + 1

    def unpin(self, site: int, lfn: str) -> None:
        n = self._pins[site].get(lfn, 0) - 1
        if n <= 0:
            self._pins[site].pop(lfn, None)
        else:
            self._pins[site][lfn] = n

    def is_pinned(self, site: int, lfn: str) -> bool:
        return self._pins[site].get(lfn, 0) > 0

    # -- queries -----------------------------------------------------------
    def holds(self, site: int, lfn: str) -> bool:
        return lfn in self._contents[site]

    def site_contents(self, site: int) -> list[str]:
        """All lfns currently in the site's SE (snapshot copy)."""
        return list(self._contents[site])

    def lru_order(self, site: int) -> list[str]:
        """Site contents, least-recently-used first."""
        return [lfn for _, _, lfn in self._lru[site]]

    def evictable(self, site: int, lfn: str) -> bool:
        """Masters and pinned (in-use) files are never evicted."""
        return not self.catalog.is_master(lfn, site) and not self.is_pinned(site, lfn)

    def free(self, site: int) -> float:
        return self.topology.sites[site].free_storage


def _best_bandwidth_source(
    candidates: list[int], dst: int, topology: GridTopology
) -> int:
    """Max available-bandwidth source (HRS's replica-selection criterion)."""
    return max(candidates, key=lambda s: (topology.point_bandwidth(s, dst), -s))


class ReplicaStrategy:
    """Base interface. Subclasses implement ``plan_fetch``.

    ``access`` is the shared :class:`repro.core.access.AccessHistory` the
    simulator feeds from its fetch/hit path; it is ``None`` for the
    history-blind paper strategies and required by the access-aware ones
    (``economic`` / ``predictive``, which also set ``uses_economy`` so
    the simulator arms the periodic :class:`repro.core.economy.
    ReplicationOptimizer`).
    """

    name = "base"
    uses_economy = False         # arm the proactive ReplicationOptimizer?
    econ_model = "economic"      # VALUE_MODELS entry the optimizer scores with

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState, access=None) -> None:
        self.catalog = catalog
        self.topology = topology
        self.storage = storage
        self.access = access

    def _online_holders(self, lfn: str) -> list[int]:
        """Holders we may fetch from (see ReplicaCatalog.fetchable_holders)."""
        return self.catalog.fetchable_holders(lfn, self.topology)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        raise NotImplementedError

    # Shared helper: evict files in ``order`` (already filtered; any
    # iterable, consumed only as far as needed) until ``need`` bytes are
    # free at ``site``. Returns evicted list or [] when impossible.
    def _evict_until(self, site: int, need: float,
                     order: "Iterable[str]") -> list[str]:
        freed = self.storage.free(site)
        out: list[str] = []
        for lfn in order:
            if freed >= need:
                break
            out.append(lfn)
            freed += self.catalog.size(lfn)
        return out if freed >= need else []


class HRSStrategy(ReplicaStrategy):
    """Hierarchical Replication Strategy — the paper's contribution (§3.3).

    1. Prefer replicas in the local region; pick the max-available-bandwidth
       candidate.
    2. Intra-region fetch with insufficient space -> temporary buffer (the
       replica is NOT stored; it is dropped when the job completes).
    3. Inter-region fetch with insufficient space -> two-phase LRU eviction:
       first local replicas duplicated elsewhere in the same region, then
       local replicas duplicated in other regions. Masters/pinned are safe.
       If space still cannot be made, fall back to the temporary buffer.
    """

    name = "hrs"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        size = self.catalog.size(lfn)
        if local:
            src = _best_bandwidth_source(local, dst, self.topology)
            store = self.storage.free(dst) >= size
            return FetchPlan(lfn, src, dst, store=store, evictions=[],
                             inter_region=False)
        src = _best_bandwidth_source(holders, dst, self.topology)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=True)
        # two-phase LRU eviction, scanned lazily: phase 1 (region-duplicated
        # replicas) in LRU order, then phase 2 (the rest) in LRU order —
        # `_evict_until` stops consuming once enough space is freed
        lru = [f for f in self.storage.lru_order(dst) if self.storage.evictable(dst, f)]
        dup = self.catalog.duplicated_in_region
        evictions = self._evict_until(dst, size, itertools.chain(
            (f for f in lru if dup(f, dst, self.topology)),
            (f for f in lru if not dup(f, dst, self.topology))))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=True)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=True)


class HRSSinglePhaseStrategy(HRSStrategy):
    """Ablation: HRS with its two-phase eviction collapsed to plain LRU.

    Isolates the contribution of the paper's novel eviction order (evict
    region-duplicated replicas first, protecting sole-in-region copies
    whose re-fetch would cross the WAN) from the rest of HRS (region-
    priority source selection + temp buffer)."""

    name = "hrs_singlephase"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        size = self.catalog.size(lfn)
        if local:
            src = _best_bandwidth_source(local, dst, self.topology)
            store = self.storage.free(dst) >= size
            return FetchPlan(lfn, src, dst, store=store, evictions=[],
                             inter_region=False)
        src = _best_bandwidth_source(holders, dst, self.topology)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=True)
        evictions = self._evict_until(       # single phase, lazy LRU scan
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=True)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=True)


class BHRStrategy(ReplicaStrategy):
    """Bandwidth Hierarchy based Replication (Park et al. [5]), as described
    in the paper §2/§4.2: replicate if there is space; if the file is
    available within the same region, access it remotely (no replication);
    otherwise make room with plain LRU and replicate. Source selection
    searches *all* sites for the best (max-bandwidth) replica, with no
    intra-region priority.
    """

    name = "bhr"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        region = self.topology.region_of(dst)
        in_region = [h for h in holders if self.topology.region_of(h) == region]
        if in_region:
            rsrc = _best_bandwidth_source(in_region, dst, self.topology)
            return FetchPlan(lfn, rsrc, dst, store=False, evictions=[],
                             inter_region=False, remote_access=True)
        evictions = self._evict_until(
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)


class LRUStrategy(ReplicaStrategy):
    """Plain LRU replication (paper §4.2): always replicate, evicting the
    least-recently-used files to make room. No region awareness anywhere;
    the source is simply the max-bandwidth holder over all sites."""

    name = "lru"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        evictions = self._evict_until(
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)


class _AccessAwareStrategy(ReplicaStrategy):
    """Shared machinery for the history-driven strategies: guaranteed
    non-None ``access`` plus source selection and eviction ordering that
    consult it."""

    uses_economy = True

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState, access=None) -> None:
        if access is None:
            from .access import AccessHistory   # deferred: avoid cycle cost
            access = AccessHistory(catalog, topology)
        super().__init__(catalog, topology, storage, access)

    def _select_source(self, candidates: list[int], dst: int) -> int:
        """Max effective bandwidth, discounted by how busy a candidate has
        recently been *serving* transfers (AccessHistory's decayed serve
        counts) — equally-fast replicas rotate instead of dog-piling one
        source. Ties break toward the lowest site id."""
        def key(h: int) -> tuple[float, int]:
            bw = self.topology.point_bandwidth(h, dst)
            return (bw / (1.0 + self.access.serve_load(h)), -h)
        return max(candidates, key=key)

    def _plan_trade(self, lfn: str, src: int, dst: int, inter: bool,
                    size: float, value_in: float,
                    retention) -> FetchPlan:
        """The shared eviction trade: evict cheapest-retention-value
        first, but only while the incoming file's value stays strictly
        ahead of the total evicted; a losing (or unfillable) trade
        streams through the temporary buffer instead. ``retention`` maps
        the evictable resident list to its per-file retention values —
        the only thing the two access-aware strategies disagree on."""
        resident = [f for f in self.storage.lru_order(dst)
                    if self.storage.evictable(dst, f)]
        values = np.asarray(retention(resident), float)
        freed = self.storage.free(dst)
        evictions: list[str] = []
        value_out = 0.0
        for i in np.argsort(values, kind="stable"):
            if freed >= size:
                break
            value_out += float(values[int(i)])
            if value_out >= value_in:
                break                        # the trade went net-negative
            evictions.append(resident[int(i)])
            freed += self.catalog.size(resident[int(i)])
        if freed >= size and value_out < value_in:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)

    def _refetch_cost(self, lfn: str, site: int) -> float:
        """Seconds to re-stage ``lfn`` at ``site`` from its best *other*
        holder; infinite when no other copy exists (losing the last
        non-master copy is priced as unaffordable)."""
        holders = [h for h in
                   self.catalog.fetchable_holders(lfn, self.topology)
                   if h != site]
        if not holders:
            return float("inf")
        bw = max(self.topology.point_bandwidth(h, site) for h in holders)
        if bw <= 0.0:
            return float("inf")
        return self.catalog.size(lfn) / bw


class PredictiveStrategy(_AccessAwareStrategy):
    """Popularity-prediction replication (CMS access-pattern study line).

    Stores a fetched file only when its predicted future accesses (the
    decayed count — the access that triggered this fetch is already in it)
    beat the summed prediction of everything that must be evicted to make
    room; a losing trade streams through the temporary buffer instead,
    keeping the cache full of files the history says will be read again.
    Retention is hierarchy-aware in the HRS spirit: a sole-in-region copy
    counts double (its re-fetch would cross the WAN). Sources are picked
    region-local first, by effective bandwidth discounted for recent
    serving load. Enables the periodic optimizer under the ``popularity``
    value model, so rising files are staged ahead of demand — the
    drifting-hot-set regime (``hotset_drift``) is where this beats
    reactive HRS.
    """

    name = "predictive"
    econ_model = "popularity"
    #: retention multiplier for sole-in-region copies (WAN re-fetch risk)
    sole_copy_weight = 2.0

    def _retention_scores(self, site: int,
                          lfns: list[str]) -> np.ndarray:
        scores = self.access.scores(site, lfns)
        dup = np.array([self.catalog.duplicated_in_region(l, site,
                                                          self.topology)
                        for l in lfns], bool)
        return np.where(dup, scores, self.sole_copy_weight * scores)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        src = self._select_source(local or holders, dst)
        inter = self.topology.is_inter_region(src, dst)
        size = self.catalog.size(lfn)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        # the trade: predicted accesses in vs predicted accesses evicted
        score_in = float(self.access.scores(dst, [lfn])[0])
        return self._plan_trade(
            lfn, src, dst, inter, size, score_in,
            lambda resident: self._retention_scores(dst, resident))


class EconomicStrategy(_AccessAwareStrategy):
    """OptorSim-style economic replication.

    A replica is bought only when the trade clears: the incoming file's
    value (predicted local accesses x the transfer cost each would pay
    without it) must exceed the total retention value of everything
    evicted to make room. Eviction scans cheapest-retention-value first;
    a losing trade falls back to the temporary buffer (stream, don't
    store). Enables the periodic optimizer under the ``economic`` value
    model, which runs the same pricing proactively grid-wide.
    """

    name = "economic"
    econ_model = "economic"

    def _retention_value(self, lfn: str, site: int) -> float:
        score = float(self.access.scores(site, [lfn])[0])
        return score * self._refetch_cost(lfn, site)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = self._select_source(holders, dst)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        # value of owning the incoming file: predicted accesses x the
        # cost of fetching it (what each future access would pay)
        score_in = float(self.access.scores(dst, [lfn])[0])
        bw = self.topology.point_bandwidth(src, dst)
        value_in = score_in * (size / bw if bw > 0.0 else float("inf"))
        return self._plan_trade(
            lfn, src, dst, inter, size, value_in,
            lambda resident: [self._retention_value(f, dst)
                              for f in resident])


class NoReplicationStrategy(ReplicaStrategy):
    """Always stream remotely, never store. Lower bound for replication."""

    name = "noreplication"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=self.topology.is_inter_region(src, dst))


#: Replication-strategy registry, keyed by each strategy's ``name``
#: attribute: ``hrs`` (the paper's contribution), ``hrs_singlephase``
#: (eviction ablation), ``bhr``, ``lru``, ``noreplication``, plus the
#: access-history-driven pair ``economic`` (OptorSim-style valuation) and
#: ``predictive`` (decayed-popularity prediction), which also arm the
#: proactive replication economy. These names are what ``GridSimulator``,
#: ``run_experiment`` and ``ScenarioSpec.strategy`` accept.
STRATEGIES: dict[str, type[ReplicaStrategy]] = {
    c.name: c for c in (HRSStrategy, HRSSinglePhaseStrategy, BHRStrategy,
                        LRUStrategy, NoReplicationStrategy,
                        EconomicStrategy, PredictiveStrategy)
}


def make_strategy(name: str, catalog: ReplicaCatalog, topology: GridTopology,
                  storage: StorageState, access=None) -> ReplicaStrategy:
    """Instantiate a replication strategy from :data:`STRATEGIES` by name.

    Strategies are pure decision functions over the shared ``catalog`` /
    ``topology`` / ``storage`` state — the simulator executes the
    :class:`FetchPlan` they return. ``access`` is the shared
    :class:`repro.core.access.AccessHistory` (the access-aware strategies
    build a private empty one when omitted, e.g. in unit tests). Raises
    ``KeyError`` for unknown names.
    """
    return STRATEGIES[name](catalog, topology, storage, access)
