"""Replica management strategies: HRS (paper §3.3), BHR, LRU baselines.

A strategy answers one question: *given that site ``dst`` needs file ``lfn``
which it does not hold, where do we fetch it from and what happens to local
storage?* The simulator (or the real runtime's DataGridService) executes the
returned plan.

Storage bookkeeping (LRU clocks, pinning of in-use files) lives in
``StorageState`` so strategies stay pure decision functions.

Every strategy exists in two interchangeable forms:

* the *sequential* classes below — one ``plan_fetch`` call per missing
  file, walking holder lists and LRU orders in Python; and
* the *batched* classes (``strategy_mode="batch"``, same registry keys) —
  one ``plan_batch`` call per arrival burst that scores every (job,
  missing-file) pair at once through the
  :mod:`repro.kernels.strategy_plan` op (float64 numpy oracle on CPU, the
  compiled Pallas kernel on TPU) and resolves eviction contents with
  masked reductions over a :class:`StorageTensorView`, the dense array
  mirror of catalog + SE state maintained cell-by-cell through change
  listeners. On the CPU routes every batched plan is bit-identical to its
  sequential twin (pinned by ``tests/test_batch_strategy.py``).
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools
import weakref
from typing import Iterable, Optional

import numpy as np

from .catalog import ReplicaCatalog
from .topology import GridTopology
from ..kernels.strategy_plan import strategy_plan


@dataclasses.dataclass
class FetchPlan:
    lfn: str
    src: int
    dst: int
    store: bool                    # keep in dst's SE (vs temporary buffer)
    evictions: list[str]           # lfns to delete from dst's SE first
    inter_region: bool             # paper's "inter-communication" metric
    remote_access: bool = False    # BHR: stream without storing


class StorageState:
    """Per-site SE contents with LRU clocks and pins.

    Recency is kept as a per-site list sorted by ``(last_access, add_seq)``
    maintained incrementally with bisect, so ``lru_order`` is a copy instead
    of a full sort per call. ``add_seq`` (monotonic registration counter)
    reproduces exactly the seed engine's tie-break: a stable sort by access
    time over dict-insertion order.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology) -> None:
        self.catalog = catalog
        self.topology = topology
        # site -> {lfn: last_access_time}; insertion kept, times updated
        self._contents: dict[int, dict[str, float]] = {
            s.site_id: {} for s in topology.sites
        }
        self._pins: dict[int, dict[str, int]] = {s.site_id: {} for s in topology.sites}
        self._add_seq: dict[int, dict[str, int]] = {
            s.site_id: {} for s in topology.sites
        }
        self._lru: dict[int, list[tuple[float, int, str]]] = {
            s.site_id: [] for s in topology.sites
        }
        self._seq = 0
        self._listeners: list[weakref.ref] = []

    # -- change listeners ---------------------------------------------------
    def add_listener(self, listener: object) -> None:
        """Subscribe ``listener`` to SE mutations — the
        :meth:`repro.core.catalog.ReplicaCatalog.add_listener` pattern for
        storage state, so array mirrors (:class:`StorageTensorView`) track
        LRU clocks and pins cell-by-cell instead of rescanning per burst.
        It must provide ``on_storage_add(site, lfn, now, seq)``,
        ``on_storage_touch(site, lfn, now)``, ``on_storage_remove(site,
        lfn)`` and ``on_storage_pin(site, lfn, count)`` /
        ``on_storage_unpin(site, lfn, count)``; each fires *after* the
        mutation it reports. Held weakly; dead references are pruned on
        registration."""
        self._listeners = [r for r in self._listeners if r() is not None]
        self._listeners.append(weakref.ref(listener))

    def _notify(self, method: str, *args) -> None:
        for ref in self._listeners:
            sub = ref()
            if sub is not None:
                getattr(sub, method)(*args)

    def __deepcopy__(self, memo: dict) -> "StorageState":
        """Deep copy *without* listeners (the catalog's ``__deepcopy__``
        contract): a copied store — the tie-race sanitizer's twin engine —
        must never notify the original's mirrors."""
        import copy

        clone = self.__class__.__new__(self.__class__)
        memo[id(self)] = clone
        clone.catalog = copy.deepcopy(self.catalog, memo)
        clone.topology = copy.deepcopy(self.topology, memo)
        clone._contents = {s: dict(d) for s, d in self._contents.items()}
        clone._pins = {s: dict(d) for s, d in self._pins.items()}
        clone._add_seq = {s: dict(d) for s, d in self._add_seq.items()}
        clone._lru = {s: list(l) for s, l in self._lru.items()}
        clone._seq = self._seq
        clone._listeners = []
        return clone

    def _lru_insert(self, site: int, lfn: str, now: float) -> None:
        self._seq += 1
        self._add_seq[site][lfn] = self._seq
        bisect.insort(self._lru[site], (now, self._seq, lfn))

    def _lru_discard(self, site: int, lfn: str) -> None:
        key = (self._contents[site][lfn], self._add_seq[site][lfn], lfn)
        lst = self._lru[site]
        i = bisect.bisect_left(lst, key)
        if i < len(lst) and lst[i] == key:
            lst.pop(i)

    # -- mutation ----------------------------------------------------------
    def add(self, site: int, lfn: str, now: float) -> None:
        size = self.catalog.size(lfn)
        st = self.topology.sites[site]
        assert st.free_storage >= size - 1e-9, (
            f"SE overflow at site {site}: need {size}, free {st.free_storage}"
        )
        if lfn in self._contents[site]:
            # Re-add of a file already on the SE (two store transfers can
            # race for the same key when a temp fetch pops the in-flight
            # entry): refresh the clock, keep the original insertion rank.
            # The duplicate's reservation was already released by the
            # caller, so counting the size again would leak used_storage —
            # one byte ledger entry per resident replica (I3/I4).
            self.touch(site, lfn, now)
        else:
            self._contents[site][lfn] = now
            self._lru_insert(site, lfn, now)
            self._notify("on_storage_add", site, lfn, now, self._seq)
            st.used_storage += size
        self.catalog.add_replica(lfn, site)

    def bootstrap(self, site: int, lfn: str, now: float = 0.0) -> None:
        """Place an initial (master) copy that is already registered in the
        catalog — fills SE bookkeeping without re-registering."""
        if lfn in self._contents[site]:
            self.touch(site, lfn, now)   # re-bootstrap: refresh, don't dup
        else:
            self._contents[site][lfn] = now
            self._lru_insert(site, lfn, now)
            self._notify("on_storage_add", site, lfn, now, self._seq)
        self.topology.sites[site].used_storage += self.catalog.size(lfn)

    def remove(self, site: int, lfn: str) -> None:
        assert not self.is_pinned(site, lfn), f"evicting pinned {lfn}@{site}"
        self._lru_discard(site, lfn)
        del self._contents[site][lfn]
        del self._add_seq[site][lfn]
        self._notify("on_storage_remove", site, lfn)
        self.topology.sites[site].used_storage -= self.catalog.size(lfn)
        self.catalog.remove_replica(lfn, site)

    def lose(self, site: int, lfn: str) -> None:
        """Failure path: the SE is gone, so the replica disappears no matter
        what pins were held."""
        self._pins[site].pop(lfn, None)
        self.remove(site, lfn)

    def touch(self, site: int, lfn: str, now: float) -> None:
        if lfn in self._contents[site]:
            if self._contents[site][lfn] != now:
                key = (self._contents[site][lfn], self._add_seq[site][lfn], lfn)
                lst = self._lru[site]
                i = bisect.bisect_left(lst, key)
                if i < len(lst) and lst[i] == key:
                    lst.pop(i)
                    bisect.insort(lst, (now, self._add_seq[site][lfn], lfn))
            self._contents[site][lfn] = now
            self._notify("on_storage_touch", site, lfn, now)

    def pin(self, site: int, lfn: str) -> None:
        self._pins[site][lfn] = self._pins[site].get(lfn, 0) + 1
        self._notify("on_storage_pin", site, lfn, self._pins[site][lfn])

    def unpin(self, site: int, lfn: str) -> None:
        n = self._pins[site].get(lfn, 0) - 1
        if n <= 0:
            self._pins[site].pop(lfn, None)
        else:
            self._pins[site][lfn] = n
        self._notify("on_storage_unpin", site, lfn, max(n, 0))

    def is_pinned(self, site: int, lfn: str) -> bool:
        return self._pins[site].get(lfn, 0) > 0

    # -- queries -----------------------------------------------------------
    def holds(self, site: int, lfn: str) -> bool:
        return lfn in self._contents[site]

    def site_contents(self, site: int) -> list[str]:
        """All lfns currently in the site's SE (snapshot copy)."""
        return list(self._contents[site])

    def lru_order(self, site: int) -> list[str]:
        """Site contents, least-recently-used first."""
        return [lfn for _, _, lfn in self._lru[site]]

    def evictable(self, site: int, lfn: str) -> bool:
        """Masters and pinned (in-use) files are never evicted."""
        return not self.catalog.is_master(lfn, site) and not self.is_pinned(site, lfn)

    def free(self, site: int) -> float:
        return self.topology.sites[site].free_storage


class StorageTensorView:
    """Dense array mirror of catalog + SE state for the batched planners.

    One ``(sites, files)`` tensor bundle — catalog presence, per-region
    holder counts, LRU clocks (``atime`` + insertion ``seq``, exactly the
    :class:`StorageState` sort key) and pin counts — kept current
    *cell-by-cell* through both change-listener channels
    (:meth:`ReplicaCatalog.add_listener` and
    :meth:`StorageState.add_listener`), so per-burst reductions never
    rescan holder tables or LRU lists. File *registration* is absorbed
    lazily: :meth:`sync` rebuilds the whole bundle when the catalog's file
    count moved (the :class:`repro.core.jaxsched.JaxScheduler`
    presence-bitmap pattern), and every public reader syncs first — the
    SL012 coherence rule covers this class automatically.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState) -> None:
        self.catalog = catalog
        self.topology = topology
        self.storage = storage
        self._n_files = -1
        self.sync()
        catalog.add_listener(self)
        storage.add_listener(self)

    # -- rebuild / sync -----------------------------------------------------
    def sync(self) -> None:
        """Rebuild the file axis if files were registered since the last
        build; no-op (one length check) otherwise."""
        if len(self.catalog.files) != self._n_files:
            self._rebuild()

    def _rebuild(self) -> None:
        cat, topo, store = self.catalog, self.topology, self.storage
        lfns = sorted(cat.files)
        self.lfns: list[str] = lfns
        self.lfn_index: dict[str, int] = {l: j for j, l in enumerate(lfns)}
        n_files, n_sites = len(lfns), topo.n_sites
        self.sizes = np.fromiter((cat.size(l) for l in lfns), np.float64,
                                 n_files)
        self.masters = np.fromiter((cat.files[l].master_site for l in lfns),
                                   np.intp, n_files)
        self.region_map = np.fromiter((topo.region_of(s)
                                       for s in range(n_sites)),
                                      np.intp, n_sites)
        self.cat_present = np.zeros((n_sites, n_files), bool)
        for j, lfn in enumerate(lfns):
            self.cat_present[sorted(cat.holders(lfn)), j] = True
        self.region_counts = cat.region_counts_np(topo, lfns)
        self.st_present = np.zeros((n_sites, n_files), bool)
        self.st_atime = np.zeros((n_sites, n_files))
        self.st_seq = np.zeros((n_sites, n_files), np.int64)
        self.st_pins = np.zeros((n_sites, n_files), np.int64)
        # owner-module read of the SE bookkeeping maps (coherence rule
        # SL013 scopes them to this file, like SL011 does for the catalog)
        for s in range(n_sites):
            seqs = store._add_seq[s]
            for lfn, atime in store._contents[s].items():
                j = self.lfn_index[lfn]
                self.st_present[s, j] = True
                self.st_atime[s, j] = atime
                self.st_seq[s, j] = seqs[lfn]
            for lfn, n_pins in store._pins[s].items():
                self.st_pins[s, self.lfn_index[lfn]] = n_pins
        self._n_files = n_files

    # -- catalog listener channel -------------------------------------------
    def on_register_file(self, lfn: str) -> None:
        pass                      # file-count change; next sync() rebuilds

    def on_add_replica(self, lfn: str, site: int) -> None:
        j = self.lfn_index.get(lfn)
        if j is None:
            return                # registered after last rebuild
        # the catalog notifies idempotent mutations too — guard the count
        # increment with our own presence cell, like the catalog's
        # internal `if site not in holders`
        if not self.cat_present[site, j]:
            self.cat_present[site, j] = True
            self.region_counts[self.region_map[site], j] += 1

    def on_remove_replica(self, lfn: str, site: int) -> None:
        j = self.lfn_index.get(lfn)
        if j is None:
            return
        if self.cat_present[site, j]:
            self.cat_present[site, j] = False
            self.region_counts[self.region_map[site], j] -= 1

    # -- storage listener channel -------------------------------------------
    def on_storage_add(self, site: int, lfn: str, now: float,
                       seq: int) -> None:
        j = self.lfn_index.get(lfn)
        if j is None:
            return
        self.st_present[site, j] = True
        self.st_atime[site, j] = now
        self.st_seq[site, j] = seq

    def on_storage_touch(self, site: int, lfn: str, now: float) -> None:
        j = self.lfn_index.get(lfn)
        if j is not None:
            self.st_atime[site, j] = now

    def on_storage_remove(self, site: int, lfn: str) -> None:
        j = self.lfn_index.get(lfn)
        if j is None:
            return
        self.st_present[site, j] = False
        self.st_pins[site, j] = 0     # `lose` drops pins without unpinning

    def on_storage_pin(self, site: int, lfn: str, count: int) -> None:
        j = self.lfn_index.get(lfn)
        if j is not None:
            self.st_pins[site, j] = count

    def on_storage_unpin(self, site: int, lfn: str, count: int) -> None:
        j = self.lfn_index.get(lfn)
        if j is not None:
            self.st_pins[site, j] = count

    # -- burst reads (used by the batched planners) -------------------------
    def file_indices(self, lfns: "Iterable[str]") -> np.ndarray:
        self.sync()
        idx = self.lfn_index
        lfns = list(lfns)
        return np.fromiter((idx[l] for l in lfns), np.intp, len(lfns))

    def fetch_mask(self, js: np.ndarray, online: np.ndarray) -> np.ndarray:
        """``(sites, pairs)`` fetchable-holder mask for file columns
        ``js``: online holders, plus the durable master rows regardless of
        liveness — :meth:`ReplicaCatalog.fetchable_holders` as one gather."""
        self.sync()
        mask = self.cat_present[:, js] & online[:, None]
        m = self.masters[js]
        ar = np.arange(js.size)
        mask[m, ar] = self.cat_present[m, js]
        return mask

    def local_mask(self, dsts: np.ndarray) -> np.ndarray:
        """``(sites, pairs)``: site in the same region as ``dsts[p]``."""
        self.sync()
        return self.region_map[:, None] == self.region_map[dsts][None, :]

    def lru_evictable(self, dst: int) -> np.ndarray:
        """Evictable residents of ``dst`` (non-master, unpinned) as file
        indices in LRU order — ``(atime, seq)`` ascending, the exact
        :meth:`StorageState.lru_order` key (unique per cell, so the lfn
        tie-break is never reached)."""
        self.sync()
        row = (self.st_present[dst] & (self.masters != dst)
               & (self.st_pins[dst] == 0))
        cand = np.flatnonzero(row)
        if cand.size <= 1:
            return cand
        return cand[np.lexsort((self.st_seq[dst, cand],
                                self.st_atime[dst, cand]))]

    def region_dup(self, dst: int, js: np.ndarray) -> np.ndarray:
        """Vector :meth:`ReplicaCatalog.duplicated_in_region`: some
        *other* site in ``dst``'s region also holds file ``js[i]``."""
        self.sync()
        n = (self.region_counts[self.region_map[dst], js]
             - self.cat_present[dst, js])
        return n > 0

    def refetch_costs(self, dst: int, js: np.ndarray, bw_col: np.ndarray,
                      online: np.ndarray) -> np.ndarray:
        """Seconds to re-stage each file (columns ``js``) at ``dst`` from
        its best *other* fetchable holder — the vectorized
        ``_AccessAwareStrategy._refetch_cost`` (``inf`` when no other copy
        exists or its bandwidth is zero)."""
        self.sync()
        h = self.fetch_mask(js, online)
        h[dst, :] = False
        best = np.where(h, bw_col[:, None], -np.inf).max(axis=0,
                                                         initial=-np.inf)
        good = best > 0.0
        return np.where(good, self.sizes[js] / np.where(good, best, 1.0),
                        np.inf)


def _best_bandwidth_source(
    candidates: list[int], dst: int, topology: GridTopology
) -> int:
    """Max available-bandwidth source (HRS's replica-selection criterion)."""
    return max(candidates, key=lambda s: (topology.point_bandwidth(s, dst), -s))


class ReplicaStrategy:
    """Base interface. Subclasses implement ``plan_fetch``.

    ``access`` is the shared :class:`repro.core.access.AccessHistory` the
    simulator feeds from its fetch/hit path; it is ``None`` for the
    history-blind paper strategies and required by the access-aware ones
    (``economic`` / ``predictive``, which also set ``uses_economy`` so
    the simulator arms the periodic :class:`repro.core.economy.
    ReplicationOptimizer`).
    """

    name = "base"
    uses_economy = False         # arm the proactive ReplicationOptimizer?
    econ_model = "economic"      # VALUE_MODELS entry the optimizer scores with

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState, access=None) -> None:
        self.catalog = catalog
        self.topology = topology
        self.storage = storage
        self.access = access

    def _online_holders(self, lfn: str) -> list[int]:
        """Holders we may fetch from (see ReplicaCatalog.fetchable_holders)."""
        return self.catalog.fetchable_holders(lfn, self.topology)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        raise NotImplementedError

    # Shared helper: evict files in ``order`` (already filtered; any
    # iterable, consumed only as far as needed) until ``need`` bytes are
    # free at ``site``. Returns evicted list or [] when impossible.
    def _evict_until(self, site: int, need: float,
                     order: "Iterable[str]") -> list[str]:
        freed = self.storage.free(site)
        out: list[str] = []
        for lfn in order:
            if freed >= need:
                break
            out.append(lfn)
            freed += self.catalog.size(lfn)
        return out if freed >= need else []


class HRSStrategy(ReplicaStrategy):
    """Hierarchical Replication Strategy — the paper's contribution (§3.3).

    1. Prefer replicas in the local region; pick the max-available-bandwidth
       candidate.
    2. Intra-region fetch with insufficient space -> temporary buffer (the
       replica is NOT stored; it is dropped when the job completes).
    3. Inter-region fetch with insufficient space -> two-phase LRU eviction:
       first local replicas duplicated elsewhere in the same region, then
       local replicas duplicated in other regions. Masters/pinned are safe.
       If space still cannot be made, fall back to the temporary buffer.
    """

    name = "hrs"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        size = self.catalog.size(lfn)
        if local:
            src = _best_bandwidth_source(local, dst, self.topology)
            store = self.storage.free(dst) >= size
            return FetchPlan(lfn, src, dst, store=store, evictions=[],
                             inter_region=False)
        src = _best_bandwidth_source(holders, dst, self.topology)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=True)
        # two-phase LRU eviction, scanned lazily: phase 1 (region-duplicated
        # replicas) in LRU order, then phase 2 (the rest) in LRU order —
        # `_evict_until` stops consuming once enough space is freed
        lru = [f for f in self.storage.lru_order(dst) if self.storage.evictable(dst, f)]
        dup = self.catalog.duplicated_in_region
        evictions = self._evict_until(dst, size, itertools.chain(
            (f for f in lru if dup(f, dst, self.topology)),
            (f for f in lru if not dup(f, dst, self.topology))))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=True)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=True)


class HRSSinglePhaseStrategy(HRSStrategy):
    """Ablation: HRS with its two-phase eviction collapsed to plain LRU.

    Isolates the contribution of the paper's novel eviction order (evict
    region-duplicated replicas first, protecting sole-in-region copies
    whose re-fetch would cross the WAN) from the rest of HRS (region-
    priority source selection + temp buffer)."""

    name = "hrs_singlephase"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        size = self.catalog.size(lfn)
        if local:
            src = _best_bandwidth_source(local, dst, self.topology)
            store = self.storage.free(dst) >= size
            return FetchPlan(lfn, src, dst, store=store, evictions=[],
                             inter_region=False)
        src = _best_bandwidth_source(holders, dst, self.topology)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=True)
        evictions = self._evict_until(       # single phase, lazy LRU scan
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=True)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=True)


class BHRStrategy(ReplicaStrategy):
    """Bandwidth Hierarchy based Replication (Park et al. [5]), as described
    in the paper §2/§4.2: replicate if there is space; if the file is
    available within the same region, access it remotely (no replication);
    otherwise make room with plain LRU and replicate. Source selection
    searches *all* sites for the best (max-bandwidth) replica, with no
    intra-region priority.
    """

    name = "bhr"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        region = self.topology.region_of(dst)
        in_region = [h for h in holders if self.topology.region_of(h) == region]
        if in_region:
            rsrc = _best_bandwidth_source(in_region, dst, self.topology)
            return FetchPlan(lfn, rsrc, dst, store=False, evictions=[],
                             inter_region=False, remote_access=True)
        evictions = self._evict_until(
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)


class LRUStrategy(ReplicaStrategy):
    """Plain LRU replication (paper §4.2): always replicate, evicting the
    least-recently-used files to make room. No region awareness anywhere;
    the source is simply the max-bandwidth holder over all sites."""

    name = "lru"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        evictions = self._evict_until(
            dst, size, (f for f in self.storage.lru_order(dst)
                        if self.storage.evictable(dst, f)))
        if evictions:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)


class _AccessAwareStrategy(ReplicaStrategy):
    """Shared machinery for the history-driven strategies: guaranteed
    non-None ``access`` plus source selection and eviction ordering that
    consult it."""

    uses_economy = True

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState, access=None) -> None:
        if access is None:
            from .access import AccessHistory   # deferred: avoid cycle cost
            access = AccessHistory(catalog, topology)
        super().__init__(catalog, topology, storage, access)

    def _select_source(self, candidates: list[int], dst: int) -> int:
        """Max effective bandwidth, discounted by how busy a candidate has
        recently been *serving* transfers (AccessHistory's decayed serve
        counts) — equally-fast replicas rotate instead of dog-piling one
        source. Ties break toward the lowest site id."""
        def key(h: int) -> tuple[float, int]:
            bw = self.topology.point_bandwidth(h, dst)
            return (bw / (1.0 + self.access.serve_load(h)), -h)
        return max(candidates, key=key)

    def _plan_trade(self, lfn: str, src: int, dst: int, inter: bool,
                    size: float, value_in: float,
                    retention) -> FetchPlan:
        """The shared eviction trade: evict cheapest-retention-value
        first, but only while the incoming file's value stays strictly
        ahead of the total evicted; a losing (or unfillable) trade
        streams through the temporary buffer instead. ``retention`` maps
        the evictable resident list to its per-file retention values —
        the only thing the two access-aware strategies disagree on."""
        resident = [f for f in self.storage.lru_order(dst)
                    if self.storage.evictable(dst, f)]
        values = np.asarray(retention(resident), float)
        freed = self.storage.free(dst)
        evictions: list[str] = []
        value_out = 0.0
        for i in np.argsort(values, kind="stable"):
            if freed >= size:
                break
            value_out += float(values[int(i)])
            if value_out >= value_in:
                break                        # the trade went net-negative
            evictions.append(resident[int(i)])
            freed += self.catalog.size(resident[int(i)])
        if freed >= size and value_out < value_in:
            return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=inter)

    def _refetch_cost(self, lfn: str, site: int) -> float:
        """Seconds to re-stage ``lfn`` at ``site`` from its best *other*
        holder; infinite when no other copy exists (losing the last
        non-master copy is priced as unaffordable)."""
        holders = [h for h in
                   self.catalog.fetchable_holders(lfn, self.topology)
                   if h != site]
        if not holders:
            return float("inf")
        bw = max(self.topology.point_bandwidth(h, site) for h in holders)
        if bw <= 0.0:
            return float("inf")
        return self.catalog.size(lfn) / bw


class PredictiveStrategy(_AccessAwareStrategy):
    """Popularity-prediction replication (CMS access-pattern study line).

    Stores a fetched file only when its predicted future accesses (the
    decayed count — the access that triggered this fetch is already in it)
    beat the summed prediction of everything that must be evicted to make
    room; a losing trade streams through the temporary buffer instead,
    keeping the cache full of files the history says will be read again.
    Retention is hierarchy-aware in the HRS spirit: a sole-in-region copy
    counts double (its re-fetch would cross the WAN). Sources are picked
    region-local first, by effective bandwidth discounted for recent
    serving load. Enables the periodic optimizer under the ``popularity``
    value model, so rising files are staged ahead of demand — the
    drifting-hot-set regime (``hotset_drift``) is where this beats
    reactive HRS.
    """

    name = "predictive"
    econ_model = "popularity"
    #: retention multiplier for sole-in-region copies (WAN re-fetch risk)
    sole_copy_weight = 2.0

    def _retention_scores(self, site: int,
                          lfns: list[str]) -> np.ndarray:
        scores = self.access.scores(site, lfns)
        dup = np.array([self.catalog.duplicated_in_region(l, site,
                                                          self.topology)
                        for l in lfns], bool)
        return np.where(dup, scores, self.sole_copy_weight * scores)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        region = self.topology.region_of(dst)
        local = [h for h in holders if self.topology.region_of(h) == region]
        src = self._select_source(local or holders, dst)
        inter = self.topology.is_inter_region(src, dst)
        size = self.catalog.size(lfn)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        # the trade: predicted accesses in vs predicted accesses evicted
        score_in = float(self.access.scores(dst, [lfn])[0])
        return self._plan_trade(
            lfn, src, dst, inter, size, score_in,
            lambda resident: self._retention_scores(dst, resident))


class EconomicStrategy(_AccessAwareStrategy):
    """OptorSim-style economic replication.

    A replica is bought only when the trade clears: the incoming file's
    value (predicted local accesses x the transfer cost each would pay
    without it) must exceed the total retention value of everything
    evicted to make room. Eviction scans cheapest-retention-value first;
    a losing trade falls back to the temporary buffer (stream, don't
    store). Enables the periodic optimizer under the ``economic`` value
    model, which runs the same pricing proactively grid-wide.
    """

    name = "economic"
    econ_model = "economic"

    def _retention_value(self, lfn: str, site: int) -> float:
        score = float(self.access.scores(site, [lfn])[0])
        return score * self._refetch_cost(lfn, site)

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = self._select_source(holders, dst)
        size = self.catalog.size(lfn)
        inter = self.topology.is_inter_region(src, dst)
        if self.storage.free(dst) >= size:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        # value of owning the incoming file: predicted accesses x the
        # cost of fetching it (what each future access would pay)
        score_in = float(self.access.scores(dst, [lfn])[0])
        bw = self.topology.point_bandwidth(src, dst)
        value_in = score_in * (size / bw if bw > 0.0 else float("inf"))
        return self._plan_trade(
            lfn, src, dst, inter, size, value_in,
            lambda resident: [self._retention_value(f, dst)
                              for f in resident])


class NoReplicationStrategy(ReplicaStrategy):
    """Always stream remotely, never store. Lower bound for replication."""

    name = "noreplication"

    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        holders = self._online_holders(lfn)
        src = _best_bandwidth_source(holders, dst, self.topology)
        return FetchPlan(lfn, src, dst, store=False, evictions=[],
                         inter_region=self.topology.is_inter_region(src, dst))


# -- batched planners (strategy_mode="batch") ------------------------------

class _BatchedStrategy(ReplicaStrategy):
    """Shared machinery for the batched planners.

    ``plan_batch`` scores one arrival burst — every (job, missing-file)
    pair — in a single :func:`repro.kernels.strategy_plan.strategy_plan`
    pass over the engine-shared bandwidth tensor
    (:meth:`repro.core.network.NetworkEngine.point_bandwidth_columns`),
    the :class:`StorageTensorView` presence/region masks and the decayed
    serve loads, then assembles per-pair :class:`FetchPlan` objects with
    the strategy-specific ``_assemble``. Eviction contents (two-phase LRU
    order, retention-vs-refetch trades) are masked reductions over the
    view, touching only the pairs whose no-eviction store verdict failed.
    On the CPU routes each plan is bit-identical to the sequential twin
    strategy's ``plan_fetch`` against the same state.
    """

    #: the simulator routes arrival bursts through ``plan_batch`` (and
    #: calls ``invalidate_online`` from the failure-injection paths) when
    #: this is set
    batched = True
    #: discount source bandwidth by decayed serving load (the
    #: access-aware key); zero serve is an IEEE no-op division by 1.0,
    #: so one kernel formula covers both key types
    serve_weighted = False

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 storage: StorageState, access=None, *, network=None,
                 view: Optional[StorageTensorView] = None,
                 backend: str = "auto") -> None:
        if network is None:
            raise ValueError(
                f"strategy_mode='batch' ({self.name!r}) plans off the "
                "engine-shared NetworkEngine bandwidth state; pass "
                "network=")
        super().__init__(catalog, topology, storage, access)
        self.network = network
        self.view = view if view is not None else StorageTensorView(
            catalog, topology, storage)
        self.backend = backend
        self._online: Optional[np.ndarray] = None

    # -- engine hooks -------------------------------------------------------
    def invalidate_online(self) -> None:
        """Drop the cached online-site vector. The simulator calls this
        from its failure/recovery paths; liveness changes are rare next
        to fetches, so the vector is rebuilt lazily instead of per-site."""
        self._online = None

    def _online_mask(self) -> np.ndarray:
        if self._online is None:
            self._online = np.fromiter(
                (s.online for s in self.topology.sites), bool,
                self.topology.n_sites)
        return self._online

    # -- planning -----------------------------------------------------------
    def plan_fetch(self, lfn: str, dst: int) -> FetchPlan:
        """Singleton replan route (burst-cache misses, re-staging rounds,
        event-broker singleton bursts): the exact :func:`strategy_plan`
        oracle formulas inlined on 1-D views, skipping the pair-axis
        gathers — bit-identical to ``plan_batch([(lfn, dst)])[0]``."""
        view = self.view
        view.sync()
        j = view.lfn_index[lfn]
        online = self._online_mask()
        bw = self.network.point_bandwidth_column(dst)
        fetchm = view.cat_present[:, j] & online
        m = int(view.masters[j])
        fetchm[m] = view.cat_present[m, j]
        # serve = 0 divides by exactly 1.0 (IEEE no-op), same as the oracle
        eff = (bw / (1.0 + self.access.serve_loads())
               if self.serve_weighted else bw)
        key_g = np.where(fetchm, eff, -1.0)
        src_g = int(np.argmax(key_g))            # first max = lowest id
        localm = view.region_map == view.region_map[dst]
        fl = fetchm & localm
        has_l = bool(fl.any())
        src_l = int(np.argmax(np.where(fl, eff, -1.0))) if has_l else 0
        inter_g = not bool(localm[src_g])
        free = float(self.topology.sites[dst].free_storage)
        size = float(view.sizes[j])
        return self._assemble(lfn, dst, size, free, bw, src_g, src_l,
                              has_l, inter_g, free >= size)

    def refresh_plan(self, plan: FetchPlan) -> FetchPlan:
        """Re-verdict a burst-cached plan whose store/eviction half went
        stale while the source is still good (the simulator's
        ``_live_plan`` guard). The default replans from scratch;
        strategies whose ``_assemble`` verdict needs nothing beyond the
        plan's own (src, inter_region) override with a source-preserving
        re-verdict, skipping the bandwidth column and argmax entirely."""
        return self.plan_fetch(plan.lfn, plan.dst)

    def _reverdict(self, plan: FetchPlan) -> FetchPlan:
        """Source-preserving :meth:`refresh_plan`: recompute free space
        and rerun ``_assemble`` with the cached source standing in for
        both the global and local pick. Only valid for strategies whose
        every ``_assemble`` branch encodes ``has_l`` as
        ``not inter_region`` (or ignores it) and never reads the
        bandwidth column."""
        view = self.view
        view.sync()
        size = float(view.sizes[view.lfn_index[plan.lfn]])
        free = float(self.topology.sites[plan.dst].free_storage)
        return self._assemble(plan.lfn, plan.dst, size, free, None,
                              plan.src, plan.src, not plan.inter_region,
                              plan.inter_region, free >= size)

    def plan_batch(self, pairs: list[tuple[str, int]]) -> list[FetchPlan]:
        """Plan every ``(lfn, dst)`` pair of one burst in one pass."""
        view = self.view
        view.sync()
        n = len(pairs)
        js = view.file_indices(l for l, _ in pairs)
        dsts = np.fromiter((d for _, d in pairs), np.intp, n)
        online = self._online_mask()
        bw = self.network.point_bandwidth_columns(dsts)
        fetch = view.fetch_mask(js, online)
        local = view.local_mask(dsts)
        serve = (self.access.serve_loads() if self.serve_weighted
                 else np.zeros(self.topology.n_sites))
        free = np.fromiter(
            (self.topology.sites[d].free_storage for d in dsts),
            np.float64, n)
        size = view.sizes[js]
        src_g, src_l, has_l, inter_g, store_ok = strategy_plan(
            bw, fetch, local, serve, free, size, backend=self.backend)
        # pre-compute the LRU eviction lists for every pair whose verdict
        # needs one, rowwise across the burst instead of per pair
        evs: dict[int, list[str]] = {}
        mask = self._evict_mask(has_l, store_ok)
        if mask is not None and mask.any():
            rows = np.flatnonzero(mask)
            evs = dict(zip(
                rows.tolist(),
                self._lru_evictions_multi(dsts[rows], size[rows],
                                          free[rows],
                                          two_phase=self.two_phase)))
        return [
            self._assemble(pairs[p][0], int(dsts[p]), float(size[p]),
                           float(free[p]), bw[:, p], int(src_g[p]),
                           int(src_l[p]), bool(has_l[p]), bool(inter_g[p]),
                           bool(store_ok[p]), evictions=evs.get(p))
            for p in range(n)
        ]

    #: eviction-order flavor consumed by ``_evict_mask`` pre-computation
    #: (HRS's region-duplicated-first order when True)
    two_phase = False

    def _evict_mask(self, has_l: np.ndarray,
                    store_ok: np.ndarray) -> Optional[np.ndarray]:
        """Which pairs of a burst need an LRU eviction list pre-computed
        (``None``: the strategy plans evictions itself per pair — the
        access-aware trade rules)."""
        return None

    def _assemble(self, lfn: str, dst: int, size: float, free: float,
                  bw_col: np.ndarray, src_g: int, src_l: int, has_l: bool,
                  inter_g: bool, store_ok: bool,
                  evictions: Optional[list[str]] = None) -> FetchPlan:
        raise NotImplementedError

    # Vectorized ``_evict_until`` over a pre-filtered eviction order:
    # left-to-right cumulative frees (``np.cumsum`` accumulates in
    # sequence, matching the sequential ``freed += size`` association
    # order bit for bit), evict up to the first prefix that covers
    # ``need`` — or nothing when even the full order cannot.
    def _lru_evictions(self, dst: int, need: float, free: float, *,
                       two_phase: bool = False) -> list[str]:
        view = self.view
        order = view.lru_evictable(dst)
        if order.size == 0:
            return []
        if two_phase:
            dup = view.region_dup(dst, order)
            order = np.concatenate((order[dup], order[~dup]))
        freed = np.cumsum(np.concatenate(([free], view.sizes[order])))
        hit = np.flatnonzero(freed >= need)
        if hit.size == 0:
            return []
        return [view.lfns[int(i)] for i in order[:int(hit[0])]]

    # `_lru_evictions` for a whole burst. All of a job's files land on
    # its site, so the burst's eviction-needing pairs share a handful of
    # destinations: build each destination's LRU order and cumulative
    # free-space prefix ONCE (the exact singleton arrays — same
    # lexsort, same two-phase partition, same left-assoc cumsum with the
    # free space prepended), then cut each pair at its own first covering
    # prefix. ``freed`` is nondecreasing (sizes are nonnegative), so the
    # left bisect equals the singleton's first ``freed >= need`` index.
    def _lru_evictions_multi(self, dsts: np.ndarray, needs: np.ndarray,
                             frees: np.ndarray, *,
                             two_phase: bool = False) -> list[list[str]]:
        view = self.view
        out: list[list[str]] = [[] for _ in range(len(dsts))]
        lfns = view.lfns
        for dst in np.unique(dsts):
            rows = np.flatnonzero(dsts == dst)
            order = view.lru_evictable(int(dst))
            if order.size == 0:
                continue
            if two_phase:
                dup = view.region_dup(int(dst), order)
                order = np.concatenate((order[dup], order[~dup]))
            sizes_o = view.sizes[order]
            # one prefix per distinct free-space reading (one in practice:
            # the burst snapshots every pair's free space at the same
            # instant, but the grouping must not assume it)
            for free in np.unique(frees[rows]):
                sub = rows[frees[rows] == free]
                freed = np.cumsum(np.concatenate(([free], sizes_o)))
                cuts = np.searchsorted(freed, needs[sub], side="left")
                for p, cut in zip(sub, cuts):
                    if cut < freed.size:
                        out[p] = [lfns[int(i)] for i in order[:int(cut)]]
        return out


class BatchedHRSStrategy(_BatchedStrategy):
    """Batched :class:`HRSStrategy` (region priority, temp-buffer
    fallback, two-phase LRU eviction)."""

    name = "hrs"
    two_phase = True

    def _evict_mask(self, has_l, store_ok):
        return ~(has_l | store_ok)

    def _assemble(self, lfn, dst, size, free, bw_col, src_g, src_l, has_l,
                  inter_g, store_ok, evictions=None):
        if has_l:
            return FetchPlan(lfn, src_l, dst, store=store_ok, evictions=[],
                             inter_region=False)
        if store_ok:
            return FetchPlan(lfn, src_g, dst, store=True, evictions=[],
                             inter_region=True)
        if evictions is None:
            evictions = self._lru_evictions(dst, size, free,
                                            two_phase=self.two_phase)
        if evictions:
            return FetchPlan(lfn, src_g, dst, store=True,
                             evictions=evictions, inter_region=True)
        return FetchPlan(lfn, src_g, dst, store=False, evictions=[],
                         inter_region=True)

    # every branch above maps has_l <-> not inter_region and ignores the
    # bandwidth column, so the cheap source-preserving re-verdict applies
    refresh_plan = _BatchedStrategy._reverdict


class BatchedHRSSinglePhaseStrategy(BatchedHRSStrategy):
    """Batched :class:`HRSSinglePhaseStrategy` (eviction ablation)."""

    name = "hrs_singlephase"
    two_phase = False


class BatchedBHRStrategy(_BatchedStrategy):
    """Batched :class:`BHRStrategy` (in-region remote access, plain
    LRU eviction)."""

    name = "bhr"

    def _evict_mask(self, has_l, store_ok):
        return ~(has_l | store_ok)

    def _assemble(self, lfn, dst, size, free, bw_col, src_g, src_l, has_l,
                  inter_g, store_ok, evictions=None):
        if store_ok:
            return FetchPlan(lfn, src_g, dst, store=True, evictions=[],
                             inter_region=inter_g)
        if has_l:
            return FetchPlan(lfn, src_l, dst, store=False, evictions=[],
                             inter_region=False, remote_access=True)
        if evictions is None:
            evictions = self._lru_evictions(dst, size, free)
        if evictions:
            return FetchPlan(lfn, src_g, dst, store=True,
                             evictions=evictions, inter_region=inter_g)
        return FetchPlan(lfn, src_g, dst, store=False, evictions=[],
                         inter_region=inter_g)


class BatchedLRUStrategy(_BatchedStrategy):
    """Batched :class:`LRUStrategy` (always replicate, plain LRU)."""

    name = "lru"

    def _evict_mask(self, has_l, store_ok):
        return ~store_ok

    def _assemble(self, lfn, dst, size, free, bw_col, src_g, src_l, has_l,
                  inter_g, store_ok, evictions=None):
        if store_ok:
            return FetchPlan(lfn, src_g, dst, store=True, evictions=[],
                             inter_region=inter_g)
        if evictions is None:
            evictions = self._lru_evictions(dst, size, free)
        if evictions:
            return FetchPlan(lfn, src_g, dst, store=True,
                             evictions=evictions, inter_region=inter_g)
        return FetchPlan(lfn, src_g, dst, store=False, evictions=[],
                         inter_region=inter_g)

    # src_g-only planning, has_l unused: the cheap re-verdict applies
    refresh_plan = _BatchedStrategy._reverdict


class BatchedNoReplicationStrategy(_BatchedStrategy):
    """Batched :class:`NoReplicationStrategy` (stream, never store)."""

    name = "noreplication"

    def _assemble(self, lfn, dst, size, free, bw_col, src_g, src_l, has_l,
                  inter_g, store_ok, evictions=None):
        return FetchPlan(lfn, src_g, dst, store=False, evictions=[],
                         inter_region=inter_g)

    def refresh_plan(self, plan):
        return plan          # never stores: nothing to re-verdict


class _BatchedAccessAwareStrategy(_BatchedStrategy):
    """Batched counterpart of :class:`_AccessAwareStrategy`: guaranteed
    non-None ``access``, serve-load-discounted source keys, and the
    vectorized retention-vs-refetch eviction trade."""

    uses_economy = True
    serve_weighted = True

    def __init__(self, catalog, topology, storage, access=None,
                 **kwargs) -> None:
        if access is None:
            from .access import AccessHistory   # deferred: avoid cycle cost
            access = AccessHistory(catalog, topology)
        super().__init__(catalog, topology, storage, access, **kwargs)

    def _trade_evictions(self, dst: int, size: float, free: float,
                         value_in: float, resident: np.ndarray,
                         res_lfns: list[str],
                         values: np.ndarray) -> Optional[list[str]]:
        """Vectorized ``_AccessAwareStrategy._plan_trade`` core: evict
        cheapest-retention-value first up to the first prefix that covers
        ``size``, store only while the incoming value stays strictly
        ahead of the total evicted. Returns the eviction list for a
        winning trade, ``None`` for a losing or unfillable one."""
        view = self.view
        order = np.argsort(values, kind="stable")
        freed = np.cumsum(np.concatenate(
            ([free], view.sizes[resident[order]])))
        space = np.flatnonzero(freed >= size)
        if space.size == 0:
            return None
        k = int(space[0])          # >= 1: free < size on this path
        # the sequential loop's `value_out < value_in` gate. Retention
        # values are nonnegative, so the running sum is nondecreasing and
        # this one compare also covers its early value-break; a NaN sum
        # (inf refetch cost x zero score) fails the compare — a failed
        # trade, exactly like the sequential accumulator
        cum_v = np.cumsum(values[order])
        if not cum_v[k - 1] < value_in:
            return None
        return [res_lfns[int(i)] for i in order[:k]]


class BatchedPredictiveStrategy(_BatchedAccessAwareStrategy):
    """Batched :class:`PredictiveStrategy` (popularity trade, sole-copy
    retention weighting, region-local source priority)."""

    name = "predictive"
    econ_model = "popularity"
    sole_copy_weight = PredictiveStrategy.sole_copy_weight

    def _assemble(self, lfn, dst, size, free, bw_col, src_g, src_l, has_l,
                  inter_g, store_ok, evictions=None):
        src = src_l if has_l else src_g
        inter = False if has_l else inter_g
        if store_ok:
            return FetchPlan(lfn, src, dst, store=True, evictions=[],
                             inter_region=inter)
        view = self.view
        resident = view.lru_evictable(dst)
        res_lfns = [view.lfns[int(i)] for i in resident]
        scores = self.access.scores(dst, res_lfns)
        dup = view.region_dup(dst, resident)
        values = np.where(dup, scores, self.sole_copy_weight * scores)
        score_in = float(self.access.scores(dst, [lfn])[0])
        evictions = self._trade_evictions(dst, size, free, score_in,
                                          resident, res_lfns, values)
        if evictions is None:
            return FetchPlan(lfn, src, dst, store=False, evictions=[],
                             inter_region=inter)
        return FetchPlan(lfn, src, dst, store=True, evictions=evictions,
                         inter_region=inter)

    # local source => inter_region False in every branch, bandwidth
    # column unused: the cheap source-preserving re-verdict applies
    refresh_plan = _BatchedStrategy._reverdict


class BatchedEconomicStrategy(_BatchedAccessAwareStrategy):
    """Batched :class:`EconomicStrategy` (OptorSim valuation: predicted
    accesses x transfer cost, against refetch-priced retention)."""

    name = "economic"
    econ_model = "economic"

    def _assemble(self, lfn, dst, size, free, bw_col, src_g, src_l, has_l,
                  inter_g, store_ok, evictions=None):
        if store_ok:
            return FetchPlan(lfn, src_g, dst, store=True, evictions=[],
                             inter_region=inter_g)
        view = self.view
        resident = view.lru_evictable(dst)
        res_lfns = [view.lfns[int(i)] for i in resident]
        scores = self.access.scores(dst, res_lfns)
        refetch = view.refetch_costs(dst, resident, bw_col,
                                     self._online_mask())
        values = scores * refetch
        score_in = float(self.access.scores(dst, [lfn])[0])
        bw_sd = float(bw_col[src_g])
        value_in = score_in * (size / bw_sd if bw_sd > 0.0
                               else float("inf"))
        evictions = self._trade_evictions(dst, size, free, value_in,
                                          resident, res_lfns, values)
        if evictions is None:
            return FetchPlan(lfn, src_g, dst, store=False, evictions=[],
                             inter_region=inter_g)
        return FetchPlan(lfn, src_g, dst, store=True, evictions=evictions,
                         inter_region=inter_g)


#: Replication-strategy registry, keyed by each strategy's ``name``
#: attribute: ``hrs`` (the paper's contribution), ``hrs_singlephase``
#: (eviction ablation), ``bhr``, ``lru``, ``noreplication``, plus the
#: access-history-driven pair ``economic`` (OptorSim-style valuation) and
#: ``predictive`` (decayed-popularity prediction), which also arm the
#: proactive replication economy. These names are what ``GridSimulator``,
#: ``run_experiment`` and ``ScenarioSpec.strategy`` accept.
STRATEGIES: dict[str, type[ReplicaStrategy]] = {
    c.name: c for c in (HRSStrategy, HRSSinglePhaseStrategy, BHRStrategy,
                        LRUStrategy, NoReplicationStrategy,
                        EconomicStrategy, PredictiveStrategy)
}

#: Planning engines accepted by :func:`make_strategy` / ``GridSimulator``'s
#: ``strategy_mode`` flag.
STRATEGY_MODES = ("sequential", "batch")

#: ``strategy_mode="batch"`` counterparts — same keys, every strategy has
#: a batched twin that plans whole arrival bursts in one
#: :mod:`repro.kernels.strategy_plan` pass.
BATCH_STRATEGIES: dict[str, type[_BatchedStrategy]] = {
    c.name: c for c in (BatchedHRSStrategy, BatchedHRSSinglePhaseStrategy,
                        BatchedBHRStrategy, BatchedLRUStrategy,
                        BatchedNoReplicationStrategy,
                        BatchedEconomicStrategy, BatchedPredictiveStrategy)
}


def make_strategy(name: str, catalog: ReplicaCatalog, topology: GridTopology,
                  storage: StorageState, access=None, *,
                  mode: str = "sequential", network=None,
                  backend: str = "auto") -> ReplicaStrategy:
    """Instantiate a replication strategy from :data:`STRATEGIES` (or,
    with ``mode="batch"``, :data:`BATCH_STRATEGIES`) by name.

    Strategies are pure decision functions over the shared ``catalog`` /
    ``topology`` / ``storage`` state — the simulator executes the
    :class:`FetchPlan` they return. ``access`` is the shared
    :class:`repro.core.access.AccessHistory` (the access-aware strategies
    build a private empty one when omitted, e.g. in unit tests). The
    batched planners additionally need the engine's
    :class:`repro.core.network.NetworkEngine` as ``network``; ``backend``
    routes their :mod:`repro.kernels.strategy_plan` pass
    (``"auto"``: the float64 numpy oracle on CPU, the compiled Pallas
    kernel on TPU). Raises ``KeyError`` for unknown names, ``ValueError``
    for unknown modes.
    """
    if mode == "sequential":
        return STRATEGIES[name](catalog, topology, storage, access)
    if mode != "batch":
        raise ValueError(f"unknown strategy_mode {mode!r} "
                         "(want 'sequential' | 'batch')")
    return BATCH_STRATEGIES[name](catalog, topology, storage, access,
                                  network=network, backend=backend)
