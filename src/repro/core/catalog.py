"""Centralized Replica Catalogue (paper §3.1, Fig. 2).

Indexes which sites hold which files; handles queries from the scheduler and
the per-site replica managers. Master copies are pinned (the paper assumes
"master site always has a safe copy before deleting").

Change notification: array-backed mirrors of the holder table (the jax
brokers' presence bitmap — :class:`repro.core.jaxsched.JaxScheduler`) keep
themselves current *incrementally* instead of rebuilding a ``(sites,
files)`` scan per dispatch batch. They register through
:meth:`ReplicaCatalog.add_listener`; every holder-table mutation calls the
matching ``on_register_file`` / ``on_add_replica`` / ``on_remove_replica``
callback after the catalog state has changed. With no listeners the hooks
cost one truthiness check per mutation.
"""

from __future__ import annotations

import dataclasses
import weakref

import numpy as np


@dataclasses.dataclass(frozen=True)
class FileInfo:
    lfn: str                 # logical file name
    size: float              # bytes
    master_site: int         # site holding the pinned master copy


class ReplicaCatalog:
    def __init__(self) -> None:
        self.files: dict[str, FileInfo] = {}
        self._holders: dict[str, set[int]] = {}
        self._listeners: list[weakref.ref] = []
        # lazily-bound region index: site -> region map from the first
        # topology that asks a region query, plus per-file region holder
        # counts maintained on every mutation (duplicated_in_region is on
        # the HRS eviction hot path — millions of calls per run)
        self._region_map: list[int] | None = None
        self._region_topo: weakref.ref | None = None
        self._region_counts: dict[str, dict[int, int]] = {}

    def __deepcopy__(self, memo: dict) -> "ReplicaCatalog":
        """Deep copy *without* listeners. Listeners are per-instance
        mirrors of per-instance engine state (presence bitmaps); a copied
        catalog (the tie-race sanitizer's twin engine) must never notify
        the original's mirrors. weakref.ref is also deep-copied atomically
        by the stdlib, so keeping the list would alias the originals."""
        clone = self.__class__.__new__(self.__class__)
        memo[id(self)] = clone
        clone.files = dict(self.files)          # FileInfo is frozen
        clone._holders = {lfn: set(h) for lfn, h in self._holders.items()}
        clone._listeners = []
        # region index rebinds lazily against the twin's own topology
        clone._region_map = None
        clone._region_topo = None
        clone._region_counts = {}
        return clone

    # -- change listeners ---------------------------------------------------
    def add_listener(self, listener: object) -> None:
        """Subscribe ``listener`` to holder-table changes. It must provide
        ``on_register_file(lfn)``, ``on_add_replica(lfn, site_id)`` and
        ``on_remove_replica(lfn, site_id)``; each fires *after* the
        mutation it reports (idempotent mutations still notify). Held by
        weak reference: a mirror that is no longer referenced anywhere
        else is collected instead of being notified forever."""
        self._listeners = [r for r in self._listeners if r() is not None]
        self._listeners.append(weakref.ref(listener))

    def _notify(self, method: str, *args) -> None:
        for ref in self._listeners:
            sub = ref()
            if sub is not None:
                getattr(sub, method)(*args)

    # -- registration (paper: "replica manager sends file register request
    #    to RC and RC adds this site to the list of sites") ----------------
    def register_file(self, lfn: str, size: float, master_site: int) -> None:
        if lfn in self.files:
            raise ValueError(f"duplicate file registration: {lfn}")
        self.files[lfn] = FileInfo(lfn, size, master_site)
        self._holders[lfn] = {master_site}
        if self._region_map is not None:
            self._region_counts[lfn] = {self._region_map[master_site]: 1}
        self._notify("on_register_file", lfn)

    def add_replica(self, lfn: str, site_id: int) -> None:
        h = self._holders[lfn]
        if site_id not in h:
            h.add(site_id)
            if self._region_map is not None:
                rc = self._region_counts[lfn]
                r = self._region_map[site_id]
                rc[r] = rc.get(r, 0) + 1
        self._notify("on_add_replica", lfn, site_id)

    def remove_replica(self, lfn: str, site_id: int) -> None:
        info = self.files[lfn]
        if site_id == info.master_site:
            raise ValueError(f"cannot delete master copy of {lfn}")
        h = self._holders[lfn]
        if site_id in h:
            h.discard(site_id)
            if self._region_map is not None:
                rc = self._region_counts[lfn]
                rc[self._region_map[site_id]] -= 1
        self._notify("on_remove_replica", lfn, site_id)

    # -- queries -----------------------------------------------------------
    def holders(self, lfn: str) -> set[int]:
        return set(self._holders[lfn])

    def has_replica(self, lfn: str, site_id: int) -> bool:
        return site_id in self._holders[lfn]

    def size(self, lfn: str) -> float:
        return self.files[lfn].size

    def n_copies(self, lfn: str) -> int:
        return len(self._holders[lfn])

    def is_master(self, lfn: str, site_id: int) -> bool:
        return self.files[lfn].master_site == site_id

    def files_at(self, site_id: int) -> list[str]:
        return [lfn for lfn, h in self._holders.items() if site_id in h]

    def bytes_at_site(self, required: list[str], site_id: int) -> float:
        """Paper eq. (1): S_s = sum of sizes of required files present at s."""
        return sum(
            self.files[lfn].size for lfn in required if site_id in self._holders[lfn]
        )

    def fetchable_holders(self, lfn: str, topology) -> list[int]:
        """Holders a fetch may source from. Master copies are durable (the
        paper assumes the master site 'always has a safe copy'), so a master
        remains fetchable even while its site is marked failed."""
        master = self.files[lfn].master_site
        return sorted(
            h for h in self._holders[lfn]
            if topology.sites[h].online or h == master
        )

    def _bind_region_index(self, topology) -> list[int]:
        """(Re)build the site->region map and the per-file region holder
        counts against ``topology``. Bound to one topology at a time —
        rebinding (a fresh topology instance, e.g. a sanitizer twin)
        rebuilds both from the current holder table."""
        rm = [topology.region_of(s) for s in range(len(topology.sites))]
        self._region_map = rm
        self._region_topo = weakref.ref(topology)
        counts: dict[str, dict[int, int]] = {}
        for lfn, holders in self._holders.items():
            rc: dict[int, int] = {}
            for h in holders:
                r = rm[h]
                rc[r] = rc.get(r, 0) + 1
            counts[lfn] = rc
        self._region_counts = counts
        return rm

    def region_counts_np(self, topology, lfns: list[str]) -> np.ndarray:
        """Per-region holder counts as a dense ``(n_regions, len(lfns))``
        array — the bootstrap read of the array-backed strategy mirror
        (:class:`repro.core.replica.StorageTensorView`), served from the
        same incrementally-maintained counts :meth:`duplicated_in_region`
        answers from instead of a holder-table rescan."""
        if self._region_map is None or (
                self._region_topo is not None
                and self._region_topo() is not topology):
            self._bind_region_index(topology)
        out = np.zeros((topology.n_regions, len(lfns)), np.int64)
        for j, lfn in enumerate(lfns):
            for r, n in self._region_counts[lfn].items():
                out[r, j] = n
        return out

    def duplicated_in_region(self, lfn: str, site_id: int, topology) -> bool:
        """True if some *other* site in site_id's region also holds lfn.

        O(1): answered from the incrementally-maintained per-file region
        holder counts (region membership is static per topology, so the
        count is a pure function of the holder table)."""
        rm = self._region_map
        if rm is None or (self._region_topo is not None
                          and self._region_topo() is not topology):
            rm = self._bind_region_index(topology)
        n = self._region_counts[lfn].get(rm[site_id], 0)
        if site_id in self._holders[lfn]:
            n -= 1
        return n > 0
