"""Vectorized, jit-compiled scheduling decision (beyond-paper).

The paper's algorithm is an argmax over sites of S_s with a relative-load
tie-break. At cluster scale (thousands of hosts, thousands of artifacts) the
Python loop becomes the broker's bottleneck, so we express the decision as a
single fused XLA computation over:

  presence:  bool[n_sites, n_files]  — replica catalog as a bitmap
  sizes:     f32[n_files]            — file sizes
  required:  bool[n_files]           — the job's R_j as a mask
  load:      f32[n_sites]            — queued work per site
  capacity:  f32[n_sites]            — CE capacity per site
  online:    bool[n_sites]

Tie-break is exact (no epsilon folding): stage 1 computes S_s and its max,
stage 2 arg-minimizes relative load over the tied sites only. Both stages
fuse into one XLA computation.

This module is also the bridge used by grid/placement.py to run dispatch
on-device for batches of jobs (vmap over the job axis).

Beyond the paper's policy, :class:`JaxShortestTransferBroker` vectorizes the
``shortesttransfer`` baseline the same way: a *point-bandwidth matrix*
``B[h, s] = min over link_ids_for(h, s) of bandwidth / (active + 1)`` is
snapshotted from the NetworkEngine's per-link arrays (one gather-min over a
static ``(sites, sites, path)`` link-id tensor), and each job's estimated
(transfer + queue) cost is an einsum-shaped masked reduction over it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .catalog import ReplicaCatalog
from .topology import GridTopology


@functools.partial(jax.jit, static_argnames=())
def select_site_vec(presence, sizes, required, load, capacity, online):
    """Paper §3.2 as one fused computation. Returns the chosen site index."""
    # S_s for every site: presence masked by the job's requirement
    s = (presence & required[None, :]) @ sizes              # [n_sites]
    s = jnp.where(online, s, -1.0)
    tie = s >= jnp.max(s)                                    # max-S_s sites
    rel = load / capacity                                    # [n_sites]
    rel = jnp.where(tie, rel, jnp.inf)
    return jnp.argmin(rel)                                   # first min = min (rel, id)


select_sites_batch = jax.jit(
    jax.vmap(select_site_vec, in_axes=(None, None, 0, None, None, None))
)


class JaxScheduler:
    """Array-backed mirror of (catalog, topology) for on-device dispatch.

    Also the snapshot substrate for every jax broker: the host-side
    presence bitmap, per-site load/capacity/online vectors and
    required-file masks built here are shared with
    :class:`JaxShortestTransferBroker`.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology) -> None:
        self.catalog = catalog
        self.topology = topology
        self.lfns = sorted(catalog.files)
        self.lfn_index = {l: i for i, l in enumerate(self.lfns)}
        self.sizes = jnp.asarray([catalog.size(l) for l in self.lfns], jnp.float32)

    # -- host-side snapshot pieces (shared by all brokers) -----------------
    def presence_np(self) -> np.ndarray:
        """bool[n_sites, n_files] replica bitmap (all holders)."""
        presence = np.zeros((self.topology.n_sites, len(self.lfns)), bool)
        for j, lfn in enumerate(self.lfns):
            for h in self.catalog.holders(lfn):
                presence[h, j] = True
        return presence

    def site_state_np(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(load, capacity, online) per-site vectors."""
        load = np.array([s.queued_work for s in self.topology.sites], np.float32)
        cap = np.array([s.compute_capacity for s in self.topology.sites], np.float32)
        online = np.array([s.online for s in self.topology.sites], bool)
        return load, cap, online

    def required_np(self, required_sets: list[list[str]]) -> np.ndarray:
        """bool[n_jobs, n_files] requirement masks (R_j rows)."""
        m = np.zeros((len(required_sets), len(self.lfns)), dtype=bool)
        for i, req in enumerate(required_sets):
            for lfn in req:
                m[i, self.lfn_index[lfn]] = True
        return m

    def snapshot(self):
        load, cap, online = self.site_state_np()
        return (jnp.asarray(self.presence_np()), self.sizes,
                jnp.asarray(load), jnp.asarray(cap), jnp.asarray(online))

    def required_mask(self, required: list[str]) -> jnp.ndarray:
        return jnp.asarray(self.required_np([required])[0])

    def select(self, required: list[str]) -> int:
        presence, sizes, load, cap, online = self.snapshot()
        return int(select_site_vec(presence, sizes, self.required_mask(required),
                                   load, cap, online))

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        presence, sizes, load, cap, online = self.snapshot()
        masks = jnp.asarray(self.required_np(required_sets))
        return [int(i) for i in
                select_sites_batch(presence, sizes, masks, load, cap, online)]


@jax.jit
def leastloaded_select(load, capacity, online):
    """LeastLoaded as one fused computation: argmin of relative load over
    online sites. ``jnp.argmin`` returns the first (lowest-id) minimum,
    matching the sequential policy's ``(relative_load, site_id)`` key."""
    rel = jnp.where(online, load / capacity, jnp.inf)
    return jnp.argmin(rel)


class JaxLeastLoadedBroker(JaxScheduler):
    """Vectorized ``leastloaded`` dispatch.

    Snapshot semantics match the other jax brokers: every job in a batch
    sees the same load vector (queued work is not updated between batch
    members), so the whole batch lands on the argmin site — bulk placement
    trades spreading for one fused decision, exactly like the dataaware
    batch broker's shared-snapshot argmax.
    """

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        load, cap, online = self.site_state_np()
        site = int(leastloaded_select(jnp.asarray(load), jnp.asarray(cap),
                                      jnp.asarray(online)))
        return [site] * len(required_sets)


class JaxRandomBroker(JaxScheduler):
    """Vectorized ``random`` dispatch: a host-PRNG index vector gathered
    over the online-site vector on device.

    Site-for-site identical to the sequential :class:`repro.core.scheduler.
    RandomScheduler`: ``rng.choice(seq)`` consumes exactly one
    ``_randbelow(len(seq))`` draw, and so does ``rng.randrange(n)`` here —
    share (or equally seed) the policy's ``Random`` and the decision
    streams coincide.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 rng) -> None:
        super().__init__(catalog, topology)
        self.rng = rng

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        _, _, online = self.site_state_np()
        ids = np.flatnonzero(online)
        idx = np.array([self.rng.randrange(len(ids))
                        for _ in required_sets], np.intp)
        return [int(s) for s in jnp.take(jnp.asarray(ids), jnp.asarray(idx))]


@jax.jit
def st_costs_batch(path, valid, link_bw, link_act, presence, fetch_mask,
                   sizes, required, rel, online):
    """ShortestTransfer (Chang et al. [6]) as one fused computation.

    path/valid: i32/bool[n_sites, n_sites, max_links] — static link-id
    tensor (``[h, s]`` row = ``link_ids_for(h, s)``, -1 padded); link_bw /
    link_act: f32[n_links] — the NetworkEngine arrays; presence:
    bool[n_sites, n_files]; fetch_mask: presence restricted to fetchable
    holders (online or durable master); required: bool[n_jobs, n_files].
    Returns f32[n_jobs, n_sites] costs (inf for offline sites).
    """
    share = link_bw / (link_act + 1.0)                       # + the new flow
    b = jnp.where(valid, share[jnp.maximum(path, 0)], jnp.inf)
    b = jnp.min(b, axis=-1)                                  # B[h, s]
    # best fetchable source per (file, dst): max over holders of B[h, s]
    bestbw = jnp.max(
        jnp.where(fetch_mask[:, :, None], b[:, None, :], 0.0), axis=0)
    t_fs = jnp.where(bestbw > 0.0, sizes[:, None] / bestbw, jnp.inf)
    # files the job still needs at s (zero-bw guard -> inf cost survives)
    miss = required[:, :, None] & ~presence.T[None, :, :]    # [J, F, S]
    t = jnp.sum(jnp.where(miss, t_fs[None], 0.0), axis=1)    # [J, S]
    cost = jnp.maximum(t, rel[None, :])
    return jnp.where(online[None, :], cost, jnp.inf)


class JaxShortestTransferBroker(JaxScheduler):
    """Vectorized ``shortesttransfer`` dispatch over a shared snapshot.

    Mirrors :meth:`repro.core.scheduler.ShortestTransferScheduler.
    select_site` — including the durable-masters rule and the zero-bandwidth
    guard — but costs every (job, site) pair at once against a
    point-bandwidth matrix built from the NetworkEngine's per-link
    bandwidth/occupancy arrays. Like the dataaware batch broker, all jobs
    in a batch see the same snapshot (queued work is not updated between
    batch members).
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 network) -> None:
        super().__init__(catalog, topology)
        self.network = network
        self.masters = np.array(
            [catalog.files[l].master_site for l in self.lfns], np.intp)
        n = topology.n_sites
        path = np.full((n, n, network.max_links), -1, np.int32)
        for h in range(n):
            for s in range(n):
                ids = topology.link_ids_for(h, s)
                path[h, s, : len(ids)] = ids
        self.path = jnp.asarray(path)
        self.path_valid = jnp.asarray(path >= 0)

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        presence = self.presence_np()
        load, cap, online = self.site_state_np()
        # fetchable = online holder, or the durable master copy
        files = np.arange(len(self.lfns))
        fetch_mask = presence & online[:, None]
        fetch_mask[self.masters, files] |= presence[self.masters, files]
        costs = st_costs_batch(
            self.path, self.path_valid,
            jnp.asarray(self.network.link_bw, jnp.float32),
            jnp.asarray(self.network.link_act, jnp.float32),
            jnp.asarray(presence), jnp.asarray(fetch_mask), self.sizes,
            jnp.asarray(self.required_np(required_sets)),
            jnp.asarray(load / cap), jnp.asarray(online))
        return [int(i) for i in jnp.argmin(costs, axis=1)]
