"""Vectorized, jit-compiled scheduling decision (beyond-paper).

The paper's algorithm is an argmax over sites of S_s with a relative-load
tie-break. At cluster scale (thousands of hosts, thousands of artifacts) the
Python loop becomes the broker's bottleneck, so we express the decision as a
single fused XLA computation over:

  presence:  bool[n_sites, n_files]  — replica catalog as a bitmap
  sizes:     f32[n_files]            — file sizes
  required:  bool[n_files]           — the job's R_j as a mask
  load:      f32[n_sites]            — queued work per site
  capacity:  f32[n_sites]            — CE capacity per site
  online:    bool[n_sites]

Tie-break is exact (no epsilon folding): stage 1 computes S_s and its max,
stage 2 arg-minimizes relative load over the tied sites only. Both stages
fuse into one XLA computation.

This module is also the bridge used by grid/placement.py to run dispatch
on-device for batches of jobs (vmap over the job axis).

Snapshot maintenance is incremental: the presence bitmap is kept current
by :class:`repro.core.catalog.ReplicaCatalog` change listeners (one cell
write per replica add/evict/loss) instead of a Python double loop over
the whole catalog per batch, and the file axis re-syncs lazily when files
are registered after broker construction (the same convention
:class:`repro.core.access.AccessHistory` uses).

Beyond the paper's policy, :class:`JaxShortestTransferBroker` vectorizes the
``shortesttransfer`` baseline the same way: each batch is costed against
the engine-shared point-bandwidth snapshot
(:meth:`repro.core.network.NetworkEngine.point_bandwidth_matrix`, the same
matrix the replication economy prices with) through the *blocked*
``repro.kernels.st_cost`` pass — running-max over holders, running-sum
over files — so peak broker memory is O(sites x files + sites x sites),
never the old ``(sites, files, sites)`` broadcast.

Degenerate-snapshot semantics match the sequential policies: dispatching
against a snapshot with **no online site** raises exactly what the
sequential policy would (``ValueError`` from the empty ``min``/``max`` for
the deterministic policies, ``IndexError`` from ``Random.choice(())`` for
``random`` — with no PRNG draw consumed), instead of argmin-over-inf
silently landing every job on site 0.

The batched *strategy* engine (``strategy_mode="batch"``,
:mod:`repro.core.replica`'s ``_BatchedStrategy`` family) follows the same
snapshot contract from the other side of the dispatch: once a burst's
placements are fixed, every missing (job, file) pair is planned against one
shared presence/bandwidth snapshot — the per-destination column view
(:meth:`repro.core.network.NetworkEngine.point_bandwidth_columns`) of the
same matrix the brokers cost with — and intra-burst conflicts are resolved
by revalidate-or-replan at execution time, exactly the tolerance convention
the jax brokers established for stale queue loads. Singleton bursts take the
sequential path bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .catalog import ReplicaCatalog
from .topology import GridTopology


@functools.partial(jax.jit, static_argnames=())
def select_site_vec(presence, sizes, required, load, capacity, online):
    """Paper §3.2 as one fused computation. Returns the chosen site index."""
    # S_s for every site: presence masked by the job's requirement
    s = (presence & required[None, :]) @ sizes              # [n_sites]
    s = jnp.where(online, s, -1.0)
    tie = s >= jnp.max(s)                                    # max-S_s sites
    rel = load / capacity                                    # [n_sites]
    rel = jnp.where(tie, rel, jnp.inf)
    return jnp.argmin(rel)                                   # first min = min (rel, id)


@jax.jit
def select_sites_batch(presence, sizes, masks, load, capacity, online):
    """Batched :func:`select_site_vec`, reformulated as one GEMM.

    A straight ``vmap`` of the single-job scorer materializes a
    ``(jobs, sites, files)`` bool intermediate (25M elements per 50-job
    burst at the 500-site scale point); algebraically the per-site byte
    sum is ``(masks * sizes) @ presence.T``, which XLA lowers to a real
    ``(jobs, files) x (files, sites)`` matmul instead. Same scores (file
    sizes are uniform per config, so the f32 sums are exact in any
    summation order), same tie-breaking as the vmapped form.
    """
    w = masks.astype(sizes.dtype) * sizes                   # [jobs, files]
    s = w @ presence.T.astype(sizes.dtype)                  # [jobs, sites]
    s = jnp.where(online[None, :], s, -1.0)
    tie = s >= jnp.max(s, axis=1, keepdims=True)
    rel = jnp.where(tie, (load / capacity)[None, :], jnp.inf)
    return jnp.argmin(rel, axis=1)


class JaxScheduler:
    """Array-backed mirror of (catalog, topology) for on-device dispatch.

    Also the snapshot substrate for every jax broker: the host-side
    presence bitmap, per-site load/capacity/online vectors and
    required-file masks built here are shared with
    :class:`JaxShortestTransferBroker`.

    The presence bitmap is maintained **incrementally**: the broker
    registers as a catalog change listener and flips single cells as
    replicas are added/evicted/lost. Files registered after construction
    are picked up by the lazy :meth:`sync` (cheap count check per batch),
    which rebuilds the file axis carrying maintained columns over.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology) -> None:
        self.catalog = catalog
        self.topology = topology
        self.lfns = sorted(catalog.files)
        self.lfn_index = {l: i for i, l in enumerate(self.lfns)}
        self._sizes_np = np.array([catalog.size(l) for l in self.lfns],
                                  np.float64)
        self.sizes = jnp.asarray(self._sizes_np, jnp.float32)
        self._n_catalog = len(catalog.files)
        self._presence: np.ndarray | None = None    # built on first use
        catalog.add_listener(self)

    # -- catalog change listeners (incremental presence maintenance) -------
    def on_register_file(self, lfn: str) -> None:
        """New file axis entry; the next :meth:`sync` rebuilds (lazily —
        registration bursts cost one rebuild, not one per file)."""

    def on_add_replica(self, lfn: str, site_id: int) -> None:
        if self._presence is not None:
            j = self.lfn_index.get(lfn)
            if j is not None:
                self._presence[site_id, j] = True

    def on_remove_replica(self, lfn: str, site_id: int) -> None:
        if self._presence is not None:
            j = self.lfn_index.get(lfn)
            if j is not None:
                self._presence[site_id, j] = False

    # -- catalog sync ------------------------------------------------------
    def sync(self) -> None:
        """Pick up files registered in the catalog *after* construction
        (dynamic workloads, late-registered artifacts): rebuild the file
        axis in sorted order, carrying the incrementally-maintained
        presence columns over by LFN and filling new columns from the
        catalog. No-op when the catalog is unchanged."""
        if len(self.catalog.files) == self._n_catalog:
            return
        old_index = self.lfn_index
        old_presence = self._presence
        self.lfns = sorted(self.catalog.files)
        self.lfn_index = {l: i for i, l in enumerate(self.lfns)}
        self._sizes_np = np.array([self.catalog.size(l) for l in self.lfns],
                                  np.float64)
        self.sizes = jnp.asarray(self._sizes_np, jnp.float32)
        if old_presence is not None:
            presence = np.zeros((self.topology.n_sites, len(self.lfns)), bool)
            for j, lfn in enumerate(self.lfns):
                i = old_index.get(lfn)
                if i is not None:
                    presence[:, j] = old_presence[:, i]
                else:
                    self._fill_column(presence, j, lfn)
            self._presence = presence
        self._n_catalog = len(self.catalog.files)
        self._resync()

    def _resync(self) -> None:
        """Hook for subclasses with extra per-file state (e.g. masters)."""

    def _fill_column(self, presence: np.ndarray, j: int, lfn: str) -> None:
        """One file's presence column from the catalog's holder set — the
        single definition of what a bitmap cell means."""
        for h in sorted(self.catalog.holders(lfn)):
            presence[h, j] = True

    # -- host-side snapshot pieces (shared by all brokers) -----------------
    def presence_np(self) -> np.ndarray:
        """bool[n_sites, n_files] replica bitmap (all holders).

        The *live* incrementally-maintained array — treat it as
        read-only; copy before masking (``presence & ...`` does)."""
        self.sync()     # no-op unless files were registered late
        if self._presence is None:
            presence = np.zeros((self.topology.n_sites, len(self.lfns)), bool)
            for j, lfn in enumerate(self.lfns):
                self._fill_column(presence, j, lfn)
            self._presence = presence
        return self._presence

    def site_state_np(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(load, capacity, online) per-site vectors."""
        load = np.array([s.queued_work for s in self.topology.sites], np.float32)
        cap = np.array([s.compute_capacity for s in self.topology.sites], np.float32)
        online = np.array([s.online for s in self.topology.sites], bool)
        return load, cap, online

    def required_np(self, required_sets: list[list[str]]) -> np.ndarray:
        """bool[n_jobs, n_files] requirement masks (R_j rows)."""
        self.sync()     # no-op unless files were registered late
        m = np.zeros((len(required_sets), len(self.lfns)), dtype=bool)
        for i, req in enumerate(required_sets):
            for lfn in req:
                m[i, self.lfn_index[lfn]] = True
        return m

    @staticmethod
    def _check_online(online: np.ndarray) -> None:
        """All-offline guard, shared by every broker: raise exactly what
        the sequential policies' empty ``min``/``max`` raises instead of
        letting an argmin-over-inf dispatch to (offline) site 0."""
        if not online.any():
            raise ValueError("no online sites to dispatch to")

    def snapshot(self):
        load, cap, online = self.site_state_np()
        self._check_online(online)
        return (jnp.asarray(self.presence_np()), self.sizes,
                jnp.asarray(load), jnp.asarray(cap), jnp.asarray(online))

    def required_mask(self, required: list[str]) -> jnp.ndarray:
        return jnp.asarray(self.required_np([required])[0])

    def select(self, required: list[str]) -> int:
        self.sync()
        presence, sizes, load, cap, online = self.snapshot()
        return int(select_site_vec(presence, sizes, self.required_mask(required),
                                   load, cap, online))

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        self.sync()
        presence, sizes, load, cap, online = self.snapshot()
        masks = jnp.asarray(self.required_np(required_sets))
        # one host transfer for the whole batch (per-element int() would
        # sync the device once per job)
        return np.asarray(
            select_sites_batch(presence, sizes, masks, load, cap, online)
        ).tolist()


@jax.jit
def leastloaded_select(load, capacity, online):
    """LeastLoaded as one fused computation: argmin of relative load over
    online sites. ``jnp.argmin`` returns the first (lowest-id) minimum,
    matching the sequential policy's ``(relative_load, site_id)`` key.
    Callers must reject all-offline snapshots host-side — an argmin over
    all-``inf`` would silently return site 0."""
    rel = jnp.where(online, load / capacity, jnp.inf)
    return jnp.argmin(rel)


class JaxLeastLoadedBroker(JaxScheduler):
    """Vectorized ``leastloaded`` dispatch.

    Snapshot semantics match the other jax brokers: every job in a batch
    sees the same load vector (queued work is not updated between batch
    members), so the whole batch lands on the argmin site — bulk placement
    trades spreading for one fused decision, exactly like the dataaware
    batch broker's shared-snapshot argmax.
    """

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        load, cap, online = self.site_state_np()
        self._check_online(online)
        site = int(leastloaded_select(jnp.asarray(load), jnp.asarray(cap),
                                      jnp.asarray(online)))
        return [site] * len(required_sets)


class JaxRandomBroker(JaxScheduler):
    """Vectorized ``random`` dispatch: a host-PRNG index vector gathered
    over the online-site vector on device.

    Site-for-site identical to the sequential :class:`repro.core.scheduler.
    RandomScheduler`: ``rng.choice(seq)`` consumes exactly one
    ``_randbelow(len(seq))`` draw, and so does ``rng.randrange(n)`` here —
    share (or equally seed) the policy's ``Random`` and the decision
    streams coincide. With no online site the sequential policy's
    ``choice`` raises ``IndexError`` *without* touching the PRNG
    (``_randbelow(0)`` draws nothing), so the broker does the same — the
    shared stream stays aligned across a caught churn-to-zero window.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 rng) -> None:
        super().__init__(catalog, topology)
        self.rng = rng

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        _, _, online = self.site_state_np()
        ids = np.flatnonzero(online)
        if ids.size == 0:
            raise IndexError("cannot choose from an empty online-site list")
        idx = np.array([self.rng.randrange(len(ids))
                        for _ in required_sets], np.intp)
        return np.asarray(
            jnp.take(jnp.asarray(ids), jnp.asarray(idx))).tolist()


class JaxShortestTransferBroker(JaxScheduler):
    """Vectorized ``shortesttransfer`` dispatch over a shared snapshot.

    Mirrors :meth:`repro.core.scheduler.ShortestTransferScheduler.
    select_site` — including the durable-masters rule, the zero-bandwidth
    guard and the all-``inf`` tie rule (first online site) — but costs
    every (job, site) pair at once through the blocked
    :func:`repro.kernels.st_cost.st_cost` pass against the
    **engine-shared** point-bandwidth snapshot
    (:meth:`repro.core.network.NetworkEngine.point_bandwidth_matrix`):
    one cached ``(sites, sites, depth)`` link tensor serves this broker
    and the replication economy alike, and no private path tensor is
    built. The file axis is restricted to the batch's required-file
    union before costing — bit-exact (absent files contribute exact
    zeros) and it keeps the per-batch oracle work O(union x sites).
    Like the dataaware batch broker, all jobs in a batch see the same
    snapshot (queued work is not updated between batch members).
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 network) -> None:
        super().__init__(catalog, topology)
        self.network = network
        self._resync()
        # st_cost route, re-resolved per call ("auto" = compiled Pallas
        # kernel on TPU, float64 oracle on CPU); tests may override
        self._backend = "auto"

    def _resync(self) -> None:
        self.masters = np.array(
            [self.catalog.files[l].master_site for l in self.lfns], np.intp)

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        from repro.kernels.st_cost import st_cost  # jax-free package import
        self.sync()
        presence = self.presence_np()
        online = np.array([s.online for s in self.topology.sites], bool)
        self._check_online(online)
        required = self.required_np(required_sets)
        # restrict every file-axis input to the batch's required-file
        # union up front (ascending ids, so sum order is preserved)
        union = np.flatnonzero(required.any(axis=0))
        presence_u = presence[:, union]
        # fetchable = online holder, or the durable master copy
        files = np.arange(union.size)
        masters_u = self.masters[union]
        fetch_mask = presence_u & online[:, None]
        fetch_mask[masters_u, files] |= presence_u[masters_u, files]
        # relative load in float64 straight from the sites — the exact
        # doubles the sequential policy reads
        rel = np.array([s.relative_load() for s in self.topology.sites],
                       np.float64)
        costs = st_cost(
            self.network.point_bandwidth_matrix(),
            fetch_mask, presence_u, self._sizes_np[union],
            required[:, union], rel, online, backend=self._backend)
        picks = np.argmin(costs, axis=1)
        # every online site at inf (nothing fetchable at finite cost):
        # the sequential (cost, site_id) min takes the first online site
        stuck = ~np.isfinite(costs[np.arange(len(picks)), picks])
        if stuck.any():
            picks[stuck] = np.flatnonzero(online)[0]
        return [int(i) for i in picks]
