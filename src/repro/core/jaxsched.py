"""Vectorized, jit-compiled scheduling decision (beyond-paper).

The paper's algorithm is an argmax over sites of S_s with a relative-load
tie-break. At cluster scale (thousands of hosts, thousands of artifacts) the
Python loop becomes the broker's bottleneck, so we express the decision as a
single fused XLA computation over:

  presence:  bool[n_sites, n_files]  — replica catalog as a bitmap
  sizes:     f32[n_files]            — file sizes
  required:  bool[n_files]           — the job's R_j as a mask
  load:      f32[n_sites]            — queued work per site
  capacity:  f32[n_sites]            — CE capacity per site
  online:    bool[n_sites]

Tie-break is exact (no epsilon folding): stage 1 computes S_s and its max,
stage 2 arg-minimizes relative load over the tied sites only. Both stages
fuse into one XLA computation.

This module is also the bridge used by grid/placement.py to run dispatch
on-device for batches of jobs (vmap over the job axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .catalog import ReplicaCatalog
from .topology import GridTopology


@functools.partial(jax.jit, static_argnames=())
def select_site_vec(presence, sizes, required, load, capacity, online):
    """Paper §3.2 as one fused computation. Returns the chosen site index."""
    # S_s for every site: presence masked by the job's requirement
    s = (presence & required[None, :]) @ sizes              # [n_sites]
    s = jnp.where(online, s, -1.0)
    tie = s >= jnp.max(s)                                    # max-S_s sites
    rel = load / capacity                                    # [n_sites]
    rel = jnp.where(tie, rel, jnp.inf)
    return jnp.argmin(rel)                                   # first min = min (rel, id)


select_sites_batch = jax.jit(
    jax.vmap(select_site_vec, in_axes=(None, None, 0, None, None, None))
)


class JaxScheduler:
    """Array-backed mirror of (catalog, topology) for on-device dispatch."""

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology) -> None:
        self.catalog = catalog
        self.topology = topology
        self.lfns = sorted(catalog.files)
        self.lfn_index = {l: i for i, l in enumerate(self.lfns)}
        self.sizes = jnp.asarray([catalog.size(l) for l in self.lfns], jnp.float32)

    def snapshot(self):
        n_sites, n_files = self.topology.n_sites, len(self.lfns)
        presence = np.zeros((n_sites, n_files), dtype=bool)
        for j, lfn in enumerate(self.lfns):
            for h in self.catalog.holders(lfn):
                presence[h, j] = True
        load = np.array([s.queued_work for s in self.topology.sites], np.float32)
        cap = np.array([s.compute_capacity for s in self.topology.sites], np.float32)
        online = np.array([s.online for s in self.topology.sites], bool)
        return (jnp.asarray(presence), self.sizes, jnp.asarray(load),
                jnp.asarray(cap), jnp.asarray(online))

    def required_mask(self, required: list[str]) -> jnp.ndarray:
        m = np.zeros((len(self.lfns),), dtype=bool)
        for lfn in required:
            m[self.lfn_index[lfn]] = True
        return jnp.asarray(m)

    def select(self, required: list[str]) -> int:
        presence, sizes, load, cap, online = self.snapshot()
        return int(select_site_vec(presence, sizes, self.required_mask(required),
                                   load, cap, online))

    def select_batch(self, required_sets: list[list[str]]) -> list[int]:
        presence, sizes, load, cap, online = self.snapshot()
        masks = jnp.stack([self.required_mask(r) for r in required_sets])
        return [int(i) for i in
                select_sites_batch(presence, sizes, masks, load, cap, online)]
