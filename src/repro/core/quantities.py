"""Canonical unit constants + conversions for the grid simulator.

Every quantity in the engine is carried in base units — **bytes** for
sizes, **bytes/s** for bandwidth, **seconds** for sim time — while the
configuration surface speaks the paper's units (Mbps links, GB storage
elements, MB files) and the telemetry probe reports wall time in
microseconds. The conversions between the two vocabularies used to be
scattered ``* 1e6 / 8``-style literals; they live here now, under names
the unit checker (:mod:`repro.analysis.units`, rule SL024) can recognize
as sanctioned dimension changes.

All constants are exact in float64 (powers of ten and ``1e6 / 8 ==
125000.0``), so replacing a literal with its named constant is
bit-identical — the golden suites pin that.
"""

from __future__ import annotations

BITS_PER_BYTE = 8.0

#: Decimal size prefixes (storage vendors' GB, the paper's convention).
KB = 1e3
MB = 1e6
GB = 1e9
TB = 1e12

#: Mbps -> bytes/s: ``10 Mbps * MBPS_TO_BYTES_PER_S == 1.25e6 bytes/s``.
MBPS_TO_BYTES_PER_S = 1e6 / BITS_PER_BYTE

#: Wall-clock microseconds per second (the obs probe's span unit).
US_PER_S = 1e6


def mbps_to_bytes_per_s(mbps: float) -> float:
    """Link bandwidth from the config vocabulary to engine base units."""
    return mbps * MBPS_TO_BYTES_PER_S


def bytes_to_gb(n_bytes: float) -> float:
    """Engine byte totals to the report vocabulary (decimal GB)."""
    return n_bytes / GB


def us_to_s(us: float) -> float:
    """Probe wall-clock spans (microseconds) to seconds."""
    return us / US_PER_S
