"""Path-contention fluid network engine for the grid DES.

Owns every piece of transfer-network state the simulator used to keep
inline: slot-indexed numpy arrays of remaining bytes and rates, plus a
padded ``(slots, max_links)`` link-path matrix over a **unified link
space** — NIC ``i`` is link ``i`` and ``topology.wan_links[j]`` is link
``n_sites + j`` (see ``GridTopology.link_ids_for``). A transfer's rate is
the min over *every* link in its row of ``bandwidth / max(1, active)``,
so mid-tier uplinks congest under through-traffic on deep trees; on
two-level grids the row is exactly the legacy {source NIC, region uplink}
pair and results are bit-identical to the pre-refactor engine.

Interchangeable backends (the ``net=`` engine flag):

``"numpy"`` (default)
    Incremental re-rating: only slots sharing a link whose membership
    changed are re-rated (rates are pure functions of link occupancy, so
    this equals a full recompute — bit-identically). Small groups take a
    scalar fast path; larger ones a vectorized gather-min.

``"pallas"``
    The ``repro.kernels.net_rerate`` formulation: a per-link share vector
    per event, then one gather-min per changed-link batch — the compiled
    Pallas kernel on TPU, the identical inline numpy expression on CPU —
    so 100k-transfer batches re-rate as one fused pass instead of a
    python loop (and beat the incremental backend at the 10k-job scale
    point). ``"pallas-interpret"`` instead runs the *full* slot array
    plus the next-completion scan through the kernel under the Pallas
    interpreter every event (slow; extends the bit-identity contract to
    the kernel itself).

``"device"``
    The batched event engine (``repro.kernels.event_engine``): per-event
    ``rerate`` calls only mark the engine dirty, and the simulator runs
    one fused *flush* pass per drained event instant — remaining bytes
    are reconstructed on the fly from each slot's cached ``(rate, eta)``
    pair, every slot is re-rated, and a running-min over the new etas
    yields the next NET wake-up. Per-event work is O(1) regardless of
    how many transfers are in flight (the saturated-backlog pathology of
    the incremental backend), at the price of ulp-level drift: the
    reconstruction ``rate * (eta - now)`` rounds differently from the
    stepwise ``rem -= rate * dt`` integration, so the device engine is
    pinned to the numpy oracle by *tolerance* goldens
    (``tests/golden_tolerance.json``), not the bit-exact suite.
    ``"device-interpret"`` runs the same flush through the Pallas
    interpreter under x64 (slow; bit-identical to the ``"device"`` CPU
    route by the kernel's oracle-identity contract).

On CPU (oracle and interpret routes) the numpy and pallas backends return
identical results on identical histories; the golden suite pins this
(``tests/test_golden_metrics.py``). The *compiled* TPU kernel computes in
float32 (TPUs have no f64), so on TPU ``net="pallas"`` is an approximate
backend — rates drift at the 1e-7 relative level — and the bit-identity
contract applies to the CPU routes only.
"""

from __future__ import annotations

import math
from typing import Iterable, Optional

import numpy as np

from .topology import GridTopology

# A transfer is complete when less than one byte remains. Sub-byte residue
# left by float rounding must count as done, otherwise the event loop can
# starve: eta increments below the clock's ulp make dt == 0 forever.
_DONE_EPS = 1.0

BACKENDS = ("numpy", "pallas", "pallas-interpret", "device",
            "device-interpret")


class NetworkEngine:
    """Slot-indexed fluid-model transfer network (see module docstring)."""

    def __init__(self, topology: GridTopology, backend: str = "numpy") -> None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown network backend {backend!r} "
                             f"(want one of {BACKENDS})")
        self.topology = topology
        self.backend = backend
        self._ops_backend = {"pallas": "auto",
                             "pallas-interpret": "interpret"}.get(backend)
        self._use_kernel = False
        self.batched = backend in ("device", "device-interpret")
        if backend == "pallas":
            # resolve the route once: the compiled kernel op on TPU, the
            # inline share-vector gather-min (same math) on CPU. The
            # kernels package import is jax-free; ops pulls jax lazily.
            from repro.kernels.net_rerate import net_rerate
            import jax
            self._use_kernel = jax.default_backend() == "tpu"
            self._op = net_rerate
        elif self.batched:
            # same once-per-engine route resolution for the flush op: the
            # compiled event_engine kernel on TPU, its float64 numpy
            # oracle inline on CPU (no per-flush jax dispatch)
            from repro.kernels.event_engine import (event_engine,
                                                    event_engine_core)
            self._flush_op = event_engine
            self._flush_ref = event_engine_core
            if backend == "device":
                import jax
                self._use_kernel = jax.default_backend() == "tpu"
        n_sites = topology.n_sites
        self.n_links = n_sites + len(topology.wan_links)
        # the engine is the sole bookkeeper of link occupancy: alloc and
        # release update both the topology Link objects (read by
        # point_bandwidth during replica selection) and the float mirror
        # link_act (exact — the counts are small integers)
        self._link_objs = list(topology.nic_links) + list(topology.wan_links)
        self.link_bw = np.array([l.bandwidth for l in self._link_objs])
        self.link_act = np.array([float(l.active) for l in self._link_objs])
        # per-link member slots as insertion-ordered dicts (value unused):
        # O(1) add/remove like a set, but iteration order is allocation
        # order, not hash order — simlint SL001 bans iterating raw sets in
        # engine paths (rates are order-independent anyway; this keeps the
        # re-rate batch order reproducible by construction)
        self.members: list[dict[int, None]] = [
            {} for _ in range(self.n_links)]
        self.max_links = topology.depth        # NIC + up to depth-1 uplinks
        self.cap = 64
        self.rem = np.zeros(self.cap)
        self.rate = np.zeros(self.cap)
        # per-slot completion time cached by the last flush (inf where the
        # slot has no rate); the batched backend's only integration state —
        # rem is reconstructed from (rate, eta) instead of being advanced.
        # `due` is the precomputed completion deadline eta - eps/rate:
        # completions() is then a single compare against the clock instead
        # of an O(capacity) rem reconstruction per NET event
        self.eta = np.full(self.cap, np.inf)
        self.due = np.full(self.cap, np.inf)
        self.active = np.zeros(self.cap, bool)
        self.path = np.full((self.cap, self.max_links), -1, np.intp)
        self.obj: list[Optional[object]] = [None] * self.cap
        self._free = list(range(self.cap - 1, -1, -1))
        self.n_active = 0
        self.last = 0.0                        # last advance() timestamp
        self.dirty = False                     # batched: flush pending?
        # batched: links whose occupancy moved since the last flush
        # (insertion-ordered dict, same discipline as `members`)
        self._dirty_links: dict[int, None] = {}
        # per-event work counters (the saturated-backlog regression test
        # asserts on these, so they are part of the engine contract):
        # rerate_calls — rerate() invocations; rerate_slots — slots
        # re-rated *synchronously inside rerate()* (the incremental
        # routes' per-event member-union + eta-scan work; identically 0
        # on the batched backend, whose rerate only marks dirty links);
        # flush_passes / flush_slots — fused passes and the slots they
        # re-rated (at most one pass per drained instant).
        self.stats = {"rerate_calls": 0, "rerate_slots": 0,
                      "flush_passes": 0, "flush_slots": 0}
        self._pair_paths: Optional[np.ndarray] = None   # lazy (S, S, depth)
        # per-destination (link idx, validity) slices of the path tensor,
        # cached on first use: topology is static, only link shares move
        self._col_paths: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    # -- slot lifecycle ----------------------------------------------------
    def alloc(self, tr, size: float, links: tuple[int, ...]) -> int:
        """Claim a slot for ``tr`` (sets ``tr.slot``), register it on every
        link of ``links`` (unified ids, source NIC first)."""
        if not self._free:
            old = self.cap
            self.cap = old * 2
            self.rem = np.concatenate([self.rem, np.zeros(old)])
            self.rate = np.concatenate([self.rate, np.zeros(old)])
            self.eta = np.concatenate([self.eta, np.full(old, np.inf)])
            self.due = np.concatenate([self.due, np.full(old, np.inf)])
            self.active = np.concatenate([self.active, np.zeros(old, bool)])
            self.path = np.concatenate(
                [self.path, np.full((old, self.max_links), -1, np.intp)])
            self.obj.extend([None] * old)
            self._free.extend(range(self.cap - 1, old - 1, -1))
        slot = self._free.pop()
        tr.slot = slot
        self.rem[slot] = size
        self.rate[slot] = 0.0
        self.eta[slot] = np.inf   # unrated: flush reads rem verbatim
        self.due[slot] = np.inf
        row = self.path[slot]
        row[:] = -1
        row[: len(links)] = links
        self.active[slot] = True
        self.obj[slot] = tr
        self.n_active += 1
        for li in links:
            self.members[li][slot] = None
            self.link_act[li] += 1.0
            self._link_objs[li].active += 1
        return slot

    def release(self, tr) -> tuple[int, ...]:
        """Free ``tr``'s slot and de-register its links; returns the link
        ids whose occupancy changed (feed them back into ``rerate``)."""
        slot = tr.slot
        links = tuple(int(li) for li in self.path[slot] if li >= 0)
        self.active[slot] = False
        self.rate[slot] = 0.0
        self.rem[slot] = 0.0
        self.eta[slot] = np.inf
        self.due[slot] = np.inf
        self.path[slot, :] = -1
        self.obj[slot] = None
        self.n_active -= 1
        for li in links:
            self.members[li].pop(slot, None)
            self.link_act[li] -= 1.0
            self._link_objs[li].active -= 1
        self._free.append(slot)
        tr.slot = -1
        return links

    # -- bandwidth queries -------------------------------------------------
    def point_bandwidth(self, src: int, dst: int) -> float:
        """Available bandwidth if one more transfer joined ``src -> dst``,
        computed from the engine's own link arrays. The counts mirror the
        topology ``Link`` objects exactly (both are updated in
        ``alloc``/``release``), so this equals
        :meth:`GridTopology.point_bandwidth` bit-for-bit; it exists so the
        replication economy prices transfers against the same state the
        fluid model drains."""
        ids = self.topology.link_ids_for(src, dst)
        bw = np.inf
        for li in ids:
            share = self.link_bw[li] / (self.link_act[li] + 1.0)
            if share < bw:
                bw = share
        return float(bw)

    def point_bandwidth_matrix(self) -> np.ndarray:
        """``B[h, s]`` = :meth:`point_bandwidth` for every (source, dst)
        pair, as one vectorized gather-min over the cached static
        ``(sites, sites, depth)`` link-id tensor
        (:meth:`GridTopology.pair_link_matrix`). This is the one shared
        point-bandwidth snapshot: the replication economy prices
        transfers off it and the jitted shortest-transfer broker costs
        dispatch batches off it, so neither builds a private path tensor.
        The diagonal is the source NIC share (no uplinks crossed);
        consumers mask self-supply themselves."""
        if self._pair_paths is None:
            self._pair_paths = self.topology.pair_link_matrix()
        share = self.link_bw / (self.link_act + 1.0)
        p = self._pair_paths
        valid = p >= 0
        return np.where(valid, share[np.maximum(p, 0)], np.inf).min(axis=-1)

    def point_bandwidth_columns(self, dsts) -> np.ndarray:
        """Destination columns of :meth:`point_bandwidth_matrix`:
        ``B[h, p]`` = :meth:`point_bandwidth` ``(h, dsts[p])``, without
        materializing the full ``(sites, sites)`` matrix. The batched
        replica planners (``strategy_mode="batch"``) read one column per
        (job, missing-file) pair each arrival burst, so this is their
        per-burst cost: ``O(sites x pairs x depth)`` on the shared cached
        path tensor."""
        if self._pair_paths is None:
            self._pair_paths = self.topology.pair_link_matrix()
        share = self.link_bw / (self.link_act + 1.0)
        d = np.asarray(dsts, np.intp)
        # bursts repeat destinations (all of a job's files land on its
        # site): gather the path tensor once per unique column, then
        # replicate — pure indexing, bit-identical to the direct gather
        u, inv = np.unique(d, return_inverse=True)
        p = self._pair_paths[:, u, :]
        cols = np.where(p >= 0, share[np.maximum(p, 0)], np.inf).min(axis=-1)
        return cols[:, inv]

    def point_bandwidth_column(self, dst: int) -> np.ndarray:
        """One destination column, ``(sites,)`` — the singleton-replan
        route of the batched planners. Same expression as
        :meth:`point_bandwidth_columns` but sliced (no fancy-index copy
        of the path tensor), so the values are bit-identical to
        ``point_bandwidth_columns([dst])[:, 0]``."""
        cached = self._col_paths.get(dst)
        if cached is None:
            if self._pair_paths is None:
                self._pair_paths = self.topology.pair_link_matrix()
            p = self._pair_paths[:, dst, :]
            cached = (np.ascontiguousarray(np.maximum(p, 0)), p >= 0)
            self._col_paths[dst] = cached
        idx, valid = cached
        share = self.link_bw / (self.link_act + 1.0)
        return np.where(valid, share[idx], np.inf).min(axis=-1)

    # -- fluid model -------------------------------------------------------
    def advance(self, now: float) -> None:
        """Integrate all active transfers to ``now``.

        The batched backend never integrates on the host: ``rem`` is
        reconstructed from the cached ``(rate, eta)`` pair whenever it is
        read (:meth:`rem_now`), so advancing is just moving the clock."""
        if self.batched:
            self.last = now
            return
        dt = now - self.last
        if dt > 0:
            np.maximum(self.rem - self.rate * dt, 0.0, out=self.rem)
        self.last = now

    def rem_now(self, now: Optional[float] = None) -> np.ndarray:
        """Remaining bytes per slot at ``now`` (default: the clock set by
        the last :meth:`advance`/:meth:`flush`). On the batched backend
        this reconstructs ``rate * (eta - now)`` for slots the last flush
        rated — the exact formulation the flush pass itself uses — and
        reads the stored array for fresh/released slots; on the
        incremental backends ``rem`` is already integrated and is
        returned as-is."""
        if not self.batched:
            return self.rem
        if now is None:
            now = self.last
        carried = self.rate > 0.0
        eta_c = np.where(carried, self.eta, 0.0)
        return np.maximum(
            np.where(carried, self.rate * (eta_c - now), self.rem), 0.0)

    def completions(self) -> np.ndarray:
        """Slot indices of active transfers with < 1 byte remaining.

        Batched backends compare the precomputed per-slot deadline
        (``due = eta - eps/rate``, maintained by :meth:`flush`) against
        the clock — algebraically the same ``rem <= eps`` test
        (``rate * (eta - now) <= eps``), one compare per slot instead of
        a full rem reconstruction per NET event."""
        if self.batched:
            # released/fresh slots carry due = inf, so the deadline
            # compare alone is the active-and-due mask
            return np.nonzero(self.due <= self.last)[0]
        return np.nonzero(self.active & (self.rem_now() <= _DONE_EPS))[0]

    def _rate_slots(self, slots: list[int],
                    share: Optional[np.ndarray] = None) -> None:
        """Recompute rate = min over the slot's links of bw/active for
        ``slots``. Pure function of current link occupancy, so re-rating a
        slot twice (it sits in several changed link groups) is harmless.

        ``share`` is an optional precomputed per-link share vector
        (``link_bw / max(1, link_act)``) — ``rerate`` hoists it once per
        event when the batch is big enough to amortize it; element-wise
        it is the exact same IEEE division, so both forms produce
        identical rates."""
        n = len(slots)
        if n == 0:
            return
        if n <= 4:      # numpy call overhead dominates tiny groups
            for sl in slots:
                r = np.inf
                for li in self.path[sl]:
                    if li < 0:
                        break
                    s = (self.link_bw[li] / max(1.0, self.link_act[li])
                         if share is None else share[li])
                    if s < r:
                        r = s
                self.rate[sl] = r
            return
        idx = np.fromiter(slots, np.intp, n)
        p = self.path[idx]
        valid = p >= 0
        safe = np.where(valid, p, 0)
        sh = (self.link_bw[safe] / np.maximum(1.0, self.link_act[safe])
              if share is None else share[safe])
        self.rate[idx] = np.where(valid, sh, np.inf).min(axis=1)

    def rerate(self, changed: Iterable[int], now: float) -> Optional[float]:
        """Refresh rates after the occupancy of ``changed`` links moved;
        return the next completion time (None when nothing is draining).

        All three routes compute the same pure function of link occupancy
        and give identical results; they differ only in batching:

        * numpy — incremental: re-rate the union of the changed links'
          member slots in one vectorized gather-min (small unions take a
          scalar fast path), then scan for the next completion on the
          host.
        * pallas — the kernel's formulation of the same union batch. On
          TPU it is a compiled ``net_rerate`` kernel call; on CPU the
          identical expression runs inline in numpy (measurably faster
          than the incremental baseline at the 10k-job scale point — see
          ``results/BENCH_net.json``). Host next-completion scan.
        * pallas-interpret — full-array: every slot (released rows are all
          ``-1`` and rate 0) plus the next-completion scan in a single
          kernel invocation under the Pallas interpreter. Slow; exists so
          the bit-identity contract covers the kernel end to end.
        * device / device-interpret — deferred: record the changed link
          ids and mark the engine dirty, O(path length) per event no
          matter how many transfers are in flight; the simulator runs one
          fused :meth:`flush` per drained event instant, which re-rates
          the whole dirty neighborhood and reschedules the NET wake-up.
        """
        self.stats["rerate_calls"] += 1
        if self.batched:
            for li in changed:
                self._dirty_links[li] = None
            self.dirty = True
            return None
        if self._ops_backend == "interpret":
            if self.n_active == 0:
                return None
            from repro.kernels.net_rerate import net_rerate  # deferred: jax
            self.stats["rerate_slots"] += self.n_active
            rate, eta = net_rerate(self.path, self.rem, self.link_bw,
                                   self.link_act, now, backend="interpret")
            self.rate[:] = rate
            return eta if np.isfinite(eta) else None
        # union the changed links' member slots first: a transfer whose
        # path crosses several changed links (source NIC + uplinks) is
        # re-rated once instead of once per link. Rates are pure functions
        # of current occupancy, so this is exactly the same computation.
        changed = list(changed)
        if len(changed) == 1:
            slots = list(self.members[changed[0]])
        else:
            # merge the changed links' member dicts: a transfer crossing
            # several changed links dedups, and the batch keeps a
            # deterministic (changed-order, then allocation-order) order
            merged: dict[int, None] = {}
            for li in changed:
                merged.update(self.members[li])
            slots = list(merged)
        self.stats["rerate_slots"] += len(slots)
        if self._use_kernel:
            if slots:
                idx = np.fromiter(slots, np.intp, len(slots))
                rate, _ = self._op(self.path[idx], self.rem[idx],
                                   self.link_bw, self.link_act, now,
                                   backend="pallas")
                self.rate[idx] = rate
        else:
            # share vector hoisted once per event (occupancy is fixed
            # while re-rating) for both CPU routes when the batch is big
            # enough to amortize it: element-wise it is the exact same
            # IEEE division as the per-slot gather, so rates are
            # bit-identical either way.
            share = (self.link_bw / np.maximum(1.0, self.link_act)
                     if len(slots) > 4 else None)
            self._rate_slots(slots, share)
        if self.n_active == 0:
            return None
        live = self.rate > 0.0   # released slots are zeroed, so live ⊆ active
        if not live.any():
            return None
        return float(np.min(now + self.rem[live] / self.rate[live]))

    def flush(self, now: float) -> Optional[float]:
        """Batched backends only: fold every occupancy change recorded
        since the last flush into one fused reconstruct + re-rate +
        next-completion pass (:mod:`repro.kernels.event_engine`) and
        clear the dirty state.

        The pass covers the *dirty neighborhood* — the union of the dirty
        links' member slots, merged once per instant instead of once per
        event (slots on untouched links keep their cached ``(rate, eta)``
        pair: rates are pure functions of link occupancy, so they are
        still exact). The next completion then comes from one vectorized
        running-min over the cached eta array (released slots are ``inf``)
        — O(capacity) *per instant*, where the incremental backends pay an
        O(live) scan per *event*. On TPU (and under ``device-interpret``)
        the kernel instead sees the full slot array in a single call —
        subset gathers save nothing when the whole array is one fused
        device pass — and its running-min output is used directly.

        Writes back the reconstructed ``rem``, the new ``rate`` and the
        new per-slot ``eta`` (so host readers — completions, the tie-race
        digest — see state as of ``now``) and returns the earliest
        completion time, or None when nothing is draining. The simulator
        calls this once per drained event instant
        (``GridSimulator._net_flush``)."""
        self.dirty = False
        self.last = now
        self.stats["flush_passes"] += 1
        if self.n_active == 0:
            self._dirty_links.clear()
            return None
        if self._use_kernel or self.backend == "device-interpret":
            self._dirty_links.clear()
            self.stats["flush_slots"] += self.n_active
            out = self._flush_op(self.path, self.rem, self.rate, self.eta,
                                 self.link_bw, self.link_act, now,
                                 backend="pallas" if self._use_kernel
                                 else "interpret")
            rem_now, rate_new, eta_new, eta_min = out
            self.rem[:] = rem_now
            self.rate[:] = rate_new
            self.eta[:] = eta_new
            live = rate_new > 0.0
            self.due[:] = np.where(
                live, eta_new - _DONE_EPS / np.where(live, rate_new, 1.0),
                np.inf)
            return eta_min if np.isfinite(eta_min) else None
        # CPU route: the same fused pass (float64 oracle) over the dirty
        # neighborhood, then the running-min over the eta array
        merged: dict[int, None] = {}
        for li in self._dirty_links:
            merged.update(self.members[li])
        self._dirty_links.clear()
        if merged:
            self.stats["flush_slots"] += len(merged)
            if len(merged) <= 8:
                # scalar fast path: same IEEE-double math as the ref pass
                # (Python floats are f64), skipping the fancy-index
                # gather/scatter overhead that dominates tiny unions
                bw, act = self.link_bw, self.link_act
                for s in merged:
                    r_new = math.inf
                    for li in self.path[s]:
                        if li < 0:
                            break
                        a = act[li]
                        sh = bw[li] / (a if a > 1.0 else 1.0)
                        if sh < r_new:
                            r_new = sh
                    if math.isinf(r_new):   # all-padding row
                        r_new = 0.0
                    old_rate = self.rate[s]
                    if old_rate > 0.0:
                        rn = old_rate * (self.eta[s] - now)
                    else:
                        rn = self.rem[s]
                    if rn < 0.0:
                        rn = 0.0
                    self.rem[s] = rn
                    self.rate[s] = r_new
                    if r_new > 0.0:
                        e = now + rn / r_new
                        self.eta[s] = e
                        self.due[s] = e - _DONE_EPS / r_new
                    else:
                        self.eta[s] = np.inf
                        self.due[s] = np.inf
                eta_min = float(self.eta.min())
                return eta_min if np.isfinite(eta_min) else None
            idx = np.fromiter(merged, np.intp, len(merged))
            rem_now, rate_new, eta_new, _ = self._flush_ref(
                self.path[idx], self.rem[idx], self.rate[idx],
                self.eta[idx], self.link_bw, self.link_act, now)
            self.rem[idx] = rem_now
            self.rate[idx] = rate_new
            self.eta[idx] = eta_new
            live = rate_new > 0.0
            self.due[idx] = np.where(
                live, eta_new - _DONE_EPS / np.where(live, rate_new, 1.0),
                np.inf)
        eta_min = float(self.eta.min())
        return eta_min if np.isfinite(eta_min) else None
