"""Path-contention fluid network engine for the grid DES.

Owns every piece of transfer-network state the simulator used to keep
inline: slot-indexed numpy arrays of remaining bytes and rates, plus a
padded ``(slots, max_links)`` link-path matrix over a **unified link
space** — NIC ``i`` is link ``i`` and ``topology.wan_links[j]`` is link
``n_sites + j`` (see ``GridTopology.link_ids_for``). A transfer's rate is
the min over *every* link in its row of ``bandwidth / max(1, active)``,
so mid-tier uplinks congest under through-traffic on deep trees; on
two-level grids the row is exactly the legacy {source NIC, region uplink}
pair and results are bit-identical to the pre-refactor engine.

Two interchangeable backends (the ``net=`` engine flag):

``"numpy"`` (default)
    Incremental re-rating: only slots sharing a link whose membership
    changed are re-rated (rates are pure functions of link occupancy, so
    this equals a full recompute — bit-identically). Small groups take a
    scalar fast path; larger ones a vectorized gather-min.

``"pallas"``
    The ``repro.kernels.net_rerate`` formulation: a per-link share vector
    per event, then one gather-min per changed-link batch — the compiled
    Pallas kernel on TPU, the identical inline numpy expression on CPU —
    so 100k-transfer batches re-rate as one fused pass instead of a
    python loop (and beat the incremental backend at the 10k-job scale
    point). ``"pallas-interpret"`` instead runs the *full* slot array
    plus the next-completion scan through the kernel under the Pallas
    interpreter every event (slow; extends the bit-identity contract to
    the kernel itself).

On CPU (oracle and interpret routes) both backends return identical
results on identical histories; the golden suite pins this
(``tests/test_golden_metrics.py``). The *compiled* TPU kernel computes in
float32 (TPUs have no f64), so on TPU ``net="pallas"`` is an approximate
backend — rates drift at the 1e-7 relative level — and the bit-identity
contract applies to the CPU routes only.
"""

from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from .topology import GridTopology

# A transfer is complete when less than one byte remains. Sub-byte residue
# left by float rounding must count as done, otherwise the event loop can
# starve: eta increments below the clock's ulp make dt == 0 forever.
_DONE_EPS = 1.0

BACKENDS = ("numpy", "pallas", "pallas-interpret")


class NetworkEngine:
    """Slot-indexed fluid-model transfer network (see module docstring)."""

    def __init__(self, topology: GridTopology, backend: str = "numpy") -> None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown network backend {backend!r} "
                             f"(want one of {BACKENDS})")
        self.topology = topology
        self.backend = backend
        self._ops_backend = {"pallas": "auto",
                             "pallas-interpret": "interpret"}.get(backend)
        self._use_kernel = False
        if backend == "pallas":
            # resolve the route once: the compiled kernel op on TPU, the
            # inline share-vector gather-min (same math) on CPU. The
            # kernels package import is jax-free; ops pulls jax lazily.
            from repro.kernels.net_rerate import net_rerate
            import jax
            self._use_kernel = jax.default_backend() == "tpu"
            self._op = net_rerate
        n_sites = topology.n_sites
        self.n_links = n_sites + len(topology.wan_links)
        # the engine is the sole bookkeeper of link occupancy: alloc and
        # release update both the topology Link objects (read by
        # point_bandwidth during replica selection) and the float mirror
        # link_act (exact — the counts are small integers)
        self._link_objs = list(topology.nic_links) + list(topology.wan_links)
        self.link_bw = np.array([l.bandwidth for l in self._link_objs])
        self.link_act = np.array([float(l.active) for l in self._link_objs])
        # per-link member slots as insertion-ordered dicts (value unused):
        # O(1) add/remove like a set, but iteration order is allocation
        # order, not hash order — simlint SL001 bans iterating raw sets in
        # engine paths (rates are order-independent anyway; this keeps the
        # re-rate batch order reproducible by construction)
        self.members: list[dict[int, None]] = [
            {} for _ in range(self.n_links)]
        self.max_links = topology.depth        # NIC + up to depth-1 uplinks
        self.cap = 64
        self.rem = np.zeros(self.cap)
        self.rate = np.zeros(self.cap)
        self.active = np.zeros(self.cap, bool)
        self.path = np.full((self.cap, self.max_links), -1, np.intp)
        self.obj: list[Optional[object]] = [None] * self.cap
        self._free = list(range(self.cap - 1, -1, -1))
        self.n_active = 0
        self.last = 0.0                        # last advance() timestamp
        self._pair_paths: Optional[np.ndarray] = None   # lazy (S, S, depth)

    # -- slot lifecycle ----------------------------------------------------
    def alloc(self, tr, size: float, links: tuple[int, ...]) -> int:
        """Claim a slot for ``tr`` (sets ``tr.slot``), register it on every
        link of ``links`` (unified ids, source NIC first)."""
        if not self._free:
            old = self.cap
            self.cap = old * 2
            self.rem = np.concatenate([self.rem, np.zeros(old)])
            self.rate = np.concatenate([self.rate, np.zeros(old)])
            self.active = np.concatenate([self.active, np.zeros(old, bool)])
            self.path = np.concatenate(
                [self.path, np.full((old, self.max_links), -1, np.intp)])
            self.obj.extend([None] * old)
            self._free.extend(range(self.cap - 1, old - 1, -1))
        slot = self._free.pop()
        tr.slot = slot
        self.rem[slot] = size
        self.rate[slot] = 0.0
        row = self.path[slot]
        row[:] = -1
        row[: len(links)] = links
        self.active[slot] = True
        self.obj[slot] = tr
        self.n_active += 1
        for li in links:
            self.members[li][slot] = None
            self.link_act[li] += 1.0
            self._link_objs[li].active += 1
        return slot

    def release(self, tr) -> tuple[int, ...]:
        """Free ``tr``'s slot and de-register its links; returns the link
        ids whose occupancy changed (feed them back into ``rerate``)."""
        slot = tr.slot
        links = tuple(int(li) for li in self.path[slot] if li >= 0)
        self.active[slot] = False
        self.rate[slot] = 0.0
        self.rem[slot] = 0.0
        self.path[slot, :] = -1
        self.obj[slot] = None
        self.n_active -= 1
        for li in links:
            self.members[li].pop(slot, None)
            self.link_act[li] -= 1.0
            self._link_objs[li].active -= 1
        self._free.append(slot)
        tr.slot = -1
        return links

    # -- bandwidth queries -------------------------------------------------
    def point_bandwidth(self, src: int, dst: int) -> float:
        """Available bandwidth if one more transfer joined ``src -> dst``,
        computed from the engine's own link arrays. The counts mirror the
        topology ``Link`` objects exactly (both are updated in
        ``alloc``/``release``), so this equals
        :meth:`GridTopology.point_bandwidth` bit-for-bit; it exists so the
        replication economy prices transfers against the same state the
        fluid model drains."""
        ids = self.topology.link_ids_for(src, dst)
        bw = np.inf
        for li in ids:
            share = self.link_bw[li] / (self.link_act[li] + 1.0)
            if share < bw:
                bw = share
        return float(bw)

    def point_bandwidth_matrix(self) -> np.ndarray:
        """``B[h, s]`` = :meth:`point_bandwidth` for every (source, dst)
        pair, as one vectorized gather-min over the cached static
        ``(sites, sites, depth)`` link-id tensor
        (:meth:`GridTopology.pair_link_matrix`). This is the one shared
        point-bandwidth snapshot: the replication economy prices
        transfers off it and the jitted shortest-transfer broker costs
        dispatch batches off it, so neither builds a private path tensor.
        The diagonal is the source NIC share (no uplinks crossed);
        consumers mask self-supply themselves."""
        if self._pair_paths is None:
            self._pair_paths = self.topology.pair_link_matrix()
        share = self.link_bw / (self.link_act + 1.0)
        p = self._pair_paths
        valid = p >= 0
        return np.where(valid, share[np.maximum(p, 0)], np.inf).min(axis=-1)

    # -- fluid model -------------------------------------------------------
    def advance(self, now: float) -> None:
        """Integrate all active transfers to ``now``."""
        dt = now - self.last
        if dt > 0:
            np.maximum(self.rem - self.rate * dt, 0.0, out=self.rem)
        self.last = now

    def completions(self) -> np.ndarray:
        """Slot indices of active transfers with < 1 byte remaining."""
        return np.nonzero(self.active & (self.rem <= _DONE_EPS))[0]

    def _rate_slots(self, slots: list[int],
                    share: Optional[np.ndarray] = None) -> None:
        """Recompute rate = min over the slot's links of bw/active for
        ``slots``. Pure function of current link occupancy, so re-rating a
        slot twice (it sits in several changed link groups) is harmless.

        ``share`` is an optional precomputed per-link share vector
        (``link_bw / max(1, link_act)``) — ``rerate`` hoists it once per
        event when the batch is big enough to amortize it; element-wise
        it is the exact same IEEE division, so both forms produce
        identical rates."""
        n = len(slots)
        if n == 0:
            return
        if n <= 4:      # numpy call overhead dominates tiny groups
            for sl in slots:
                r = np.inf
                for li in self.path[sl]:
                    if li < 0:
                        break
                    s = (self.link_bw[li] / max(1.0, self.link_act[li])
                         if share is None else share[li])
                    if s < r:
                        r = s
                self.rate[sl] = r
            return
        idx = np.fromiter(slots, np.intp, n)
        p = self.path[idx]
        valid = p >= 0
        safe = np.where(valid, p, 0)
        sh = (self.link_bw[safe] / np.maximum(1.0, self.link_act[safe])
              if share is None else share[safe])
        self.rate[idx] = np.where(valid, sh, np.inf).min(axis=1)

    def rerate(self, changed: Iterable[int], now: float) -> Optional[float]:
        """Refresh rates after the occupancy of ``changed`` links moved;
        return the next completion time (None when nothing is draining).

        All three routes compute the same pure function of link occupancy
        and give identical results; they differ only in batching:

        * numpy — incremental: re-rate the union of the changed links'
          member slots in one vectorized gather-min (small unions take a
          scalar fast path), then scan for the next completion on the
          host.
        * pallas — the kernel's formulation of the same union batch. On
          TPU it is a compiled ``net_rerate`` kernel call; on CPU the
          identical expression runs inline in numpy (measurably faster
          than the incremental baseline at the 10k-job scale point — see
          ``results/BENCH_net.json``). Host next-completion scan.
        * pallas-interpret — full-array: every slot (released rows are all
          ``-1`` and rate 0) plus the next-completion scan in a single
          kernel invocation under the Pallas interpreter. Slow; exists so
          the bit-identity contract covers the kernel end to end.
        """
        if self._ops_backend == "interpret":
            if self.n_active == 0:
                return None
            from repro.kernels.net_rerate import net_rerate  # deferred: jax
            rate, eta = net_rerate(self.path, self.rem, self.link_bw,
                                   self.link_act, now, backend="interpret")
            self.rate[:] = rate
            return eta if np.isfinite(eta) else None
        # union the changed links' member slots first: a transfer whose
        # path crosses several changed links (source NIC + uplinks) is
        # re-rated once instead of once per link. Rates are pure functions
        # of current occupancy, so this is exactly the same computation.
        changed = list(changed)
        if len(changed) == 1:
            slots = list(self.members[changed[0]])
        else:
            # merge the changed links' member dicts: a transfer crossing
            # several changed links dedups, and the batch keeps a
            # deterministic (changed-order, then allocation-order) order
            merged: dict[int, None] = {}
            for li in changed:
                merged.update(self.members[li])
            slots = list(merged)
        if self._use_kernel:
            if slots:
                idx = np.fromiter(slots, np.intp, len(slots))
                rate, _ = self._op(self.path[idx], self.rem[idx],
                                   self.link_bw, self.link_act, now,
                                   backend="pallas")
                self.rate[idx] = rate
        else:
            # share vector hoisted once per event (occupancy is fixed
            # while re-rating) for both CPU routes when the batch is big
            # enough to amortize it: element-wise it is the exact same
            # IEEE division as the per-slot gather, so rates are
            # bit-identical either way.
            share = (self.link_bw / np.maximum(1.0, self.link_act)
                     if len(slots) > 4 else None)
            self._rate_slots(slots, share)
        if self.n_active == 0:
            return None
        live = self.rate > 0.0   # released slots are zeroed, so live ⊆ active
        if not live.any():
            return None
        return float(np.min(now + self.rem[live] / self.rate[live]))
