"""Discrete-event data-grid simulator (the paper's GridSim analogue, §4).

Implements the full job lifecycle of the paper:

  submit -> broker schedules (policy) -> site queue -> replica manager fetches
  missing files (strategy) -> job processes when data ready AND CE free ->
  done.  Job time = max(transfer time, queue time) + processing time, which is
  what the event ordering below produces naturally.

Network: event-driven fair-share links with re-rating (each transfer's rate is
the min over its links of bandwidth/active). This reproduces GridSim's
contention behaviour — the WAN uplink saturates under inter-region traffic —
without a packet simulator.

Engine hot paths are built for 10k-job scale:
  * transfer state (remaining bytes, rate, link membership) lives in
    slot-indexed numpy arrays; advancing the fluid model and scanning for the
    next completion are vectorized instead of per-transfer Python loops;
  * re-rating is incremental: only transfers sharing a link whose membership
    changed are re-rated (rates are pure functions of link occupancy, so this
    is exactly equivalent to a full recompute — bit-identical results);
  * CPU queues are deques and site-job sets are ordered dicts with O(1)
    removal; cancelled jobs tombstone in place (``done`` flag) and are
    skipped when popped, never removed by O(n) scans.
  * optionally, scheduling decisions are dispatched in jitted batches via
    ``repro.core.jaxsched`` (``broker="jax"``): simultaneous SUBMIT events
    (burst arrivals) are placed with one vectorized argmax over a shared
    catalog/load snapshot; with ``batch_window`` > 0 arrivals are held up to
    that many seconds and flushed as one batch (batching adds latency, never
    causality violations). The default ``broker="event"`` keeps the
    paper-exact sequential semantics.

Beyond the paper (fault-tolerance axis of this framework):
  * site failure/recovery events — non-master replicas lost, queued jobs
    resubmitted through the broker, in-flight transfers replanned;
  * straggler (slowdown) events with speculative backup jobs;
  * all deterministic under a seed.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import random as _random
from typing import Optional

import numpy as np

from .catalog import ReplicaCatalog
from .replica import FetchPlan, ReplicaStrategy, StorageState, make_strategy
from .scheduler import Job, SchedulerPolicy, make_scheduler
from .topology import GridTopology, Link


# --------------------------------------------------------------------------
# events
# --------------------------------------------------------------------------
(SUBMIT, NET, CPU_DONE, FAIL, RECOVER, SLOW_START, SLOW_END, WATCHDOG,
 FLUSH) = range(9)

# A transfer is complete when less than one byte remains. Sub-byte residue
# left by float rounding must count as done, otherwise the event loop can
# starve: eta increments below the clock's ulp make dt == 0 forever.
_DONE_EPS = 1.0


@dataclasses.dataclass(eq=False)
class _Transfer:
    tid: int
    plan: FetchPlan
    links: list[Link]
    slot: int = -1
    waiters: list["_JobState"] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(eq=False)
class _JobState:
    job: Job
    site: int = -1
    missing: list[str] = dataclasses.field(default_factory=list)
    pending_transfers: int = 0
    temp_files: list[str] = dataclasses.field(default_factory=list)
    pinned: list[str] = dataclasses.field(default_factory=list)
    data_ready_time: float = -1.0
    start_time: float = -1.0
    done: bool = False
    is_backup: bool = False
    twin: Optional["_JobState"] = None   # speculative copy, if any
    remaining_ops: float = 0.0
    rounds: int = 0                      # staging rounds (re-fetch after eviction)
    pin_on_arrival: bool = False         # anti-livelock escalation


@dataclasses.dataclass
class JobRecord:
    job_id: int
    job_type: int
    site: int
    submit_time: float
    data_ready_time: float
    start_time: float
    finish_time: float
    inter_comms: int
    wan_bytes: float
    resubmits: int = 0

    @property
    def job_time(self) -> float:
        return self.finish_time - self.submit_time


@dataclasses.dataclass
class SimResult:
    records: list[JobRecord]
    total_inter_comms: int
    total_wan_bytes: float
    total_lan_bytes: float
    makespan: float

    @property
    def avg_job_time(self) -> float:
        return sum(r.job_time for r in self.records) / max(1, len(self.records))

    @property
    def avg_inter_comms(self) -> float:
        return self.total_inter_comms / max(1, len(self.records))


class GridSimulator:
    def __init__(
        self,
        topology: GridTopology,
        catalog: ReplicaCatalog,
        *,
        scheduler: str | SchedulerPolicy = "dataaware",
        strategy: str | ReplicaStrategy = "hrs",
        seed: int = 0,
        speculative_backups: bool = False,
        straggler_threshold: float = 3.0,
        broker: str = "event",
        batch_window: float = 0.0,
    ) -> None:
        self.topology = topology
        self.catalog = catalog
        self.storage = StorageState(catalog, topology)
        self.scheduler = (
            scheduler if isinstance(scheduler, SchedulerPolicy)
            else make_scheduler(scheduler, catalog, topology, seed=seed)
        )
        self.strategy = (
            strategy if isinstance(strategy, ReplicaStrategy)
            else make_strategy(strategy, catalog, topology, self.storage)
        )
        self.rng = _random.Random(seed)
        self.speculative_backups = speculative_backups
        self.straggler_threshold = straggler_threshold
        self.batch_window = batch_window
        if broker == "jax":
            if self.scheduler.name != "dataaware":
                raise ValueError(
                    "broker='jax' implements only the paper's dataaware "
                    f"policy; got scheduler {self.scheduler.name!r}")
            from .jaxsched import JaxScheduler   # deferred: pulls in jax
            self._jax_broker: Optional["JaxScheduler"] = JaxScheduler(
                catalog, topology)
        elif broker == "event":
            if batch_window > 0:
                raise ValueError(
                    "batch_window only applies to broker='jax' "
                    "(the event broker dispatches each SUBMIT immediately)")
            self._jax_broker = None
        else:
            raise ValueError(f"unknown broker {broker!r} (want 'event'|'jax')")
        self._batch_buf: list[Job] = []
        self._flush_pending = False

        self._q: list[tuple[float, int, int, object]] = []
        self._seq = 0
        self.now = 0.0
        self._net_version = 0
        self._net_last = 0.0
        self._transfers: dict[int, _Transfer] = {}
        self._inflight: dict[tuple[int, str], _Transfer] = {}
        self._tid = 0
        # -- vectorized transfer state, slot-indexed -----------------------
        self._net_cap = 64
        self._t_rem = np.zeros(self._net_cap)
        self._t_rate = np.zeros(self._net_cap)
        self._t_src = np.zeros(self._net_cap, np.intp)
        self._t_reg = np.full(self._net_cap, -1, np.intp)
        self._t_active = np.zeros(self._net_cap, bool)
        self._t_obj: list[Optional[_Transfer]] = [None] * self._net_cap
        self._free_slots = list(range(self._net_cap - 1, -1, -1))
        self._nic_members: list[set[int]] = [set() for _ in topology.sites]
        self._wan_members: list[set[int]] = [set() for _ in topology.wan_links]
        self._nic_bw = np.array([l.bandwidth for l in topology.nic_links])
        self._wan_bw = np.array([l.bandwidth for l in topology.wan_links])
        # numpy mirrors of Link.active (simulator is the only writer); small
        # integer counts, so the float64 mirror is exact
        self._nic_act = np.array([float(l.active) for l in topology.nic_links])
        self._wan_act = np.array([float(l.active) for l in topology.wan_links])
        # per-site CPU: FIFO queue of ready jobs + the running job. Cancelled
        # jobs stay queued as tombstones (done=True) and are skipped on pop.
        self._cpu_queue: dict[int, collections.deque[_JobState]] = {
            s.site_id: collections.deque() for s in topology.sites
        }
        self._running: dict[int, Optional[_JobState]] = {
            s.site_id: None for s in topology.sites
        }
        self._cpu_version: dict[int, int] = {s.site_id: 0 for s in topology.sites}
        self._cpu_last_update: dict[int, float] = {s.site_id: 0.0 for s in topology.sites}
        # ordered set (insertion-ordered dict) -> O(1) membership + removal
        self._site_jobs: dict[int, dict[_JobState, None]] = {
            s.site_id: {} for s in topology.sites
        }

        self.records: list[JobRecord] = []
        self._inter_comms: dict[int, int] = {}
        self._wan_bytes: dict[int, float] = {}
        self._resubmits: dict[int, int] = {}
        self.total_wan_bytes = 0.0
        self.total_lan_bytes = 0.0
        self._n_expected = 0

    # -- event plumbing ----------------------------------------------------
    def _push(self, t: float, kind: int, payload: object) -> None:
        self._seq += 1
        heapq.heappush(self._q, (t, self._seq, kind, payload))

    def submit_job(self, job: Job, at: float) -> None:
        self._n_expected += 1
        job.submit_time = at
        self._push(at, SUBMIT, job)

    def _check_site(self, site: int) -> None:
        if not 0 <= site < len(self.topology.sites):
            raise ValueError(
                f"site {site} out of range (topology has "
                f"{len(self.topology.sites)} sites)")

    def inject_failure(self, site: int, at: float, duration: float) -> None:
        self._check_site(site)
        self._push(at, FAIL, site)
        self._push(at + duration, RECOVER, site)

    def inject_slowdown(self, site: int, at: float, duration: float,
                        factor: float = 0.1) -> None:
        self._check_site(site)
        self._push(at, SLOW_START, (site, factor))
        self._push(at + duration, SLOW_END, (site, factor))

    # -- network -----------------------------------------------------------
    #
    # The fluid model: remaining bytes drain at `rate` = min over the
    # transfer's links of bandwidth/active. `_net_advance` integrates all
    # active transfers to `now`; `_net_rerate` refreshes the rates of the
    # transfers named by the changed links and schedules the next completion
    # wake-up (versioned: a stale NET event is a no-op).
    def _slot_alloc(self, tr: _Transfer, size: float) -> None:
        if not self._free_slots:
            old = self._net_cap
            self._net_cap = old * 2
            self._t_rem = np.concatenate([self._t_rem, np.zeros(old)])
            self._t_rate = np.concatenate([self._t_rate, np.zeros(old)])
            self._t_src = np.concatenate([self._t_src, np.zeros(old, np.intp)])
            self._t_reg = np.concatenate([self._t_reg, np.full(old, -1, np.intp)])
            self._t_active = np.concatenate([self._t_active,
                                             np.zeros(old, bool)])
            self._t_obj.extend([None] * old)
            self._free_slots.extend(range(self._net_cap - 1, old - 1, -1))
        slot = self._free_slots.pop()
        tr.slot = slot
        src = tr.plan.src
        # an inter-region transfer traverses [nic, uplink] (see links_for);
        # ``reg`` is the uplink's index into topology.wan_links (== the
        # source region id on two-level trees, a deeper uplink otherwise)
        reg = self.topology.uplink_index(src, tr.plan.dst) if len(tr.links) > 1 else -1
        self._t_rem[slot] = size
        self._t_rate[slot] = 0.0
        self._t_src[slot] = src
        self._t_reg[slot] = reg
        self._t_active[slot] = True
        self._t_obj[slot] = tr
        self._nic_members[src].add(slot)
        self._nic_act[src] += 1.0
        if reg >= 0:
            self._wan_members[reg].add(slot)
            self._wan_act[reg] += 1.0

    def _slot_release(self, tr: _Transfer) -> None:
        slot = tr.slot
        src, reg = int(self._t_src[slot]), int(self._t_reg[slot])
        self._t_active[slot] = False
        self._t_rate[slot] = 0.0
        self._t_rem[slot] = 0.0
        self._t_obj[slot] = None
        self._nic_members[src].discard(slot)
        self._nic_act[src] -= 1.0
        if reg >= 0:
            self._wan_members[reg].discard(slot)
            self._wan_act[reg] -= 1.0
        self._free_slots.append(slot)
        tr.slot = -1

    def _net_advance(self) -> None:
        dt = self.now - self._net_last
        if dt > 0:
            np.maximum(self._t_rem - self._t_rate * dt, 0.0, out=self._t_rem)
        self._net_last = self.now

    def _rate_slots(self, slots: set[int]) -> None:
        """Recompute rate = min over links of bandwidth/active for ``slots``.
        Pure function of current link occupancy, so re-rating a slot twice
        (a transfer can sit in both a changed NIC and a changed WAN group)
        is harmless."""
        n = len(slots)
        if n == 0:
            return
        if n <= 4:      # numpy call overhead dominates tiny groups
            for sl in slots:
                src, reg = self._t_src[sl], self._t_reg[sl]
                r = self._nic_bw[src] / max(1.0, self._nic_act[src])
                if reg >= 0:
                    r = min(r, self._wan_bw[reg] / max(1.0, self._wan_act[reg]))
                self._t_rate[sl] = r
            return
        idx = np.fromiter(slots, np.intp, n)
        src = self._t_src[idx]
        rate = self._nic_bw[src] / np.maximum(1.0, self._nic_act[src])
        reg = self._t_reg[idx]
        m = reg >= 0
        if m.any():
            wr = reg[m]
            rate[m] = np.minimum(
                rate[m], self._wan_bw[wr] / np.maximum(1.0, self._wan_act[wr]))
        self._t_rate[idx] = rate

    def _net_rerate(self, sites: tuple[int, ...] = (),
                    regions: tuple[int, ...] = ()) -> None:
        for s in sites:
            self._rate_slots(self._nic_members[s])
        for r in regions:
            self._rate_slots(self._wan_members[r])
        self._net_version += 1
        if self._transfers:
            live = self._t_rate > 0.0   # released slots are zeroed, so live ⊆ active
            if live.any():
                nxt = float(np.min(self.now
                                   + self._t_rem[live] / self._t_rate[live]))
                self._push(nxt, NET, self._net_version)

    def _start_transfer(self, plan: FetchPlan, js: _JobState) -> None:
        key = (plan.dst, plan.lfn)
        if key in self._inflight and self._inflight[key].plan.store:
            # another job at this site is already fetching it; piggyback
            self._inflight[key].waiters.append(js)
            return
        self._net_advance()
        size = self.catalog.size(plan.lfn)
        links = self.topology.links_for(plan.src, plan.dst)
        for l in links:
            l.active += 1
        # evictions + space reservation happen at transfer start
        if plan.store:
            for victim in plan.evictions:
                self.storage.remove(plan.dst, victim)
            self.topology.sites[plan.dst].used_storage += size  # reserve
        self.storage.pin(plan.src, plan.lfn)   # source can't be evicted mid-copy
        self._tid += 1
        tr = _Transfer(self._tid, plan, links, waiters=[js])
        self._transfers[tr.tid] = tr
        self._slot_alloc(tr, size)
        if plan.store:
            self._inflight[key] = tr
        if plan.inter_region:
            self._inter_comms[js.job.job_id] = self._inter_comms.get(js.job.job_id, 0) + 1
            self._wan_bytes[js.job.job_id] = self._wan_bytes.get(js.job.job_id, 0.0) + size
            self.total_wan_bytes += size
        else:
            self.total_lan_bytes += size
        reg = int(self._t_reg[tr.slot])
        self._net_rerate((plan.src,), (reg,) if reg >= 0 else ())

    def _finish_transfer(self, tr: _Transfer) -> None:
        plan = tr.plan
        self._transfers.pop(tr.tid, None)
        self._inflight.pop((plan.dst, plan.lfn), None)
        src_site, reg = int(self._t_src[tr.slot]), int(self._t_reg[tr.slot])
        self._slot_release(tr)
        for l in tr.links:
            l.active -= 1
        self.storage.unpin(plan.src, plan.lfn)
        self.storage.touch(plan.src, plan.lfn, self.now)
        if plan.store:
            # un-reserve, then commit properly through StorageState
            self.topology.sites[plan.dst].used_storage -= self.catalog.size(plan.lfn)
            self.storage.add(plan.dst, plan.lfn, self.now)
        for js in tr.waiters:
            if js.done:
                continue
            if plan.store:
                if js.pin_on_arrival:
                    self.storage.pin(plan.dst, plan.lfn)
                    js.pinned.append(plan.lfn)
            else:
                js.temp_files.append(plan.lfn)
            js.pending_transfers -= 1
            self._fetch_next(js)
        self._net_rerate((src_site,), (reg,) if reg >= 0 else ())

    def _abort_transfers_touching(self, site: int) -> None:
        """Failure handling: drop transfers with src or dst at a failed site."""
        self._net_advance()
        dead = [t for t in self._transfers.values()
                if t.plan.src == site or t.plan.dst == site]
        sites_ch: set[int] = set()
        regs_ch: set[int] = set()
        for tr in dead:
            self._transfers.pop(tr.tid, None)
            self._inflight.pop((tr.plan.dst, tr.plan.lfn), None)
            sites_ch.add(int(self._t_src[tr.slot]))
            reg = int(self._t_reg[tr.slot])
            if reg >= 0:
                regs_ch.add(reg)
            self._slot_release(tr)
            for l in tr.links:
                l.active -= 1
            if self.topology.sites[tr.plan.src].online or \
               self.catalog.has_replica(tr.plan.lfn, tr.plan.src):
                self.storage.unpin(tr.plan.src, tr.plan.lfn)
            if tr.plan.store:
                self.topology.sites[tr.plan.dst].used_storage -= \
                    self.catalog.size(tr.plan.lfn)
            for js in tr.waiters:
                if js.done or js.site == site:
                    continue  # jobs at the failed site are resubmitted anyway
                # replan this file from surviving replicas
                js.missing.insert(0, tr.plan.lfn)
                js.pending_transfers -= 1
                self._fetch_next(js)
        self._net_rerate(tuple(sites_ch), tuple(regs_ch))

    # -- job lifecycle -----------------------------------------------------
    #
    # Staging semantics: replicas are pinned only while a job is *running*
    # (processing). Queued jobs do not pin — with deep queues, schedule-time
    # pinning would freeze every SE solid and no strategy could ever evict.
    # A job re-verifies its working set when it reaches the CE; anything
    # evicted in the meantime is re-staged (another round). After 3 rounds
    # the job pins files as they arrive (anti-livelock escalation).
    def _schedule(self, job: Job) -> None:
        self._place(job, self.scheduler.select_site(job))

    def _place(self, job: Job, site: int) -> None:
        js = _JobState(job=job, site=site, remaining_ops=job.length)
        self._site_jobs[site][js] = None
        self.topology.sites[site].queued_work += job.length
        js.missing = [l for l in job.required if not self.storage.holds(site, l)]
        for lfn in job.required:
            self.storage.touch(site, lfn, self.now)
        self._fetch_next(js)

    def _drain_submit_batch(self, first: Job) -> list[Job]:
        """Batch broker: pull every SUBMIT event sharing this timestamp off
        the head of the heap (stopping at any other event kind, which
        preserves causality with failures/completions)."""
        batch = [first]
        q = self._q
        while q and q[0][0] <= self.now and q[0][2] == SUBMIT:
            batch.append(heapq.heappop(q)[3])  # type: ignore[arg-type]
        return batch

    def _dispatch_batch(self, batch: list[Job]) -> None:
        if len(batch) == 1:
            self._schedule(batch[0])
            return
        assert self._jax_broker is not None
        sites = self._jax_broker.select_batch([j.required for j in batch])
        for job, site in zip(batch, sites):
            self._place(job, site)

    def _fetch_next(self, js: _JobState) -> None:
        """Files are accessed sequentially within a job (paper §4.1): one
        transfer in flight per job."""
        if js.done:
            return
        while js.missing:
            lfn = js.missing.pop(0)
            if self.storage.holds(js.site, lfn):
                self.storage.touch(js.site, lfn, self.now)
                continue
            plan = self.strategy.plan_fetch(lfn, js.site)
            js.pending_transfers += 1
            self._start_transfer(plan, js)
            return
        if js.pending_transfers == 0:
            if js.data_ready_time < 0:
                js.data_ready_time = self.now
            self._enqueue_cpu(js)

    def _working_set_missing(self, js: _JobState) -> list[str]:
        return [f for f in js.job.required
                if f not in js.temp_files and not self.storage.holds(js.site, f)]

    def _enqueue_cpu(self, js: _JobState) -> None:
        self._cpu_queue[js.site].append(js)
        self._maybe_start_cpu(js.site)

    def _cpu_advance(self, site: int) -> None:
        run = self._running[site]
        if run is not None:
            dt = self.now - self._cpu_last_update[site]
            run.remaining_ops = max(
                0.0, run.remaining_ops - dt * self.topology.sites[site].compute_capacity
            )
        self._cpu_last_update[site] = self.now

    def _maybe_start_cpu(self, site: int) -> None:
        if self._running[site] is not None or not self.topology.sites[site].online:
            return
        q = self._cpu_queue[site]
        while q:
            js = q.popleft()
            if js.done:
                continue
            missing = self._working_set_missing(js)
            if missing:
                # part of the staged set was evicted while queued: re-stage
                js.rounds += 1
                if js.rounds >= 3:
                    js.pin_on_arrival = True
                js.missing = missing
                self._fetch_next(js)
                continue
            # pin the working set for the duration of processing
            for f in js.job.required:
                if self.storage.holds(site, f) and f not in js.pinned:
                    self.storage.pin(site, f)
                    js.pinned.append(f)
                self.storage.touch(site, f, self.now)
            js.start_time = self.now
            self._running[site] = js
            self._cpu_last_update[site] = self.now
            self._reschedule_cpu(site)
            if self.speculative_backups and not js.is_backup and js.twin is None:
                expected = js.job.length / self.topology.sites[site].compute_capacity
                self._push(self.now + self.straggler_threshold * expected, WATCHDOG, js)
            return

    def _reschedule_cpu(self, site: int) -> None:
        js = self._running[site]
        if js is None:
            return
        self._cpu_version[site] += 1
        cap = self.topology.sites[site].compute_capacity
        eta = self.now + js.remaining_ops / cap
        self._push(eta, CPU_DONE, (site, self._cpu_version[site]))

    def _finish_job(self, js: _JobState) -> None:
        js.done = True
        site = js.site
        self.topology.sites[site].queued_work -= js.job.length
        for lfn in js.pinned:
            self.storage.unpin(site, lfn)
        js.temp_files.clear()   # paper: temp buffer dropped after job completes
        self._site_jobs[site].pop(js, None)
        twin = js.twin
        if twin is not None and not twin.done:
            self._cancel_job(twin)
        jid = js.job.job_id
        self.records.append(JobRecord(
            job_id=jid, job_type=js.job.job_type, site=site,
            submit_time=js.job.submit_time, data_ready_time=js.data_ready_time,
            start_time=js.start_time, finish_time=self.now,
            inter_comms=self._inter_comms.get(jid, 0),
            wan_bytes=self._wan_bytes.get(jid, 0.0),
            resubmits=self._resubmits.get(jid, 0),
        ))

    def _cancel_job(self, js: _JobState) -> None:
        js.done = True       # tombstone: a queued copy is skipped on pop
        site = js.site
        self.topology.sites[site].queued_work -= js.job.length
        for lfn in js.pinned:
            self.storage.unpin(site, lfn)
        js.temp_files.clear()
        if self._running[site] is js:
            self._cpu_advance(site)
            self._running[site] = None
            self._cpu_version[site] += 1
            self._maybe_start_cpu(site)
        self._site_jobs[site].pop(js, None)

    # -- failures / stragglers ----------------------------------------------
    def _fail_site(self, site: int) -> None:
        st = self.topology.sites[site]
        if not st.online:
            return
        self._cpu_advance(site)
        st.online = False
        self._abort_transfers_touching(site)
        # lose non-master replicas (the SE is gone); masters are durable
        for lfn in self.storage.site_contents(site):
            if not self.catalog.is_master(lfn, site):
                self.storage.lose(site, lfn)
        # resubmit every job that was at this site
        victims = list(self._site_jobs[site])
        self._site_jobs[site].clear()
        self._cpu_queue[site].clear()
        self._running[site] = None
        self._cpu_version[site] += 1
        for js in victims:
            if js.done:
                continue
            js.done = True
            st.queued_work -= js.job.length
            jid = js.job.job_id
            if js.twin is not None and not js.twin.done:
                continue  # its twin survives; no resubmission needed
            self._resubmits[jid] = self._resubmits.get(jid, 0) + 1
            self._push(self.now, SUBMIT, js.job)
            self._n_expected += 0  # same job id, record count unchanged

    def _recover_site(self, site: int) -> None:
        self.topology.sites[site].online = True
        self._maybe_start_cpu(site)

    def _watchdog(self, js: _JobState) -> None:
        """Speculative backup: if js still running past threshold, clone it."""
        if js.done or self._running[js.site] is not js:
            return
        job = js.job
        backup_site = self.scheduler.select_site(job)
        if backup_site == js.site:
            candidates = [s for s in self.topology.online_sites() if s != js.site]
            if not candidates:
                return
            backup_site = min(
                candidates, key=lambda s: (self.topology.sites[s].relative_load(), s))
        twin = _JobState(job=job, site=backup_site, is_backup=True,
                         remaining_ops=job.length)
        twin.twin = js
        js.twin = twin
        self._site_jobs[backup_site][twin] = None
        self.topology.sites[backup_site].queued_work += job.length
        twin.missing = [l for l in job.required
                        if not self.storage.holds(backup_site, l)]
        self._fetch_next(twin)

    # -- main loop -----------------------------------------------------------
    def run(self, until: float = float("inf")) -> SimResult:
        self._net_last = 0.0
        while self._q:
            t, _, kind, payload = heapq.heappop(self._q)
            if t > until:
                break
            self.now = t
            if kind == SUBMIT:
                # submit_time was stamped at first submission; resubmitted
                # jobs (failures) keep it so job_time spans the whole outage.
                if self._jax_broker is None:
                    self._schedule(payload)  # type: ignore[arg-type]
                elif self.batch_window > 0:
                    # collect; dispatch together once the window closes
                    # (batching adds latency — it never violates causality)
                    self._batch_buf.append(payload)  # type: ignore[arg-type]
                    if not self._flush_pending:
                        self._flush_pending = True
                        self._push(t + self.batch_window, FLUSH, None)
                else:
                    self._dispatch_batch(self._drain_submit_batch(payload))  # type: ignore[arg-type]
            elif kind == FLUSH:
                self._flush_pending = False
                batch, self._batch_buf = self._batch_buf, []
                if batch:
                    self._dispatch_batch(batch)
            elif kind == NET:
                if payload != self._net_version:
                    continue
                self._net_advance()
                done_idx = np.nonzero(self._t_active
                                      & (self._t_rem <= _DONE_EPS))[0]
                if done_idx.size:
                    done = sorted((self._t_obj[i] for i in done_idx),
                                  key=lambda tr: tr.tid)
                    for tr in done:
                        self._finish_transfer(tr)
                else:
                    self._net_rerate()
            elif kind == CPU_DONE:
                site, ver = payload  # type: ignore[misc]
                if ver != self._cpu_version[site]:
                    continue
                self._cpu_advance(site)
                js = self._running[site]
                if js is None:
                    continue
                self._running[site] = None
                self._finish_job(js)
                self._maybe_start_cpu(site)
            elif kind == FAIL:
                self._fail_site(payload)  # type: ignore[arg-type]
            elif kind == RECOVER:
                self._recover_site(payload)  # type: ignore[arg-type]
            elif kind == SLOW_START:
                site, factor = payload  # type: ignore[misc]
                self._cpu_advance(site)
                self.topology.sites[site].compute_capacity *= factor
                self._reschedule_cpu(site)
            elif kind == SLOW_END:
                site, factor = payload  # type: ignore[misc]
                self._cpu_advance(site)
                self.topology.sites[site].compute_capacity /= factor
                self._reschedule_cpu(site)
            elif kind == WATCHDOG:
                self._watchdog(payload)  # type: ignore[arg-type]
        total_ic = sum(r.inter_comms for r in self.records)
        return SimResult(
            records=self.records,
            total_inter_comms=total_ic,
            total_wan_bytes=self.total_wan_bytes,
            total_lan_bytes=self.total_lan_bytes,
            makespan=self.now,
        )
