"""Discrete-event data-grid simulator (the paper's GridSim analogue, §4).

Implements the full job lifecycle of the paper:

  submit -> broker schedules (policy) -> site queue -> replica manager fetches
  missing files (strategy) -> job processes when data ready AND CE free ->
  done.  Job time = max(transfer time, queue time) + processing time, which is
  what the event ordering below produces naturally.

Network: event-driven fair-share links with re-rating (each transfer's rate
is the min over *every* link it crosses of bandwidth/active — the full
source-side uplink path, so mid-tier congestion is real on deep trees).
This reproduces GridSim's contention behaviour — the WAN uplink saturates
under inter-region traffic — without a packet simulator. The fluid model
lives in :class:`repro.core.network.NetworkEngine`; the ``net=`` flag picks
its backend (``"numpy"`` incremental re-rating, ``"pallas"`` the vectorized
kernel path, ``"topmost"`` the legacy single-uplink accounting).

Engine hot paths are built for 100k-job / 500-site scale (the ``grid_500``
scenario is the pinned scale point):
  * transfer state (remaining bytes, rate, link-path membership) lives in
    slot-indexed numpy arrays inside the NetworkEngine; advancing the fluid
    model and scanning for the next completion are vectorized instead of
    per-transfer Python loops;
  * re-rating is incremental: only transfers sharing a link whose membership
    changed are re-rated, as one union batch per event (rates are pure
    functions of link occupancy, so this is exactly equivalent to a full
    recompute — bit-identical results);
  * CPU queues are deques and site-job sets are ordered dicts with O(1)
    removal; cancelled jobs tombstone in place (``done`` flag) and are
    skipped when popped, never removed by O(n) scans.
  * optionally, scheduling decisions are dispatched in jitted batches via
    ``repro.core.jaxsched`` (``broker="jax"``): simultaneous SUBMIT events
    (burst arrivals) are placed with one vectorized argmax over a shared
    catalog/load snapshot — the presence bitmap behind it is maintained
    incrementally through catalog change listeners, never rebuilt per
    batch, and the shortest-transfer variant costs batches through the
    blocked ``repro.kernels.st_cost`` pass over the engine-shared
    point-bandwidth snapshot; with ``batch_window`` > 0 arrivals are held
    up to that many seconds and flushed as one batch (batching adds
    latency, never causality violations). The default ``broker="event"``
    keeps the paper-exact sequential semantics.

Beyond the paper (fault-tolerance axis of this framework):
  * site failure/recovery events — non-master replicas lost, queued jobs
    resubmitted through the broker, in-flight transfers replanned;
  * straggler (slowdown) events with speculative backup jobs;
  * all deterministic under a seed.
"""

from __future__ import annotations

import collections
import copy
import dataclasses
import heapq
import os
import random as _random
from typing import Optional

from ..obs import DEFAULT_OBS_INTERVAL_S, OBS_MODES, make_probe
from .access import AccessHistory
from .catalog import ReplicaCatalog
from .economy import DEFAULT_INTERVAL_S, ECON_BACKENDS, ReplicationOptimizer
from .network import BACKENDS, NetworkEngine
from .replica import FetchPlan, ReplicaStrategy, StorageState, make_strategy
from .scheduler import Job, SchedulerPolicy, make_scheduler
from .topology import GridTopology


# --------------------------------------------------------------------------
# events
# --------------------------------------------------------------------------
(SUBMIT, NET, CPU_DONE, FAIL, RECOVER, SLOW_START, SLOW_END, WATCHDOG,
 FLUSH, ECON, OBS) = range(11)

EVENT_NAMES = ("SUBMIT", "NET", "CPU_DONE", "FAIL", "RECOVER", "SLOW_START",
               "SLOW_END", "WATCHDOG", "FLUSH", "ECON", "OBS")

#: Host-phase span charged for each handled event kind (telemetry only;
#: ``None`` kinds are counted but not timed — they are rare control
#: events). Nested spans (strategy planning inside a dispatch, a fused
#: flush inside a NET completion) subtract out via the probe's
#: exclusive-time accounting.
_EVENT_PHASE = ("broker.dispatch", "net.events", "cpu.done", None, None,
                None, None, None, "broker.dispatch", "econ.auction",
                "obs.sample")

#: Values the ``net=`` engine flag accepts: NetworkEngine backends plus
#: ``"topmost"``, which keeps the numpy backend over a topology built with
#: the legacy topmost-uplink accounting (fidelity baseline for benchmarks).
NETS = BACKENDS + ("topmost",)


@dataclasses.dataclass(eq=False)
class _Transfer:
    tid: int
    plan: FetchPlan
    link_ids: tuple[int, ...]    # full source-side path, unified link space
    slot: int = -1
    waiters: list["_JobState"] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(eq=False)
class _JobState:
    job: Job
    site: int = -1
    missing: list[str] = dataclasses.field(default_factory=list)
    pending_transfers: int = 0
    temp_files: list[str] = dataclasses.field(default_factory=list)
    pinned: list[str] = dataclasses.field(default_factory=list)
    data_ready_time: float = -1.0
    start_time: float = -1.0
    done: bool = False
    is_backup: bool = False
    twin: Optional["_JobState"] = None   # speculative copy, if any
    remaining_ops: float = 0.0
    rounds: int = 0                      # staging rounds (re-fetch after eviction)
    pin_on_arrival: bool = False         # anti-livelock escalation
    # burst-planned fetches awaiting execution (strategy_mode="batch"):
    # one FetchPlan per still-missing file, consumed by _fetch_next
    plan_cache: dict[str, "FetchPlan"] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class JobRecord:
    job_id: int
    job_type: int
    site: int
    submit_time: float
    data_ready_time: float
    start_time: float
    finish_time: float
    inter_comms: int
    wan_bytes: float
    resubmits: int = 0

    @property
    def job_time(self) -> float:
        return self.finish_time - self.submit_time


@dataclasses.dataclass(frozen=True)
class TieRace:
    """One same-timestamp event group whose handler order changes
    observable state (found by the ``sanitize=True`` engine mode)."""

    time: float
    kinds: tuple[str, ...]       # event kinds in the tie group, seq order
    detail: str                  # first state divergence, human-readable


@dataclasses.dataclass
class SimResult:
    records: list[JobRecord]
    total_inter_comms: int
    total_wan_bytes: float
    total_lan_bytes: float
    makespan: float
    # engine-internal counters surfaced per run (PR 9): the NetworkEngine's
    # kernel stats (rerate_calls/rerate_slots/flush_passes/flush_slots) and
    # the AccessHistory prefetch ledger — always populated, obs or not.
    net_stats: dict = dataclasses.field(default_factory=dict)
    prefetches: int = 0
    prefetch_bytes: float = 0.0
    #: :class:`repro.obs.TelemetryReport` when an ``obs=`` mode is on.
    telemetry: Optional[object] = None

    @property
    def avg_job_time(self) -> float:
        return sum(r.job_time for r in self.records) / max(1, len(self.records))

    @property
    def avg_inter_comms(self) -> float:
        return self.total_inter_comms / max(1, len(self.records))


class GridSimulator:
    def __init__(
        self,
        topology: GridTopology,
        catalog: ReplicaCatalog,
        *,
        scheduler: str | SchedulerPolicy = "dataaware",
        strategy: str | ReplicaStrategy = "hrs",
        strategy_mode: str = "sequential",
        seed: int = 0,
        speculative_backups: bool = False,
        straggler_threshold: float = 3.0,
        broker: str = "event",
        batch_window: float = 0.0,
        net: str = "numpy",
        econ: str = "numpy",
        econ_interval: Optional[float] = None,
        obs: Optional[str] = None,
        obs_interval: Optional[float] = None,
        sanitize: bool = False,
    ) -> None:
        self.topology = topology
        self.catalog = catalog
        self.storage = StorageState(catalog, topology)
        self.scheduler = (
            scheduler if isinstance(scheduler, SchedulerPolicy)
            else make_scheduler(scheduler, catalog, topology, seed=seed)
        )
        if net not in NETS:
            raise ValueError(f"unknown net engine {net!r} (want one of {NETS})")
        if net == "topmost":
            # legacy model: contend only on the topmost crossed uplink.
            # Path construction is owned by the topology (it covers the
            # engine, Link.active accounting and point_bandwidth alike),
            # so the topology must have been *built* that way — mutating
            # the caller's topology here would silently corrupt any other
            # simulator sharing it. run_experiment(net="topmost") builds
            # the right topology automatically.
            if topology.path_model != "topmost":
                raise ValueError(
                    "net='topmost' requires a topology built with "
                    "path_model='topmost' (GridTopology(..., "
                    "path_model='topmost'), or run_experiment(net="
                    "'topmost') which does this for you)")
            net = "numpy"
        # the network engine is built before the strategy: the batched
        # planners (strategy_mode="batch") read their per-burst bandwidth
        # columns from its shared link state
        self.network = NetworkEngine(topology, backend=net)
        # access history: pure observation, fed from the fetch/hit path
        # below. Shared with the strategy (the access-aware ones consult
        # it) and the replication economy (which acts on it).
        if isinstance(strategy, ReplicaStrategy):
            if strategy_mode != "sequential":
                raise ValueError(
                    "strategy_mode applies to strategies built by name; "
                    "pass the registry name instead of an instance")
            self.strategy = strategy
            if strategy.access is not None:
                self.access = strategy.access   # adopt: one shared history
            else:
                self.access = AccessHistory(catalog, topology)
                strategy.access = self.access
        else:
            self.access = AccessHistory(catalog, topology)
            self.strategy = make_strategy(strategy, catalog, topology,
                                          self.storage, self.access,
                                          mode=strategy_mode,
                                          network=self.network)
        # batched planners consume whole arrival bursts (`_batch_fetch`)
        # and cache an online-site vector the failure paths invalidate
        self._batched_strategy = getattr(self.strategy, "batched", False)
        self.rng = _random.Random(seed)
        self.speculative_backups = speculative_backups
        self.straggler_threshold = straggler_threshold
        self.batch_window = batch_window
        # -- replication economy (proactive, periodic; off by default) ----
        # econ_interval=None means "auto": the strategies that declare
        # uses_economy arm the optimizer at the default period, everything
        # else runs exactly the reactive paper pipeline (no ECON events at
        # all — the golden HRS/BHR/LRU histories are untouched). An
        # explicit interval > 0 forces the optimizer on for any strategy.
        if econ not in ECON_BACKENDS:
            raise ValueError(f"unknown econ backend {econ!r} "
                             f"(want one of {ECON_BACKENDS})")
        if econ_interval is None:
            econ_interval = (DEFAULT_INTERVAL_S
                             if self.strategy.uses_economy else 0.0)
        self._econ_interval = econ_interval
        if econ_interval > 0:
            self._econ = ReplicationOptimizer(
                catalog, topology, self.storage, self.access, self.network,
                model=self.strategy.econ_model, backend=econ)
        else:
            self._econ = None
        self._econ_armed = False
        # -- telemetry (repro.obs; off by default) ------------------------
        # obs=None defers to the REPRO_OBS env override so existing entry
        # points (the golden suites included) can run unchanged with
        # telemetry forced on — the observation-only proof in CI. With
        # obs off, self._obs is None and every hot-path guard below is a
        # single `is None` check.
        if obs is None:
            obs = os.environ.get("REPRO_OBS", "off")
        if obs not in OBS_MODES:
            raise ValueError(f"unknown obs mode {obs!r} "
                             f"(want one of {OBS_MODES})")
        self._obs = make_probe(obs)
        self._obs_interval = (DEFAULT_OBS_INTERVAL_S if obs_interval is None
                              else obs_interval)
        self._obs_armed = False
        # time of the last handled *non-OBS* event: the makespan under an
        # obs mode. Trailing OBS samples advance self.now past the real
        # workload end; counting them would break observation-only.
        self._obs_real_now = 0.0
        if broker == "jax":
            # deferred imports: jaxsched pulls in jax
            if self.scheduler.name == "dataaware":
                from .jaxsched import JaxScheduler
                self._jax_broker = JaxScheduler(catalog, topology)
            elif self.scheduler.name == "shortesttransfer":
                from .jaxsched import JaxShortestTransferBroker
                self._jax_broker = JaxShortestTransferBroker(
                    catalog, topology, self.network)
            elif self.scheduler.name == "leastloaded":
                from .jaxsched import JaxLeastLoadedBroker
                self._jax_broker = JaxLeastLoadedBroker(catalog, topology)
            elif self.scheduler.name == "random":
                # share the policy's Random: single-job batches (which fall
                # back to the sequential policy) and batched dispatch then
                # consume one PRNG stream
                from .jaxsched import JaxRandomBroker
                self._jax_broker = JaxRandomBroker(catalog, topology,
                                                   self.scheduler.rng)
            else:
                raise ValueError(
                    "broker='jax' implements the 'dataaware', "
                    "'shortesttransfer', 'leastloaded' and 'random' "
                    f"policies; got scheduler {self.scheduler.name!r}")
        elif broker == "event":
            if batch_window > 0:
                raise ValueError(
                    "batch_window only applies to broker='jax' "
                    "(the event broker dispatches each SUBMIT immediately)")
            self._jax_broker = None
        else:
            raise ValueError(f"unknown broker {broker!r} (want 'event'|'jax')")
        self._batch_buf: list[Job] = []
        self._flush_pending = False

        # -- tie-race sanitizer (dev/test mode; see docs/ANALYSIS.md) ------
        # For every group of >= 2 events sharing a timestamp, a deep-copied
        # twin replays the instant with the group's order reversed and the
        # canonicalized observable states are compared. Requires the
        # sequential broker: twins deep-copy the whole engine, and the jax
        # brokers hold device buffers + catalog listeners that a twin must
        # not share (ReplicaCatalog.__deepcopy__ drops listeners). The
        # batched planners are excluded for the same reason: their
        # StorageTensorView rides both listener channels, which the
        # catalog/storage ``__deepcopy__`` contracts deliberately drop.
        if sanitize and (self._jax_broker is not None
                         or self._batched_strategy):
            raise ValueError("sanitize=True requires broker='event' and "
                             "strategy_mode='sequential' (twin replay "
                             "deep-copies the engine, dropping listeners)")
        self.sanitize = sanitize
        self.ties_seen = 0
        self.tie_races: list[TieRace] = []

        self._q: list[tuple[float, int, int, object]] = []
        self._seq = 0
        self.now = 0.0
        self._net_version = 0
        self._transfers: dict[int, _Transfer] = {}
        self._inflight: dict[tuple[int, str], _Transfer] = {}
        self._tid = 0
        # per-site CPU: FIFO queue of ready jobs + the running job. Cancelled
        # jobs stay queued as tombstones (done=True) and are skipped on pop.
        self._cpu_queue: dict[int, collections.deque[_JobState]] = {
            s.site_id: collections.deque() for s in topology.sites
        }
        self._running: dict[int, Optional[_JobState]] = {
            s.site_id: None for s in topology.sites
        }
        self._cpu_version: dict[int, int] = {s.site_id: 0 for s in topology.sites}
        self._cpu_last_update: dict[int, float] = {s.site_id: 0.0 for s in topology.sites}
        # ordered set (insertion-ordered dict) -> O(1) membership + removal
        self._site_jobs: dict[int, dict[_JobState, None]] = {
            s.site_id: {} for s in topology.sites
        }

        self.records: list[JobRecord] = []
        self._inter_comms: dict[int, int] = {}
        self._wan_bytes: dict[int, float] = {}
        self._resubmits: dict[int, int] = {}
        self.total_wan_bytes = 0.0
        self.total_lan_bytes = 0.0
        self._n_expected = 0

    # -- event plumbing ----------------------------------------------------
    def _push(self, t: float, kind: int, payload: object) -> None:
        self._seq += 1
        heapq.heappush(self._q, (t, self._seq, kind, payload))

    def submit_job(self, job: Job, at: float) -> None:
        self._n_expected += 1
        job.submit_time = at
        self._push(at, SUBMIT, job)

    def _check_site(self, site: int) -> None:
        if not 0 <= site < len(self.topology.sites):
            raise ValueError(
                f"site {site} out of range (topology has "
                f"{len(self.topology.sites)} sites)")

    def inject_failure(self, site: int, at: float, duration: float) -> None:
        self._check_site(site)
        self._push(at, FAIL, site)
        self._push(at + duration, RECOVER, site)

    def inject_slowdown(self, site: int, at: float, duration: float,
                        factor: float = 0.1) -> None:
        self._check_site(site)
        self._push(at, SLOW_START, (site, factor))
        self._push(at + duration, SLOW_END, (site, factor))

    # -- network -----------------------------------------------------------
    #
    # The fluid model lives in self.network (NetworkEngine): remaining bytes
    # drain at `rate` = min over the transfer's full link path of
    # bandwidth/active. `_net_advance` integrates all active transfers to
    # `now`; `_net_rerate` refreshes the rates of the transfers on the
    # changed links and schedules the next completion wake-up (versioned: a
    # stale NET event is a no-op).
    def _net_advance(self) -> None:
        self.network.advance(self.now)

    def _net_rerate(self, changed: tuple[int, ...] = ()) -> None:
        if self._obs is None:
            eta = self.network.rerate(changed, self.now)
        else:
            with self._obs.span("net.rerate"):
                eta = self.network.rerate(changed, self.now)
        if self.network.batched:
            # deferred: rerate only marked the engine dirty; the single
            # fused flush at the end of the drained instant re-rates and
            # reschedules the NET wake-up (`_net_flush`)
            return
        self._net_version += 1
        if eta is not None:
            self._push(eta, NET, self._net_version)

    def _net_flush(self) -> None:
        """Batched engine only: fold everything the drained instant
        changed into one fused device pass and reschedule the NET
        wake-up. No-op on the incremental backends (never dirty) and on
        clean instants."""
        net = self.network
        if not net.dirty:
            return
        if self._obs is None:
            eta = net.flush(self.now)
        else:
            with self._obs.span("net.flush"):
                eta = net.flush(self.now)
        self._net_version += 1
        if eta is not None:
            self._push(eta, NET, self._net_version)

    def _start_transfer(self, plan: FetchPlan,
                        js: Optional[_JobState]) -> None:
        """Start a transfer. ``js`` is the waiting job, or ``None`` for a
        proactive (economy-initiated) prefetch — same fluid-model slot and
        link contention either way, but prefetches have no waiter and are
        accounted as prefetch (not per-job inter-communication) traffic."""
        key = (plan.dst, plan.lfn)
        if js is not None and key in self._inflight \
                and self._inflight[key].plan.store:
            # another job at this site is already fetching it; piggyback
            self._inflight[key].waiters.append(js)
            return
        self._net_advance()
        size = self.catalog.size(plan.lfn)
        link_ids = self.topology.link_ids_for(plan.src, plan.dst)
        # evictions + space reservation happen at transfer start
        if plan.store:
            if plan.evictions and self._obs is not None:
                self._obs.count("evict.transfers")
                self._obs.count("evict.victims", len(plan.evictions))
                with self._obs.span("evict.apply"):
                    for victim in plan.evictions:
                        self.storage.remove(plan.dst, victim)
            else:
                for victim in plan.evictions:
                    self.storage.remove(plan.dst, victim)
            self.topology.sites[plan.dst].used_storage += size  # reserve
        self.storage.pin(plan.src, plan.lfn)   # source can't be evicted mid-copy
        self._tid += 1
        tr = _Transfer(self._tid, plan, link_ids,
                       waiters=[] if js is None else [js])
        self._transfers[tr.tid] = tr
        self.network.alloc(tr, size, link_ids)
        if plan.store:
            self._inflight[key] = tr
        if plan.inter_region:
            if js is not None:
                self._inter_comms[js.job.job_id] = self._inter_comms.get(js.job.job_id, 0) + 1
                self._wan_bytes[js.job.job_id] = self._wan_bytes.get(js.job.job_id, 0.0) + size
            self.total_wan_bytes += size
        else:
            self.total_lan_bytes += size
        if js is None:
            self.access.record_prefetch(plan.src, plan.dst, plan.lfn, size,
                                        self.now)
        else:
            self.access.record_fetch(plan.src, plan.dst, plan.lfn, size,
                                     plan.inter_region, self.now)
        self._net_rerate(link_ids)

    def _finish_transfer(self, tr: _Transfer) -> None:
        plan = tr.plan
        self._transfers.pop(tr.tid, None)
        self._inflight.pop((plan.dst, plan.lfn), None)
        link_ids = self.network.release(tr)
        self.storage.unpin(plan.src, plan.lfn)
        self.storage.touch(plan.src, plan.lfn, self.now)
        if plan.store:
            # un-reserve, then commit properly through StorageState
            self.topology.sites[plan.dst].used_storage -= self.catalog.size(plan.lfn)
            self.storage.add(plan.dst, plan.lfn, self.now)
        for js in tr.waiters:
            if js.done:
                continue
            if plan.store:
                if js.pin_on_arrival:
                    self.storage.pin(plan.dst, plan.lfn)
                    js.pinned.append(plan.lfn)
            else:
                js.temp_files.append(plan.lfn)
            js.pending_transfers -= 1
            self._fetch_next(js)
        self._net_rerate(link_ids)

    def _abort_transfers_touching(self, site: int) -> None:
        """Failure handling: drop transfers with src or dst at a failed site."""
        self._net_advance()
        dead = [t for t in self._transfers.values()
                if t.plan.src == site or t.plan.dst == site]
        changed: set[int] = set()
        for tr in dead:
            self._transfers.pop(tr.tid, None)
            self._inflight.pop((tr.plan.dst, tr.plan.lfn), None)
            changed.update(self.network.release(tr))
            if self.topology.sites[tr.plan.src].online or \
               self.catalog.has_replica(tr.plan.lfn, tr.plan.src):
                self.storage.unpin(tr.plan.src, tr.plan.lfn)
            if tr.plan.store:
                self.topology.sites[tr.plan.dst].used_storage -= \
                    self.catalog.size(tr.plan.lfn)
            for js in tr.waiters:
                if js.done or js.site == site:
                    continue  # jobs at the failed site are resubmitted anyway
                # replan this file from surviving replicas
                js.missing.insert(0, tr.plan.lfn)
                js.pending_transfers -= 1
                self._fetch_next(js)
        self._net_rerate(tuple(sorted(changed)))

    # -- job lifecycle -----------------------------------------------------
    #
    # Staging semantics: replicas are pinned only while a job is *running*
    # (processing). Queued jobs do not pin — with deep queues, schedule-time
    # pinning would freeze every SE solid and no strategy could ever evict.
    # A job re-verifies its working set when it reaches the CE; anything
    # evicted in the meantime is re-staged (another round). After 3 rounds
    # the job pins files as they arrive (anti-livelock escalation).
    def _schedule(self, job: Job) -> None:
        self._place(job, self.scheduler.select_site(job))

    def _place(self, job: Job, site: int, *,
               defer_fetch: bool = False) -> _JobState:
        js = _JobState(job=job, site=site, remaining_ops=job.length)
        self._site_jobs[site][js] = None
        self.topology.sites[site].queued_work += job.length
        js.missing = [l for l in job.required if not self.storage.holds(site, l)]
        for lfn in job.required:
            self.storage.touch(site, lfn, self.now)
            # demand signal for the access-aware strategies / economy:
            # one access per required file at placement, a hit when it
            # resolved from the site's own SE (pure observation — no
            # catalog/storage state changes)
            self.access.record_access(site, lfn, self.now)
            if lfn not in js.missing:
                self.access.record_hit(site, lfn, self.now)
        if not defer_fetch:
            self._fetch_next(js)
        return js

    def _drain_submit_batch(self, first: Job) -> list[Job]:
        """Batch broker: pull every SUBMIT event sharing this timestamp off
        the head of the heap (stopping at any other event kind, which
        preserves causality with failures/completions)."""
        batch = [first]
        q = self._q
        while q and q[0][0] <= self.now and q[0][2] == SUBMIT:
            batch.append(heapq.heappop(q)[3])  # type: ignore[arg-type]
        return batch

    def _dispatch_batch(self, batch: list[Job]) -> None:
        if len(batch) == 1:
            self._schedule(batch[0])
            return
        assert self._jax_broker is not None
        obs = self._obs
        if obs is None:
            sites = self._jax_broker.select_batch([j.required for j in batch])
        else:
            obs.count("broker.batches")
            obs.count("broker.batch_jobs", len(batch))
            with obs.span("broker.select_batch"):
                sites = self._jax_broker.select_batch(
                    [j.required for j in batch])
        if self._batched_strategy:
            # burst-level plan consumption: place everything first, then
            # plan every job's first fetch in one strategy_plan pass
            jss = [self._place(job, site, defer_fetch=True)
                   for job, site in zip(batch, sites)]
            self._batch_fetch(jss)
        else:
            for job, site in zip(batch, sites):
                self._place(job, site)

    def _next_missing(self, js: _JobState) -> Optional[str]:
        """Pop ``js.missing`` down to its first file that still needs a
        transfer (touching anything that already arrived, like the
        sequential scan always did); ``None`` when nothing is left."""
        while js.missing:
            lfn = js.missing.pop(0)
            if self.storage.holds(js.site, lfn):
                self.storage.touch(js.site, lfn, self.now)
                continue
            return lfn
        return None

    def _fetch_next(self, js: _JobState) -> None:
        """Files are accessed sequentially within a job (paper §4.1): one
        transfer in flight per job."""
        if js.done:
            return
        lfn = self._next_missing(js)
        if lfn is not None:
            obs = self._obs
            plan = js.plan_cache.pop(lfn, None)
            if plan is not None:
                plan = self._live_plan(plan)
            if plan is None:
                if obs is None:
                    plan = self.strategy.plan_fetch(lfn, js.site)
                else:
                    with obs.span("strategy.plan"):
                        plan = self.strategy.plan_fetch(lfn, js.site)
            js.pending_transfers += 1
            self._start_transfer(plan, js)
            return
        if js.pending_transfers == 0:
            if js.data_ready_time < 0:
                js.data_ready_time = self.now
            self._enqueue_cpu(js)

    def _batch_fetch(self, jss: list[_JobState]) -> None:
        """Strategy-mode ``"batch"``: plan EVERY (job, missing-file) fetch
        of the burst in one ``plan_batch`` pass and cache the plans on
        each job, so the whole staging chain — not just the first file —
        rides the vectorized planner. ``_fetch_next`` consumes the cache
        one transfer at a time under the ``_live_plan`` guard — an
        earlier plan in the burst (or any event between burst and
        consumption) may take the space or the very replica a later plan
        counted on (the shared-snapshot convention of the jax dispatch
        brokers)."""
        pairs = [(lfn, js.site) for js in jss for lfn in js.missing]
        if pairs:
            obs = self._obs
            if obs is None:
                plans = self.strategy.plan_batch(pairs)
            else:
                obs.count("strategy.plan_batch_calls")
                obs.count("strategy.plan_batch_pairs", len(pairs))
                with obs.span("strategy.plan"):
                    plans = self.strategy.plan_batch(pairs)
            owners = (js for js in jss for _ in js.missing)
            for js, (lfn, _), plan in zip(owners, pairs, plans):
                js.plan_cache[lfn] = plan
        for js in jss:
            self._fetch_next(js)

    def _live_plan(self, plan: FetchPlan) -> Optional[FetchPlan]:
        """Adapt a burst-cached plan to the live state: keep it while it
        is still exactly executable, hand it to the strategy's cheap
        ``refresh_plan`` when only its store/eviction verdict went stale
        (earlier transfers moved the free space it was priced against),
        and drop it entirely (``None`` — full singleton replan) when the
        chosen source itself is gone or a cheaper class of source has
        appeared (an inter-region plan whose file now has a regional
        copy)."""
        obs = self._obs
        if plan.store and (plan.dst, plan.lfn) in self._inflight:
            if obs is not None:
                obs.count("plan_cache.keep")
            return plan      # piggybacks onto the in-flight transfer
        if not self.catalog.has_replica(plan.lfn, plan.src):
            if obs is not None:
                obs.count("plan_cache.replan")
            return None      # the chosen source was evicted since the burst
        if not (self.topology.sites[plan.src].online
                or self.catalog.is_master(plan.lfn, plan.src)):
            if obs is not None:
                obs.count("plan_cache.replan")
            return None
        if plan.inter_region and self.catalog.duplicated_in_region(
                plan.lfn, plan.dst, self.topology):
            if obs is not None:
                obs.count("plan_cache.replan")
            return None      # a regional copy appeared since the burst:
            # keeping the snapshot's WAN source would double-count
            # inter-region traffic the sequential pipeline avoids
        need = self.catalog.size(plan.lfn)
        free = self.storage.free(plan.dst)
        if plan.store and plan.evictions:
            # planned evictions must still exist, still cover, and still
            # be necessary (a file that fits outright now must not evict)
            if (free < need
                    and all(self.storage.holds(plan.dst, l)
                            and self.storage.evictable(plan.dst, l)
                            for l in plan.evictions)
                    and free + sum(self.catalog.size(l)
                                   for l in plan.evictions) >= need):
                if obs is not None:
                    obs.count("plan_cache.keep")
                return plan
        elif plan.store:
            if free >= need:
                if obs is not None:
                    obs.count("plan_cache.keep")
                return plan
        elif free < need:    # store=False stays the right call only
            if obs is not None:
                obs.count("plan_cache.keep")
            return plan      # while the file cannot fit
        if obs is None:
            return self.strategy.refresh_plan(plan)
        obs.count("plan_cache.reverdict")
        with obs.span("strategy.plan"):
            return self.strategy.refresh_plan(plan)

    def _working_set_missing(self, js: _JobState) -> list[str]:
        return [f for f in js.job.required
                if f not in js.temp_files and not self.storage.holds(js.site, f)]

    def _enqueue_cpu(self, js: _JobState) -> None:
        self._cpu_queue[js.site].append(js)
        self._maybe_start_cpu(js.site)

    def _cpu_advance(self, site: int) -> None:
        run = self._running[site]
        if run is not None:
            dt = self.now - self._cpu_last_update[site]
            run.remaining_ops = max(
                0.0, run.remaining_ops - dt * self.topology.sites[site].compute_capacity
            )
        self._cpu_last_update[site] = self.now

    def _maybe_start_cpu(self, site: int) -> None:
        if self._running[site] is not None or not self.topology.sites[site].online:
            return
        q = self._cpu_queue[site]
        while q:
            js = q.popleft()
            if js.done:
                continue
            missing = self._working_set_missing(js)
            if missing:
                # part of the staged set was evicted while queued: re-stage
                js.rounds += 1
                if js.rounds >= 3:
                    js.pin_on_arrival = True
                js.missing = missing
                self._fetch_next(js)
                continue
            # pin the working set for the duration of processing
            for f in js.job.required:
                if self.storage.holds(site, f) and f not in js.pinned:
                    self.storage.pin(site, f)
                    js.pinned.append(f)
                self.storage.touch(site, f, self.now)
            js.start_time = self.now
            self._running[site] = js
            self._cpu_last_update[site] = self.now
            self._reschedule_cpu(site)
            if self.speculative_backups and not js.is_backup and js.twin is None:
                expected = js.job.length / self.topology.sites[site].compute_capacity
                self._push(self.now + self.straggler_threshold * expected, WATCHDOG, js)
            return

    def _reschedule_cpu(self, site: int) -> None:
        js = self._running[site]
        if js is None:
            return
        self._cpu_version[site] += 1
        cap = self.topology.sites[site].compute_capacity
        eta = self.now + js.remaining_ops / cap
        self._push(eta, CPU_DONE, (site, self._cpu_version[site]))

    def _finish_job(self, js: _JobState) -> None:
        js.done = True
        site = js.site
        self.topology.sites[site].queued_work -= js.job.length
        for lfn in js.pinned:
            self.storage.unpin(site, lfn)
        js.temp_files.clear()   # paper: temp buffer dropped after job completes
        self._site_jobs[site].pop(js, None)
        twin = js.twin
        if twin is not None and not twin.done:
            self._cancel_job(twin)
        jid = js.job.job_id
        self.records.append(JobRecord(
            job_id=jid, job_type=js.job.job_type, site=site,
            submit_time=js.job.submit_time, data_ready_time=js.data_ready_time,
            start_time=js.start_time, finish_time=self.now,
            inter_comms=self._inter_comms.get(jid, 0),
            wan_bytes=self._wan_bytes.get(jid, 0.0),
            resubmits=self._resubmits.get(jid, 0),
        ))

    def _cancel_job(self, js: _JobState) -> None:
        js.done = True       # tombstone: a queued copy is skipped on pop
        site = js.site
        self.topology.sites[site].queued_work -= js.job.length
        for lfn in js.pinned:
            self.storage.unpin(site, lfn)
        js.temp_files.clear()
        if self._running[site] is js:
            self._cpu_advance(site)
            self._running[site] = None
            self._cpu_version[site] += 1
            self._maybe_start_cpu(site)
        self._site_jobs[site].pop(js, None)

    # -- replication economy -------------------------------------------------
    def _econ_round(self) -> None:
        """One periodic proactive-replication round: auction the top-valued
        files (``ReplicationOptimizer.step``) and execute the winners as
        waiter-less store transfers. Prefetches ride the same fluid model as
        job fetches — they occupy links and contend with job traffic, so
        the cost side of the economy is physically real."""
        assert self._econ is not None
        if self._obs is not None:
            self._obs.count("econ.rounds")
        self._net_advance()
        for prop in self._econ.step(self.now):
            # revalidate against the live state: an earlier winner in this
            # same round may have pinned a source copy or consumed space
            if self.storage.holds(prop.dst, prop.lfn) or \
                    (prop.dst, prop.lfn) in self._inflight:
                continue
            if not self.catalog.has_replica(prop.lfn, prop.src):
                continue
            if not all(self.storage.holds(prop.dst, l)
                       and self.storage.evictable(prop.dst, l)
                       for l in prop.evictions):
                continue
            free = self.storage.free(prop.dst) + sum(
                self.catalog.size(l) for l in prop.evictions)
            if free < self.catalog.size(prop.lfn):
                continue
            if self._obs is not None:
                self._obs.count("econ.prefetch_started")
            self._start_transfer(prop.to_plan(self.topology), None)
        if len(self.records) < self._n_expected:
            self._push(self.now + self._econ_interval, ECON, None)
        else:
            self._econ_armed = False   # workload drained; disarm

    # -- telemetry sampling (repro.obs) --------------------------------------
    def _obs_sample(self) -> None:
        """One periodic OBS sampling round: append a row of grid-state
        channels to the telemetry ring buffer. Strictly read-only over
        engine state (simlint SL014), so the event's presence in the heap
        never changes observable results — the same contract the
        sanitizer's twin replay relies on (twins drop the probe on
        deepcopy and their OBS events no-op here)."""
        obs = self._obs
        if obs is not None and obs.sampler is not None:
            obs.sampler.sample(self)
        # the repush depends only on the armed flag, not on the probe:
        # sanitizer twins drop the probe on deepcopy but must keep the
        # event stream (and hence the pending-queue digest) identical
        if self._obs_armed and len(self.records) < self._n_expected:
            self._push(self.now + self._obs_interval, OBS, None)
        else:
            self._obs_armed = False

    # -- failures / stragglers ----------------------------------------------
    def _fail_site(self, site: int) -> None:
        st = self.topology.sites[site]
        if not st.online:
            return
        self._cpu_advance(site)
        st.online = False
        if self._batched_strategy:
            self.strategy.invalidate_online()
        self._abort_transfers_touching(site)
        # lose non-master replicas (the SE is gone); masters are durable
        for lfn in self.storage.site_contents(site):
            if not self.catalog.is_master(lfn, site):
                self.storage.lose(site, lfn)
        # resubmit every job that was at this site
        victims = list(self._site_jobs[site])
        self._site_jobs[site].clear()
        self._cpu_queue[site].clear()
        self._running[site] = None
        self._cpu_version[site] += 1
        for js in victims:
            if js.done:
                continue
            js.done = True
            st.queued_work -= js.job.length
            jid = js.job.job_id
            if js.twin is not None and not js.twin.done:
                continue  # its twin survives; no resubmission needed
            self._resubmits[jid] = self._resubmits.get(jid, 0) + 1
            self._push(self.now, SUBMIT, js.job)
            self._n_expected += 0  # same job id, record count unchanged

    def _recover_site(self, site: int) -> None:
        self.topology.sites[site].online = True
        if self._batched_strategy:
            self.strategy.invalidate_online()
        self._maybe_start_cpu(site)

    def _watchdog(self, js: _JobState) -> None:
        """Speculative backup: if js still running past threshold, clone it."""
        if js.done or self._running[js.site] is not js:
            return
        job = js.job
        backup_site = self.scheduler.select_site(job)
        if backup_site == js.site:
            candidates = [s for s in self.topology.online_sites() if s != js.site]
            if not candidates:
                return
            backup_site = min(
                candidates, key=lambda s: (self.topology.sites[s].relative_load(), s))
        twin = _JobState(job=job, site=backup_site, is_backup=True,
                         remaining_ops=job.length)
        twin.twin = js
        js.twin = twin
        self._site_jobs[backup_site][twin] = None
        self.topology.sites[backup_site].queued_work += job.length
        twin.missing = [l for l in job.required
                        if not self.storage.holds(backup_site, l)]
        self._fetch_next(twin)

    # -- main loop -----------------------------------------------------------
    def run(self, until: float = float("inf")) -> SimResult:
        self.network.last = 0.0
        if self._econ is not None and not self._econ_armed:
            # first optimizer round one interval in — by then the access
            # history holds a usable demand signal
            self._econ_armed = True
            self._push(self.now + self._econ_interval, ECON, None)
        obs = self._obs
        if obs is not None and obs.sampler is not None and \
                not self._obs_armed and self._obs_interval > 0:
            # sim-time sampling clock, mirroring the ECON arming: one
            # baseline sample now, then one OBS event per interval until
            # the workload drains
            self._obs_armed = True
            obs.sampler.sample(self)
            self._push(self.now + self._obs_interval, OBS, None)
        batched = self.network.batched
        while self._q:
            if self.sanitize:
                if not self._sanitize_step(until):
                    break
                continue
            if batched:
                # batched drain: handle every event sharing the head
                # timestamp, then let _drain_instant's flush loop run the
                # one fused network pass for the whole instant
                t = self._q[0][0]
                if t > until:
                    heapq.heappop(self._q)
                    break
                self._drain_instant(t)
                continue
            t, _, kind, payload = heapq.heappop(self._q)
            if t > until:
                break
            self.now = t
            self._handle(kind, payload)
        total_ic = sum(r.inter_comms for r in self.records)
        telemetry = None
        makespan = self.now
        if obs is not None:
            makespan = self._obs_real_now
            obs.merge_counters("net", self.network.stats)
            telemetry = obs.finalize(net_stats=self.network.stats)
        return SimResult(
            records=self.records,
            total_inter_comms=total_ic,
            total_wan_bytes=self.total_wan_bytes,
            total_lan_bytes=self.total_lan_bytes,
            makespan=makespan,
            net_stats=dict(self.network.stats),
            prefetches=self.access.prefetches,
            prefetch_bytes=self.access.prefetch_bytes,
            telemetry=telemetry,
        )

    def _handle(self, kind: int, payload: object) -> None:
        """Dispatch one popped event (``self.now`` already advanced),
        charging its telemetry phase when a probe is attached — the one
        per-event hot-path branch the obs="off" contract allows."""
        obs = self._obs
        if obs is None:
            return self._handle_event(kind, payload)
        if kind != OBS:
            self._obs_real_now = self.now
        obs.event(EVENT_NAMES[kind], self.now)
        phase = _EVENT_PHASE[kind]
        if phase is None:
            return self._handle_event(kind, payload)
        with obs.span(phase):
            return self._handle_event(kind, payload)

    def _handle_event(self, kind: int, payload: object) -> None:
        t = self.now
        if kind == SUBMIT:
            # submit_time was stamped at first submission; resubmitted
            # jobs (failures) keep it so job_time spans the whole outage.
            if self._jax_broker is None:
                self._schedule(payload)  # type: ignore[arg-type]
            elif self.batch_window > 0:
                # collect; dispatch together once the window closes
                # (batching adds latency — it never violates causality)
                self._batch_buf.append(payload)  # type: ignore[arg-type]
                if not self._flush_pending:
                    self._flush_pending = True
                    self._push(t + self.batch_window, FLUSH, None)
            else:
                self._dispatch_batch(self._drain_submit_batch(payload))  # type: ignore[arg-type]
        elif kind == FLUSH:
            self._flush_pending = False
            batch, self._batch_buf = self._batch_buf, []
            if batch:
                self._dispatch_batch(batch)
        elif kind == NET:
            if payload != self._net_version:
                return
            self._net_advance()
            done_idx = self.network.completions()
            if done_idx.size:
                done = sorted((self.network.obj[i] for i in done_idx),
                              key=lambda tr: tr.tid)
                for tr in done:
                    self._finish_transfer(tr)
            else:
                self._net_rerate()
        elif kind == CPU_DONE:
            site, ver = payload  # type: ignore[misc]
            if ver != self._cpu_version[site]:
                return
            self._cpu_advance(site)
            js = self._running[site]
            if js is None:
                return
            self._running[site] = None
            self._finish_job(js)
            self._maybe_start_cpu(site)
        elif kind == FAIL:
            self._fail_site(payload)  # type: ignore[arg-type]
        elif kind == RECOVER:
            self._recover_site(payload)  # type: ignore[arg-type]
        elif kind == SLOW_START:
            site, factor = payload  # type: ignore[misc]
            self._cpu_advance(site)
            self.topology.sites[site].compute_capacity *= factor
            self._reschedule_cpu(site)
        elif kind == SLOW_END:
            site, factor = payload  # type: ignore[misc]
            self._cpu_advance(site)
            self.topology.sites[site].compute_capacity /= factor
            self._reschedule_cpu(site)
        elif kind == WATCHDOG:
            self._watchdog(payload)  # type: ignore[arg-type]
        elif kind == ECON:
            self._econ_round()
        elif kind == OBS:
            self._obs_sample()

    # -- tie-race sanitizer ------------------------------------------------
    def _sanitize_step(self, until: float) -> bool:
        """Process one *instant* (every event sharing the head timestamp);
        when the instant is a tie group, replay it order-reversed in a
        deep-copied twin and record any observable-state divergence.
        Returns False when the run should stop (head event past ``until``
        — popped and dropped, matching the normal loop)."""
        t = self._q[0][0]
        if t > until:
            heapq.heappop(self._q)
            return False
        group = sorted(e for e in self._q if e[0] == t)
        twin = self._tie_twin(t) if len(group) > 1 else None
        if twin is not None:
            self.ties_seen += 1
        self._drain_instant(t)
        if twin is not None:
            twin._drain_instant(t)
            diff = _digest_diff(self._state_digest(), twin._state_digest())
            if diff is not None:
                self.tie_races.append(TieRace(
                    time=t,
                    kinds=tuple(EVENT_NAMES[e[2]] for e in group),
                    detail=diff,
                ))
        return True

    def _drain_instant(self, t0: float) -> None:
        """Pop and handle every event at time ``t0`` — including events the
        handlers push back *at* ``t0`` (sim time never goes backwards, so
        ``<=`` only ever matches the same instant). On the batched network
        engine each drained round ends with the instant's one fused flush
        (``_net_flush``); a flush may reschedule the NET wake-up back *at*
        ``t0`` (a slot within the sub-byte done-epsilon), so the outer
        loop re-drains until the instant is quiet. On the incremental
        backends the flush is a no-op and the inner loop drains everything
        in one round — the pre-batching behavior, bit for bit."""
        while self._q and self._q[0][0] <= t0:
            while self._q and self._q[0][0] <= t0:
                t, _, kind, payload = heapq.heappop(self._q)
                self.now = t
                self._handle(kind, payload)
            self._net_flush()

    def _tie_twin(self, t: float) -> "GridSimulator":
        """Deep-copied engine whose events at ``t`` are re-queued in
        reversed seq order (fresh seq numbers keep the (time, seq) key
        shape; among themselves they pop in the reversed order)."""
        twin = copy.deepcopy(self)
        group = []
        while twin._q and twin._q[0][0] == t:
            group.append(heapq.heappop(twin._q))
        for _, _, kind, payload in reversed(group):
            twin._push(t, kind, payload)
        return twin

    def _state_digest(self) -> dict:
        """Canonicalized observable state for twin comparison. Anything
        whose order is *not* semantic (records, holder sets, transfer
        tables, the pending-event multiset) is sorted; anything whose
        order *is* semantic (per-site FIFO CPU queues) keeps its order so
        a genuine ordering race shows up. Internal version counters, PRNG
        positions and heap seq numbers are excluded — bookkeeping, not
        observable results."""
        d: dict = {"now": self.now}
        d["records"] = sorted(
            (r.job_id, r.job_type, r.site, r.submit_time, r.data_ready_time,
             r.start_time, r.finish_time, r.inter_comms, r.wan_bytes,
             r.resubmits)
            for r in self.records)
        d["sites"] = [(s.site_id, s.online, s.used_storage, s.queued_work,
                       s.compute_capacity) for s in self.topology.sites]
        d["storage"] = [sorted(self.storage.site_contents(s.site_id))
                        for s in self.topology.sites]
        d["catalog"] = [(lfn, sorted(self.catalog.holders(lfn)))
                        for lfn in self.catalog.files]
        rem_now = self.network.rem_now(self.now)
        d["transfers"] = sorted(
            (tr.plan.lfn, tr.plan.src, tr.plan.dst, bool(tr.plan.store),
             float(rem_now[tr.slot]),
             float(self.network.rate[tr.slot]),
             sorted(w.job.job_id for w in tr.waiters))
            for tr in self._transfers.values())
        d["cpu"] = [
            (s.site_id,
             None if self._running[s.site_id] is None
             else self._running[s.site_id].job.job_id,
             [js.job.job_id for js in self._cpu_queue[s.site_id]
              if not js.done])
            for s in self.topology.sites]
        d["jobs"] = sorted(
            (js.job.job_id, site_id, tuple(js.missing),
             js.pending_transfers, js.data_ready_time, js.start_time,
             js.done, js.is_backup, js.rounds)
            for site_id, jobs in self._site_jobs.items()
            for js in jobs)
        d["queue"] = sorted(
            (e[0], e[2], _payload_digest(e[2], e[3])) for e in self._q)
        d["totals"] = (
            self.total_wan_bytes, self.total_lan_bytes,
            sorted(self._inter_comms.items()),
            sorted(self._wan_bytes.items()),
            sorted(self._resubmits.items()))
        return d


def _payload_digest(kind: int, payload: object) -> tuple:
    """Order-comparison key for a pending event's payload. Version
    counters (NET, CPU_DONE) are *excluded*: twins bump them in different
    interleavings while converging to the same physical state."""
    if kind == SUBMIT:
        return ("job", payload.job_id)             # type: ignore[union-attr]
    if kind == NET:
        return ("net",)
    if kind == CPU_DONE:
        return ("cpu", payload[0])                 # type: ignore[index]
    if kind in (FAIL, RECOVER):
        return ("site", payload)
    if kind in (SLOW_START, SLOW_END):
        return ("slow",) + tuple(payload)          # type: ignore[arg-type]
    if kind == WATCHDOG:
        return ("watchdog", payload.job.job_id)    # type: ignore[union-attr]
    return (EVENT_NAMES[kind],)


def _digest_diff(a: object, b: object, path: str = "state"
                 ) -> Optional[str]:
    """First divergence between two state digests, human-readable."""
    if type(a) is not type(b):
        return f"{path}: {type(a).__name__} != {type(b).__name__}"
    if isinstance(a, dict):
        assert isinstance(b, dict)
        for k in a:
            if k not in b:
                return f"{path}.{k}: missing in twin"
            diff = _digest_diff(a[k], b[k], f"{path}.{k}")
            if diff is not None:
                return diff
        extra = [k for k in b if k not in a]
        if extra:
            return f"{path}.{extra[0]}: only in twin"
        return None
    if isinstance(a, (list, tuple)):
        assert isinstance(b, (list, tuple))
        if len(a) != len(b):
            return f"{path}: length {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            diff = _digest_diff(x, y, f"{path}[{i}]")
            if diff is not None:
                return diff
        return None
    if a != b:
        return f"{path}: {a!r} != {b!r}"
    return None
