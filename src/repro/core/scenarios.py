"""Declarative experiment scenarios + the named-scenario registry.

The paper evaluates one fixed world: a 4-region x 13-site two-level grid
with uniform links and a steady uniform arrival stream. Related work shows
the interesting regimes live elsewhere — DIANA-style network-aware
scheduling (arXiv:0707.0862) on heterogeneous fabrics, bulk scheduling
(arXiv:cs/0602026) under bursty submission. A :class:`ScenarioSpec` captures
*everything* that defines one experiment — topology shape, per-tier
bandwidth/storage, arrival process, workload mix, failure injections,
scheduler + replication strategy + broker, seeds — as a frozen, JSON
round-trippable dataclass, and :data:`SCENARIOS` registers named instances
(the paper baseline plus deep hierarchies, fat-region fabrics, flash-crowd /
diurnal / bulk arrivals, site churn, and a cache-starved regime).

Run them with ``python -m repro.launch.experiments --scenario NAME`` (or
``--all``); see ``docs/SCENARIOS.md`` for the catalog and how to add one.
"""

from __future__ import annotations

import dataclasses
import math
import random as _random

from ..obs import OBS_MODES
from .economy import ECON_BACKENDS
from .quantities import GB, MB, MBPS_TO_BYTES_PER_S
from .replica import STRATEGIES, STRATEGY_MODES
from .scheduler import SCHEDULERS
from .simulator import NETS
from .workload import GridConfig

ARRIVALS = ("uniform", "poisson", "flash_crowd", "diurnal")
BROKERS = ("event", "jax")


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """Declarative site-churn regime for the grid simulator.

    ``n_failures`` outages are spread over ``window`` (seconds of sim time);
    each takes a distinct site offline for a duration drawn exponentially
    around ``mean_downtime_s``. Expansion into concrete ``(site, at,
    duration)`` events is :func:`repro.fault.failures.churn_schedule`,
    deterministic under a seed.
    """

    n_failures: int = 0
    window: tuple[float, float] = (0.0, 0.0)
    mean_downtime_s: float = 4000.0


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Everything that defines one grid experiment, declaratively.

    Field groups (defaults reproduce the paper's Table-1 world exactly —
    ``to_grid_config`` of the default spec equals ``GridConfig()``):

    *Topology* — ``tier_fanouts`` is the tier tree, e.g. ``(4, 13)`` (the
    paper) or ``(2, 3, 3, 3)`` (a 5-tier hierarchy); ``uplink_mbps`` gives
    one uplink bandwidth per internal level, top-down; ``lan_mbps`` is the
    site NIC. ``uplink_scale`` holds ``(level, node, factor)`` bandwidth
    multipliers (fat regions), ``storage_scale`` holds ``(region, factor)``
    SE-capacity multipliers, and ``storage_gb`` the base SE size.

    *Workload* — catalog size/granularity, per-job file count, job mix and
    length, Zipf skew of the per-job file draw (``None`` = fixed sets);
    ``hotset_shifts`` reshuffles the popular file set that many times
    mid-run (the drifting-hot-set regime).

    *Arrivals* — ``arrival`` is one of ``uniform | poisson | flash_crowd |
    diurnal`` (see :func:`arrival_schedule`); ``arrival_burst`` > 1 submits
    uniform arrivals in bursts of that size (DIANA-style bulk submission,
    usually with ``broker="jax"``).

    *Injections* — ``churn`` expands into deterministic ``(site, at,
    duration)`` failures via :func:`repro.fault.failures.churn_schedule`;
    ``slowdowns`` are literal ``(site, at, duration, factor)`` stragglers.

    *Engine* — scheduler / replication strategy / broker registry names,
    the network-engine backend ``net`` (``numpy`` | ``pallas`` |
    ``pallas-interpret`` | ``device`` | ``device-interpret`` | ``topmost``,
    see :class:`repro.core.network.NetworkEngine`), the replication-economy
    value-scoring backend ``econ`` + its period ``econ_interval_s``
    (``None`` arms the optimizer only for the access-aware strategies; see
    :mod:`repro.core.economy`) and the seeds to run (one simulation per
    seed).

    Specs are frozen; derive variants with ``dataclasses.replace`` and
    serialize with :meth:`to_dict` / :meth:`from_dict` (exact round-trip,
    JSON-safe).
    """

    name: str
    description: str = ""
    probes: str = ""                 # paper figure / related-work regime
    # -- topology ----------------------------------------------------------
    tier_fanouts: tuple[int, ...] = (4, 13)
    lan_mbps: float = 1000.0
    uplink_mbps: tuple[float, ...] = (10.0,)
    uplink_scale: tuple[tuple[int, int, float], ...] = ()
    storage_gb: float = 10.0
    storage_scale: tuple[tuple[int, float], ...] = ()
    # -- workload ----------------------------------------------------------
    n_jobs: int = 500
    n_job_types: int = 5
    files_per_job: int = 12
    file_size_mb: float = 500.0
    catalog_gb: float = 50.0
    job_length: float = 60e9
    zipf_alpha: float | None = 0.9
    hotset_shifts: int = 0           # mid-run hot-set reshuffles (drift)
    # -- arrival process ---------------------------------------------------
    arrival: str = "uniform"
    interarrival_s: float = 60.0
    arrival_burst: int = 1
    crowd_at: float = 0.5            # flash_crowd: burst start (job fraction)
    crowd_frac: float = 0.3          # flash_crowd: fraction of jobs in burst
    crowd_factor: float = 30.0       # flash_crowd: rate multiplier in burst
    diurnal_amplitude: float = 0.8   # diurnal: rate swing, 0..1
    diurnal_period_jobs: int = 200   # diurnal: jobs per day-cycle
    # -- injections --------------------------------------------------------
    churn: ChurnSpec = ChurnSpec()
    slowdowns: tuple[tuple[int, float, float, float], ...] = ()
    # -- engine ------------------------------------------------------------
    scheduler: str = "dataaware"
    strategy: str = "hrs"
    strategy_mode: str = "sequential"
    broker: str = "event"
    batch_window_s: float = 0.0
    net: str = "numpy"
    econ: str = "numpy"              # value-scoring backend of the economy
    econ_interval_s: float | None = None   # None=auto (access-aware strategies)
    obs: str = "off"                 # telemetry mode (repro.obs.OBS_MODES)
    obs_interval_s: float | None = None    # sim-seconds between OBS samples
    seeds: tuple[int, ...] = (0,)

    def __post_init__(self) -> None:
        if len(self.tier_fanouts) < 2:
            raise ValueError(f"{self.name}: need >=2 tier levels")
        if len(self.uplink_mbps) != len(self.tier_fanouts) - 1:
            raise ValueError(
                f"{self.name}: {len(self.tier_fanouts)}-level fanouts need "
                f"{len(self.tier_fanouts) - 1} uplink bandwidths, got "
                f"{len(self.uplink_mbps)}")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"{self.name}: unknown arrival {self.arrival!r} "
                             f"(want one of {ARRIVALS})")
        if self.scheduler not in SCHEDULERS:
            raise ValueError(f"{self.name}: unknown scheduler "
                             f"{self.scheduler!r} (want one of "
                             f"{sorted(SCHEDULERS)})")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"{self.name}: unknown strategy "
                             f"{self.strategy!r} (want one of "
                             f"{sorted(STRATEGIES)})")
        if self.strategy_mode not in STRATEGY_MODES:
            raise ValueError(f"{self.name}: unknown strategy_mode "
                             f"{self.strategy_mode!r} (want one of "
                             f"{STRATEGY_MODES})")
        if self.broker not in BROKERS:
            raise ValueError(f"{self.name}: unknown broker {self.broker!r}")
        if self.net not in NETS:
            raise ValueError(f"{self.name}: unknown net engine "
                             f"{self.net!r} (want one of {NETS})")
        if self.econ not in ECON_BACKENDS:
            raise ValueError(f"{self.name}: unknown econ backend "
                             f"{self.econ!r} (want one of {ECON_BACKENDS})")
        if self.obs not in OBS_MODES:
            raise ValueError(f"{self.name}: unknown obs mode "
                             f"{self.obs!r} (want one of {OBS_MODES})")
        if self.hotset_shifts < 0:
            raise ValueError(f"{self.name}: hotset_shifts must be >= 0")
        if self.hotset_shifts > 0 and self.zipf_alpha is None:
            raise ValueError(
                f"{self.name}: hotset_shifts needs a Zipf workload "
                "(zipf_alpha=None draws fixed per-type filesets, which "
                "cannot drift)")
        if not self.seeds:
            raise ValueError(f"{self.name}: need at least one seed")

    # -- derived -----------------------------------------------------------
    @property
    def n_sites(self) -> int:
        n = 1
        for f in self.tier_fanouts:
            n *= f
        return n

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-safe dict; exact inverse of :meth:`from_dict`."""
        d = dataclasses.asdict(self)
        d["churn"] = dataclasses.asdict(self.churn)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        d = dict(d)
        churn = d.get("churn", {})
        if not isinstance(churn, ChurnSpec):
            churn = dict(churn)
            churn["window"] = tuple(churn.get("window", (0.0, 0.0)))
            churn = ChurnSpec(**churn)
        d["churn"] = churn
        for key in ("tier_fanouts", "uplink_mbps", "seeds"):
            if key in d:
                d[key] = tuple(d[key])
        for key in ("uplink_scale", "storage_scale", "slowdowns"):
            if key in d:
                d[key] = tuple(tuple(row) for row in d[key])
        return cls(**d)


def to_grid_config(spec: ScenarioSpec, seed: int | None = None) -> GridConfig:
    """Lower a spec's topology + workload fields to a :class:`GridConfig`.

    For two-level trees this emits the classic ``n_regions x
    sites_per_region`` form, so the default spec lowers to exactly
    ``GridConfig()`` (the golden-metrics baseline path).
    """
    mbps = MBPS_TO_BYTES_PER_S
    two_level = len(spec.tier_fanouts) == 2
    return GridConfig(
        n_regions=spec.tier_fanouts[0] if two_level else 4,
        sites_per_region=spec.tier_fanouts[1] if two_level else 13,
        storage_capacity=spec.storage_gb * GB,
        lan_bandwidth=spec.lan_mbps * mbps,
        wan_bandwidth=spec.uplink_mbps[0] * mbps,
        n_jobs=spec.n_jobs,
        n_job_types=spec.n_job_types,
        files_per_job=spec.files_per_job,
        file_size=spec.file_size_mb * MB,
        total_file_bytes=spec.catalog_gb * GB,
        job_length=spec.job_length,
        interarrival=spec.interarrival_s,
        zipf_alpha=spec.zipf_alpha,
        hotset_shifts=spec.hotset_shifts,
        seed=spec.seeds[0] if seed is None else seed,
        tier_fanouts=None if two_level else spec.tier_fanouts,
        uplink_bandwidths=(None if two_level
                           else tuple(u * mbps for u in spec.uplink_mbps)),
        uplink_scale=spec.uplink_scale,
        storage_scale=spec.storage_scale,
    )


def arrival_schedule(spec: ScenarioSpec, n_jobs: int,
                     seed: int = 0) -> list[float] | None:
    """Submit times (seconds, one per job) for the spec's arrival process.

    Returns ``None`` for ``uniform`` so the runner takes ``run_experiment``'s
    default arrival path (bit-identical to the paper baseline, including
    ``arrival_burst`` bulk submission). ``poisson`` and ``diurnal`` keep the
    baseline's mean rate ``1 / interarrival_s`` so those scenarios stay
    load-comparable; ``flash_crowd`` deliberately does not — the crowd adds
    extra load on top of the steady stream (with the default knobs the
    realized mean rate is ~1.4x the base). Deterministic under ``seed``.
    """
    ia = spec.interarrival_s
    if spec.arrival == "uniform":
        return None
    if spec.arrival == "poisson":
        rng = _random.Random(seed ^ 0xA441)
        t, out = 0.0, []
        for _ in range(n_jobs):
            out.append(t)
            t += rng.expovariate(1.0 / ia)
        return out
    if spec.arrival == "flash_crowd":
        # steady stream, except a contiguous block of jobs arrives at
        # crowd_factor x the base rate (a release / reprocessing campaign)
        lo = int(n_jobs * spec.crowd_at)
        hi = min(n_jobs, lo + max(1, int(n_jobs * spec.crowd_frac)))
        t, out = 0.0, []
        for j in range(n_jobs):
            out.append(t)
            t += ia / spec.crowd_factor if lo <= j < hi else ia
        return out
    if spec.arrival == "diurnal":
        # sinusoidally modulated gaps: "daytime" jobs arrive up to
        # (1 - amplitude) x faster, "night" up to (1 + amplitude) x slower
        t, out = 0.0, []
        for j in range(n_jobs):
            out.append(t)
            phase = 2.0 * math.pi * j / max(1, spec.diurnal_period_jobs)
            t += ia * (1.0 + spec.diurnal_amplitude * math.sin(phase))
        return out
    raise AssertionError(f"unhandled arrival {spec.arrival!r}")


def injections(spec: ScenarioSpec, seed: int = 0) -> tuple[
        list[tuple[int, float, float]],
        list[tuple[int, float, float, float]]]:
    """Expand the spec's fault fields into run_experiment's
    ``(failures, slowdowns)`` lists."""
    from repro.fault.failures import churn_schedule  # deferred: pulls in jax
    failures = churn_schedule(spec.churn, spec.n_sites, seed=seed)
    return failures, [tuple(s) for s in spec.slowdowns]


# --------------------------------------------------------------------------
# the registry
# --------------------------------------------------------------------------
SCENARIOS: dict[str, ScenarioSpec] = {}


def register_scenario(spec: ScenarioSpec) -> ScenarioSpec:
    """Add a spec to :data:`SCENARIOS` (name must be unused)."""
    if spec.name in SCENARIOS:
        raise ValueError(f"scenario {spec.name!r} already registered")
    SCENARIOS[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(sorted(SCENARIOS))}") from None


register_scenario(ScenarioSpec(
    name="paper_baseline",
    description="The paper's Table-1 world: 4 regions x 13 sites, 10 GB "
                "SEs, 1000/10 Mbps LAN/WAN, 500 jobs at a steady 60 s "
                "spacing, data-aware scheduler + HRS.",
    probes="paper fig4-fig7 (golden-metrics baseline)",
))

register_scenario(ScenarioSpec(
    name="deep_4tier",
    description="A 4-tier hierarchy (2 clusters x 4 groups x 7 sites) with "
                "a 10 Mbps top uplink over 100 Mbps group uplinks: locality "
                "is two-layered, so eviction mistakes cost more.",
    probes="hierarchy depth beyond the paper's 2-level grid",
    tier_fanouts=(2, 4, 7),
    uplink_mbps=(10.0, 100.0),
))

register_scenario(ScenarioSpec(
    name="deep_5tier",
    description="A 5-tier hierarchy (2 x 3 x 3 x 3 = 54 sites) with "
                "bandwidth decreasing up the tree (200/50/10 Mbps).",
    probes="hierarchy depth; tier-graded bandwidth",
    tier_fanouts=(2, 3, 3, 3),
    uplink_mbps=(10.0, 50.0, 200.0),
))

register_scenario(ScenarioSpec(
    name="fat_region",
    description="Paper grid but region 0's WAN uplink is 10x fatter "
                "(100 Mbps): a well-connected Tier-1-like center among "
                "thin regions.",
    probes="DIANA network-aware scheduling regime (arXiv:0707.0862)",
    uplink_scale=((1, 0, 10.0),),
))

register_scenario(ScenarioSpec(
    name="flash_crowd",
    description="Steady stream, then 30% of all jobs arrive at 30x the "
                "base rate mid-run (data release / reprocessing campaign).",
    probes="queue + WAN saturation transients",
    arrival="flash_crowd",
))

register_scenario(ScenarioSpec(
    name="diurnal",
    description="Sinusoidally modulated arrivals (80% rate swing, 200-job "
                "day cycle): replicas staged during the quiet phase serve "
                "the busy phase.",
    probes="time-varying load; cache warm-up dynamics",
    arrival="diurnal",
))

register_scenario(ScenarioSpec(
    name="bulk_diana",
    description="DIANA-style bulk submission: jobs arrive in bursts of 50 "
                "and each burst is placed by one jitted batch decision "
                "(broker='jax').",
    probes="bulk scheduling (arXiv:cs/0602026); jitted broker path",
    arrival_burst=50,
    broker="jax",
))

register_scenario(ScenarioSpec(
    name="site_churn",
    description="Paper grid under churn: 6 site outages (mean 4000 s) "
                "spread over the first 30000 s; queued jobs resubmit, "
                "replicas are lost and re-staged.",
    probes="fault-tolerance axis; replica durability",
    churn=ChurnSpec(n_failures=6, window=(1000.0, 30000.0),
                    mean_downtime_s=4000.0),
))

register_scenario(ScenarioSpec(
    name="deep_contended",
    description="A 4-tier hierarchy (3 clusters x 3 groups x 6 sites) with "
                "a fat 100 Mbps top tier over thin 10 Mbps group uplinks: "
                "cross-cluster transfers squeeze through a thin mid-tier "
                "link the legacy topmost-uplink model never contended.",
    probes="mid-tier path contention (net='numpy' vs net='topmost'; "
           "benchmarks/run.py net_sweep)",
    tier_fanouts=(3, 3, 6),
    uplink_mbps=(100.0, 10.0),
))

register_scenario(ScenarioSpec(
    name="bulk_shortest",
    description="Bulk submission placed by the vectorized shortest-transfer "
                "broker: each 50-job burst is costed against a "
                "point-bandwidth matrix snapshot of the per-link arrays "
                "and dispatched as one jitted decision.",
    probes="multi-backend brokers (shortesttransfer under broker='jax')",
    scheduler="shortesttransfer",
    arrival_burst=50,
    broker="jax",
))

register_scenario(ScenarioSpec(
    name="grid_500",
    description="The OptorSim-scale point: 500 sites (5 clusters x 10 "
                "groups x 10 sites, 500/1000 Mbps graded uplinks, 50 GB "
                "SEs) over a 1000-file / 500 GB catalog, 100k jobs "
                "arriving in bursts of 50, each burst placed by one "
                "jitted batch decision against the incremental presence "
                "bitmap. Sized to run *sustainably* — makespan tracks "
                "the arrival span and inter-comms settle near the "
                "paper's — so the benchmark measures engine throughput, "
                "not backlog pathology. The ROADMAP's scale target; "
                "`benchmarks/run.py scale_sweep` runs it as its largest "
                "point.",
    probes="engine scale (OptorSim-scale grid studies; 500-site / "
           "100k-job ROADMAP item); blocked st_cost + incremental "
           "snapshot hot paths",
    tier_fanouts=(5, 10, 10),
    uplink_mbps=(500.0, 1000.0),
    storage_gb=50.0,
    catalog_gb=500.0,
    n_jobs=100_000,
    n_job_types=10,
    interarrival_s=15.0,
    arrival_burst=50,
    broker="jax",
))

register_scenario(ScenarioSpec(
    name="grid_500_saturated",
    description="The grid_500 world driven into backlog on purpose: "
                "arrivals 30x faster (0.5 s between bursts of 50) over "
                "10x thinner uplinks (50/100 Mbps), so thousands of "
                "transfers pile onto every cluster uplink and the "
                "incremental engine's per-event member union + "
                "next-completion scan both go O(backlog). scale_sweep "
                "runs the same 20k-job point under net='numpy' and "
                "net='device'; the batched engine's O(1)-per-event "
                "drain must beat the incremental wall clock >=2x here.",
    probes="saturated-backlog pathology (ROADMAP batched-event item); "
           "device vs numpy engine wall-clock evidence",
    tier_fanouts=(5, 10, 10),
    uplink_mbps=(50.0, 100.0),
    storage_gb=50.0,
    catalog_gb=500.0,
    n_jobs=20_000,
    n_job_types=10,
    interarrival_s=0.5,
    arrival_burst=50,
    broker="jax",
    net="device",
))

register_scenario(ScenarioSpec(
    name="grid_500_evict",
    description="The grid_500 world driven into *planner* pathology: 50 "
                "MB files over a 10,000-file catalog, 25 GB SEs (~500 "
                "evictable residents each) and 25-file jobs, so the SEs "
                "saturate early and nearly every store walks the full "
                "two-phase LRU scan over hundreds of residents with "
                "hundreds of candidate sources. This is the "
                "strategy_mode='batch' discriminating regime: the "
                "sequential planner pays per-file Python scans "
                "(holders walk, per-resident evictable + "
                "duplicated_in_region checks), the batched planner "
                "amortizes them into per-burst vectorized passes plus "
                "cheap source-preserving re-verdicts. scale_sweep runs "
                "the 20k-job point in both strategy modes; the batched "
                "wall clock must beat sequential >=2x here.",
    probes="eviction-scan-bound planning (batched replica-strategy "
           "engine); burst plan-cache + refresh_plan hot paths",
    tier_fanouts=(5, 10, 10),
    uplink_mbps=(500.0, 1000.0),
    storage_gb=25.0,
    catalog_gb=500.0,
    file_size_mb=50.0,
    files_per_job=25,
    n_jobs=20_000,
    n_job_types=10,
    interarrival_s=15.0,
    arrival_burst=50,
    broker="jax",
))

register_scenario(ScenarioSpec(
    name="grid_5000",
    description="The 5000-site / 1M-job rung: 5 clusters x 10 groups x "
                "10 subgroups x 10 sites (graded 10000/2000/1000 Mbps "
                "uplinks, 50 GB SEs) over a 2000-file / 1 TB catalog, a "
                "million jobs arriving in bursts of 50 every 75 s (the "
                "same per-site pressure as grid_500), each burst placed "
                "by one jitted batch decision. Runs on the batched "
                "on-device event engine (net='device'): occupancy "
                "changes only mark the engine dirty and every drained "
                "instant re-rates + reconstructs + scans in one fused "
                "pass, so per-event network work no longer grows with "
                "the in-flight count.",
    probes="engine scale (5000-site / 1M-job ROADMAP rung); batched "
           "event-engine drain + tolerance-golden contract at scale",
    tier_fanouts=(5, 10, 10, 10),
    uplink_mbps=(10000.0, 2000.0, 1000.0),
    storage_gb=50.0,
    catalog_gb=1000.0,
    n_jobs=1_000_000,
    n_job_types=20,
    interarrival_s=1.5,
    arrival_burst=50,
    broker="jax",
    net="device",
))

register_scenario(ScenarioSpec(
    name="cache_starved",
    description="Paper grid with 2 GB SEs: a site can hold at most 4 of "
                "the 12 files a job needs, so eviction policy dominates.",
    probes="eviction-pressure regime (two-phase vs plain LRU)",
    storage_gb=2.0,
))

register_scenario(ScenarioSpec(
    name="economy_starved",
    description="The cache_starved world under the OptorSim-style "
                "replication economy: the economic strategy prices every "
                "eviction as a trade (predicted accesses x transfer cost) "
                "and a periodic optimizer auctions top-valued files to "
                "sites with space.",
    probes="replication economy (economic/auction-based related work); "
           "proactive vs reactive replication under eviction pressure",
    storage_gb=2.0,
    strategy="economic",
    seeds=(0, 1),
))

register_scenario(ScenarioSpec(
    name="hotset_drift",
    description="Paper grid whose popular file set reshuffles 3 times "
                "mid-run (sharper 1.1 Zipf draw): reactive strategies "
                "keep serving yesterday's hot set while the predictive "
                "strategy's decayed counts track the drift and its "
                "optimizer stages rising files ahead of demand.",
    probes="popularity-prediction replication (CMS access-pattern study); "
           "the regime where predictive beats reactive HRS",
    zipf_alpha=1.1,
    hotset_shifts=3,
    seeds=(0, 1),
))


# --------------------------------------------------------------------------
# parameter sweeps as first-class specs
# --------------------------------------------------------------------------
#: Axes a sweep may vary: any ScenarioSpec field (replaced literally via
#: ``dataclasses.replace``) plus the derived ``wan_mbps`` axis (the topmost
#: uplink bandwidth, i.e. ``uplink_mbps[0]``).
_SPEC_FIELDS = frozenset(
    f.name for f in dataclasses.fields(ScenarioSpec)) - {"name"}
SWEEP_AXES = _SPEC_FIELDS | {"wan_mbps"}


def with_axis(spec: ScenarioSpec, axis: str, value) -> ScenarioSpec:
    """One sweep cell: ``spec`` with ``axis`` replaced by ``value``.

    The replaced spec re-validates in ``__post_init__``, so sweeping an
    engine axis (``strategy``, ``net``, ``scheduler``, ``econ``, ...) to a
    bad value fails at expansion time, not mid-run.
    """
    if axis == "wan_mbps":
        return dataclasses.replace(
            spec, uplink_mbps=(float(value),) + spec.uplink_mbps[1:])
    if axis not in _SPEC_FIELDS:
        raise ValueError(f"unknown sweep axis {axis!r} "
                         f"(want one of {sorted(SWEEP_AXES)})")
    # JSON-sourced values (SweepSpec.from_dict) arrive as lists: coerce
    # them the same way ScenarioSpec.from_dict does, so sweep cells stay
    # hashable frozen specs
    if axis in ("tier_fanouts", "uplink_mbps", "seeds"):
        value = tuple(value)
    elif axis in ("uplink_scale", "storage_scale", "slowdowns"):
        value = tuple(tuple(row) for row in value)
    return dataclasses.replace(spec, **{axis: value})


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A named parameter study: one base scenario crossed along one axis.

    ``base`` names a registered :class:`ScenarioSpec`; each cell is the
    base with ``axis`` set to one of ``values`` (see :func:`with_axis` for
    the axis vocabulary — every spec field plus ``wan_mbps``). The runner
    (``python -m repro.launch.experiments --scenario NAME``) accepts sweep
    names next to scenario names and writes the whole grid, one row per
    (value, seed), into ``BENCH_scenarios.json``. JSON round-trippable like
    :class:`ScenarioSpec`.
    """

    name: str
    base: str
    axis: str
    values: tuple
    description: str = ""

    def __post_init__(self) -> None:
        if self.axis not in SWEEP_AXES:
            raise ValueError(f"{self.name}: unknown sweep axis "
                             f"{self.axis!r} (want one of "
                             f"{sorted(SWEEP_AXES)})")
        if not self.values:
            raise ValueError(f"{self.name}: need at least one value")

    def expand(self) -> list[tuple[object, ScenarioSpec]]:
        """``(value, cell spec)`` per value; cells are named
        ``base@axis=value`` and fully validated."""
        base = get_scenario(self.base)
        return [
            (v, dataclasses.replace(with_axis(base, self.axis, v),
                                    name=f"{self.base}@{self.axis}={v}"))
            for v in self.values
        ]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["values"] = list(self.values)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepSpec":
        d = dict(d)
        d["values"] = tuple(d["values"])
        return cls(**d)


#: Named-sweep registry (the grid analogue of :data:`SCENARIOS`).
SWEEPS: dict[str, SweepSpec] = {}


def register_sweep(spec: SweepSpec) -> SweepSpec:
    """Add a sweep to :data:`SWEEPS` (name must be unused in both
    registries, so ``--scenario`` can resolve either)."""
    if spec.name in SWEEPS or spec.name in SCENARIOS:
        raise ValueError(f"sweep {spec.name!r} already registered")
    get_scenario(spec.base)          # fail fast on a bad base
    spec.expand()                    # ... and on any invalid cell
    SWEEPS[spec.name] = spec
    return spec


def get_sweep(name: str) -> SweepSpec:
    try:
        return SWEEPS[name]
    except KeyError:
        raise KeyError(f"unknown sweep {name!r}; registered: "
                       f"{', '.join(sorted(SWEEPS))}") from None


_ALL_STRATEGIES = ("hrs", "bhr", "lru", "economic", "predictive")

register_sweep(SweepSpec(
    name="starved_strategies",
    base="cache_starved",
    axis="strategy",
    values=_ALL_STRATEGIES,
    description="Every replication strategy under 2 GB eviction pressure: "
                "the discriminating regime for the access-aware pair.",
))

register_sweep(SweepSpec(
    name="drift_strategies",
    base="hotset_drift",
    axis="strategy",
    values=_ALL_STRATEGIES,
    description="Every replication strategy against a drifting hot set "
                "(prediction should beat reactive HRS here).",
))

register_sweep(SweepSpec(
    name="contended_nets",
    base="deep_contended",
    axis="net",
    values=("topmost", "numpy", "pallas"),
    description="Network-model fidelity grid: the legacy topmost-uplink "
                "accounting vs the per-link path model vs the vectorized "
                "re-rate backend on the mid-tier-contended tree.",
))

register_sweep(SweepSpec(
    name="baseline_wan",
    base="paper_baseline",
    axis="wan_mbps",
    values=(10.0, 50.0, 100.0, 500.0, 1000.0),
    description="The paper's fig7 WAN-bandwidth axis as a first-class "
                "sweep.",
))
