"""The paper's primary contribution: hierarchical data-grid scheduling +
HRS replication, plus the discrete-event engine that evaluates them and the
jit-compiled dispatch path used by the training/serving runtime."""

from ..obs import OBS_MODES, TelemetryReport
from .access import AccessHistory
from .catalog import FileInfo, ReplicaCatalog
from .economy import (ECON_BACKENDS, EconomicValue, FileValue,
                      PopularityValue, ProposedReplication,
                      ReplicationOptimizer, VALUE_MODELS)
from .metrics import ExperimentResult, run_experiment
from .network import NetworkEngine
from .scenarios import (ChurnSpec, SCENARIOS, SWEEPS, ScenarioSpec,
                        SweepSpec, arrival_schedule, get_scenario, get_sweep,
                        injections, register_scenario, register_sweep,
                        to_grid_config, with_axis)
from .replica import (BHRStrategy, FetchPlan, HRSSinglePhaseStrategy,
                      HRSStrategy, LRUStrategy, NoReplicationStrategy,
                      ReplicaStrategy, StorageState, StorageTensorView,
                      STRATEGIES, STRATEGY_MODES, make_strategy)
from .scheduler import (DataAwareScheduler, Job, LeastLoadedScheduler,
                        RandomScheduler, SchedulerPolicy, SCHEDULERS,
                        ShortestTransferScheduler, make_scheduler)
from .simulator import GridSimulator, JobRecord, SimResult
from .topology import GridTopology, Link, Region, Site
from .workload import (GB, MB, GridConfig, build_catalog, build_topology,
                       generate_jobs, job_type_filesets)

__all__ = [
    "AccessHistory", "OBS_MODES", "TelemetryReport",
    "FileInfo", "ReplicaCatalog", "ExperimentResult", "run_experiment",
    "ECON_BACKENDS", "EconomicValue", "FileValue", "PopularityValue",
    "ProposedReplication", "ReplicationOptimizer", "VALUE_MODELS",
    "NetworkEngine",
    "ChurnSpec", "SCENARIOS", "SWEEPS", "ScenarioSpec", "SweepSpec",
    "arrival_schedule", "get_scenario", "get_sweep", "injections",
    "register_scenario", "register_sweep", "to_grid_config", "with_axis",
    "BHRStrategy", "FetchPlan", "HRSSinglePhaseStrategy", "HRSStrategy",
    "LRUStrategy",
    "NoReplicationStrategy", "ReplicaStrategy", "StorageState",
    "StorageTensorView", "STRATEGIES", "STRATEGY_MODES", "make_strategy", "DataAwareScheduler", "Job", "LeastLoadedScheduler",
    "RandomScheduler", "SchedulerPolicy", "SCHEDULERS",
    "ShortestTransferScheduler", "make_scheduler", "GridSimulator",
    "JobRecord", "SimResult", "GridTopology", "Link", "Region", "Site",
    "GB", "MB", "GridConfig", "build_catalog", "build_topology",
    "generate_jobs", "job_type_filesets",
]
