"""The paper's primary contribution: hierarchical data-grid scheduling +
HRS replication, plus the discrete-event engine that evaluates them and the
jit-compiled dispatch path used by the training/serving runtime."""

from .catalog import FileInfo, ReplicaCatalog
from .metrics import ExperimentResult, run_experiment
from .network import NetworkEngine
from .scenarios import (ChurnSpec, SCENARIOS, ScenarioSpec, arrival_schedule,
                        get_scenario, injections, register_scenario,
                        to_grid_config)
from .replica import (BHRStrategy, FetchPlan, HRSSinglePhaseStrategy,
                      HRSStrategy, LRUStrategy, NoReplicationStrategy,
                      ReplicaStrategy, StorageState, STRATEGIES,
                      make_strategy)
from .scheduler import (DataAwareScheduler, Job, LeastLoadedScheduler,
                        RandomScheduler, SchedulerPolicy, SCHEDULERS,
                        ShortestTransferScheduler, make_scheduler)
from .simulator import GridSimulator, JobRecord, SimResult
from .topology import GridTopology, Link, Region, Site
from .workload import (GB, MB, GridConfig, build_catalog, build_topology,
                       generate_jobs, job_type_filesets)

__all__ = [
    "FileInfo", "ReplicaCatalog", "ExperimentResult", "run_experiment",
    "NetworkEngine",
    "ChurnSpec", "SCENARIOS", "ScenarioSpec", "arrival_schedule",
    "get_scenario", "injections", "register_scenario", "to_grid_config",
    "BHRStrategy", "FetchPlan", "HRSSinglePhaseStrategy", "HRSStrategy",
    "LRUStrategy",
    "NoReplicationStrategy", "ReplicaStrategy", "StorageState", "STRATEGIES",
    "make_strategy", "DataAwareScheduler", "Job", "LeastLoadedScheduler",
    "RandomScheduler", "SchedulerPolicy", "SCHEDULERS",
    "ShortestTransferScheduler", "make_scheduler", "GridSimulator",
    "JobRecord", "SimResult", "GridTopology", "Link", "Region", "Site",
    "GB", "MB", "GridConfig", "build_catalog", "build_topology",
    "generate_jobs", "job_type_filesets",
]
