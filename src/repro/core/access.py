"""Access-history tracking: the demand signal behind proactive replication.

The paper's HRS is purely *reactive* — every replication decision happens at
fetch time, driven by nothing but the current catalog and LRU clocks. The
economy subsystem (:mod:`repro.core.economy`) instead acts on *observed
access patterns*, which is what this module provides: an
:class:`AccessHistory` maintaining exponentially-decayed per-(site, file)
access counts in dense numpy arrays, fed from the simulator's fetch/hit
path, with region- and grid-level aggregation views.

Design notes:

* **Lazy per-cell decay.** A count decays as ``c(t) = c(t0) * 2^-((t-t0)/
  half_life)``. Storing a per-cell last-update stamp makes each ``record``
  O(1) (decay one cell, add the weight) while a full-matrix
  :meth:`snapshot` is a single vectorized ``counts * exp2(-(now - stamps)
  / half_life)`` pass — no per-event matrix sweeps.
* **Shift-invariant ordering.** The ratio of two decayed counts is
  independent of the evaluation time (both decay by the same factor), so
  rankings produced by :meth:`scores` are valid for *any* ``now`` at or
  after the last recorded event. Strategies may therefore order evictions
  without being told the clock.
* **Accounting parity.** ``fetches`` / ``remote_fetches`` / ``wan_bytes``
  / ``lan_bytes`` are incremented by the simulator at exactly the points
  where it accounts its own inter-communication metrics, so they agree
  with :class:`repro.core.metrics.ExperimentResult` by construction
  (pinned by ``tests/test_access.py``). Proactive (economy-initiated)
  transfers are counted separately as ``prefetches``.

The tracker is pure observation: recording never mutates catalog, storage
or topology state, so wiring it into the simulator leaves the HRS/BHR/LRU
golden paths bit-identical.
"""

from __future__ import annotations

import numpy as np

from .catalog import ReplicaCatalog
from .topology import GridTopology

#: Default decay half-life (seconds of simulated time). Four hours against
#: the paper's 60 s interarrival means a file's score reflects roughly its
#: last ~240 job arrivals. Tuned empirically on ``hotset_drift`` /
#: ``cache_starved`` at 2k jobs: shorter half-lives (1-2 h) track a shift
#: faster but are too noisy to rank the steady hot set, and ranking
#: quality dominates — 4 h beat 1 h / 2 h / 8 h for both access-aware
#: strategies on both regimes.
DEFAULT_HALF_LIFE_S = 14400.0


class AccessHistory:
    """Exponentially-decayed per-(site, file) access counts (dense numpy).

    ``counts[s, f]`` is the decayed access count of file ``f`` at site
    ``s``, valid at time ``stamps[s, f]``; :meth:`snapshot` brings the
    whole matrix to a common ``now``. File axis order is ``sorted(catalog.
    files)`` (the same convention as :class:`repro.core.jaxsched.
    JaxScheduler`), exposed via ``lfns`` / ``lfn_index``.
    """

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology, *,
                 half_life_s: float = DEFAULT_HALF_LIFE_S) -> None:
        if half_life_s <= 0:
            raise ValueError(f"half_life_s must be positive, got {half_life_s}")
        self.catalog = catalog
        self.topology = topology
        self.half_life_s = float(half_life_s)
        self.lfns: list[str] = sorted(catalog.files)
        self.lfn_index: dict[str, int] = {l: i for i, l in enumerate(self.lfns)}
        self.sizes = np.array([catalog.size(l) for l in self.lfns])
        n_sites, n_files = topology.n_sites, len(self.lfns)
        self.counts = np.zeros((n_sites, n_files))
        self.stamps = np.zeros((n_sites, n_files))
        self._n_catalog = len(catalog.files)
        # per-site decayed *serving* load: how often each site has recently
        # been the source of a transfer (strategies use it to spread load
        # across equally-fast replicas)
        self.serve_counts = np.zeros(n_sites)
        self.serve_stamps = np.zeros(n_sites)
        # exact (undecayed) accounting totals
        self.accesses = 0          # demand events (one per required file per job)
        self.hits = 0              # resolved from the local SE
        self.fetches = 0           # transfers started on behalf of jobs
        self.remote_fetches = 0    # ... of which inter-region (paper metric)
        self.wan_bytes = 0.0
        self.lan_bytes = 0.0
        self.prefetches = 0        # proactive (economy) transfers
        self.prefetch_bytes = 0.0
        self.last_now = 0.0

    # -- catalog sync ------------------------------------------------------
    def sync(self) -> None:
        """Pick up files registered in the catalog *after* construction
        (ad-hoc tests, dynamic workloads): rebuild the file axis in sorted
        order, carrying existing counts/stamps over by LFN. No-op when the
        catalog is unchanged."""
        if len(self.catalog.files) == self._n_catalog:
            return
        lfns = sorted(self.catalog.files)
        n_sites = self.counts.shape[0]
        counts = np.zeros((n_sites, len(lfns)))
        stamps = np.zeros((n_sites, len(lfns)))
        for j, lfn in enumerate(lfns):
            old = self.lfn_index.get(lfn)
            if old is not None:
                counts[:, j] = self.counts[:, old]
                stamps[:, j] = self.stamps[:, old]
        self.lfns = lfns
        self.lfn_index = {l: i for i, l in enumerate(lfns)}
        self.sizes = np.array([self.catalog.size(l) for l in lfns])
        self.counts, self.stamps = counts, stamps
        self._n_catalog = len(self.catalog.files)

    def _findex(self, lfn: str) -> int:
        idx = self.lfn_index.get(lfn)
        if idx is None:
            self.sync()
            idx = self.lfn_index[lfn]
        return idx

    # -- decay helpers -----------------------------------------------------
    def _decay_cell(self, site: int, fidx: int, now: float) -> None:
        dt = now - self.stamps[site, fidx]
        if dt > 0.0:
            self.counts[site, fidx] *= 2.0 ** (-dt / self.half_life_s)
            self.stamps[site, fidx] = now

    # -- recording (called by the simulator) -------------------------------
    def record_access(self, site: int, lfn: str, now: float,
                      weight: float = 1.0) -> None:
        """One unit of demand for ``lfn`` at ``site`` (job placement)."""
        fidx = self._findex(lfn)
        self._decay_cell(site, fidx, now)
        self.counts[site, fidx] += weight
        self.accesses += 1
        if now > self.last_now:
            self.last_now = now

    def record_hit(self, site: int, lfn: str, now: float) -> None:
        """A required file resolved from ``site``'s own SE."""
        del lfn, now  # demand was already recorded at placement
        self.hits += 1

    def _record_serve(self, src: int, now: float) -> None:
        """Decay-then-increment the source site's serving load."""
        dt = now - self.serve_stamps[src]
        if dt > 0.0:
            self.serve_counts[src] *= 2.0 ** (-dt / self.half_life_s)
            self.serve_stamps[src] = now
        self.serve_counts[src] += 1.0
        if now > self.last_now:
            self.last_now = now

    def record_fetch(self, src: int, dst: int, lfn: str, size: float,
                     inter_region: bool, now: float) -> None:
        """A job-driven transfer started (same call point as the
        simulator's own inter-communication accounting)."""
        self.fetches += 1
        if inter_region:
            self.remote_fetches += 1
            self.wan_bytes += size
        else:
            self.lan_bytes += size
        self._record_serve(src, now)

    def record_prefetch(self, src: int, dst: int, lfn: str, size: float,
                        now: float) -> None:
        """A proactive (economy-initiated) transfer started."""
        del dst, lfn
        self.prefetches += 1
        self.prefetch_bytes += size
        self._record_serve(src, now)

    # -- views -------------------------------------------------------------
    def snapshot(self, now: float | None = None) -> np.ndarray:
        """The full decayed ``(n_sites, n_files)`` count matrix at ``now``
        (default: the latest recorded time). Normalizes in place — stamps
        all move to ``now`` — and returns a copy."""
        self.sync()     # no-op unless files were registered late
        now = self.last_now if now is None else now
        dt = now - self.stamps
        np.multiply(self.counts, 2.0 ** (-np.maximum(dt, 0.0) / self.half_life_s),
                    out=self.counts)
        self.stamps[dt > 0.0] = now
        return self.counts.copy()

    def site_counts(self, site: int, now: float | None = None) -> np.ndarray:
        """Decayed counts for one site, ``(n_files,)``."""
        self.sync()     # no-op unless files were registered late
        now = self.last_now if now is None else now
        dt = np.maximum(now - self.stamps[site], 0.0)
        return self.counts[site] * 2.0 ** (-dt / self.half_life_s)

    def region_counts(self, now: float | None = None) -> np.ndarray:
        """Decayed counts aggregated per region, ``(n_regions, n_files)``:
        row r is exactly the sum of its member sites' rows."""
        snap = self.snapshot(now)
        out = np.zeros((len(self.topology.regions), snap.shape[1]))
        for region in self.topology.regions:
            out[region.region_id] = snap[region.site_ids].sum(axis=0)
        return out

    def grid_counts(self, now: float | None = None) -> np.ndarray:
        """Grid-wide decayed counts, ``(n_files,)``."""
        return self.snapshot(now).sum(axis=0)

    def serve_load(self, site: int, now: float | None = None) -> float:
        """Decayed count of transfers recently served *by* ``site``."""
        now = self.last_now if now is None else now
        dt = max(now - self.serve_stamps[site], 0.0)
        return float(self.serve_counts[site] * 2.0 ** (-dt / self.half_life_s))

    def serve_loads(self, now: float | None = None) -> np.ndarray:
        """Vector :meth:`serve_load` for every site at once,
        ``(n_sites,)`` — the batched planners' serve-discount column. The
        same ufunc arithmetic as the scalar path, so entry ``s`` equals
        ``serve_load(s)`` bit for bit."""
        now = self.last_now if now is None else now
        dt = np.maximum(now - self.serve_stamps, 0.0)
        return self.serve_counts * 2.0 ** (-dt / self.half_life_s)

    def scores(self, site: int, lfns: list[str] | tuple[str, ...]
               ) -> np.ndarray:
        """Decayed popularity scores for ``lfns`` at ``site``, evaluated at
        the latest recorded time. Decay is multiplicative and uniform in
        the evaluation time, so the *ordering* of these scores is the same
        for any later ``now`` — strategies can rank eviction candidates
        without knowing the clock."""
        if any(l not in self.lfn_index for l in lfns):
            self.sync()
        idx = np.fromiter((self.lfn_index[l] for l in lfns), np.intp,
                          len(lfns))
        if idx.size == 0:
            return np.zeros(0)
        dt = np.maximum(self.last_now - self.stamps[site, idx], 0.0)
        return self.counts[site, idx] * 2.0 ** (-dt / self.half_life_s)
