"""Job scheduling policies (paper §3.2 + baselines).

The paper's algorithm:
  1. compute S_s (eq. 1) for every site = bytes of the job's required files
     already present there;
  2. pick the site with max S_s;
  3. tie-break by min RelativeLoad (eq. 2).

Baselines implemented for the ablation (and because the paper's related work
compares against them): Random, LeastLoaded (queue-only), ShortestTransfer
(estimate transfer time for missing bytes and minimize transfer + queue).
"""

from __future__ import annotations

import dataclasses
import random as _random
from typing import Sequence

from .catalog import ReplicaCatalog
from .topology import GridTopology


@dataclasses.dataclass
class Job:
    job_id: int
    job_type: int
    required: list[str]              # LFNs (paper: R_j)
    length: float                    # ops (paper: MI)
    submit_time: float = 0.0


class SchedulerPolicy:
    name = "base"

    def __init__(self, catalog: ReplicaCatalog, topology: GridTopology,
                 seed: int = 0) -> None:
        self.catalog = catalog
        self.topology = topology
        self.rng = _random.Random(seed)

    def select_site(self, job: Job) -> int:
        raise NotImplementedError


class DataAwareScheduler(SchedulerPolicy):
    """The paper's scheduling policy (§3.2)."""

    name = "dataaware"

    def select_site(self, job: Job) -> int:
        online = self.topology.online_sites()
        scores = {s: self.catalog.bytes_at_site(job.required, s) for s in online}
        best = max(scores.values())
        # sites with most available requested data, tie-break min relative load
        ties = [s for s in online if scores[s] == best]
        return min(ties, key=lambda s: (self.topology.sites[s].relative_load(), s))


class RandomScheduler(SchedulerPolicy):
    name = "random"

    def select_site(self, job: Job) -> int:
        return self.rng.choice(self.topology.online_sites())


class LeastLoadedScheduler(SchedulerPolicy):
    """Ignore data location entirely: min RelativeLoad."""

    name = "leastloaded"

    def select_site(self, job: Job) -> int:
        online = self.topology.online_sites()
        return min(online, key=lambda s: (self.topology.sites[s].relative_load(), s))


class ShortestTransferScheduler(SchedulerPolicy):
    """Chang et al. [6]-style: minimize estimated (transfer + queue) time.

    Transfer estimate: for each missing file take bytes / current point
    bandwidth from its best source; queue estimate: RelativeLoad.
    """

    name = "shortesttransfer"

    def select_site(self, job: Job) -> int:
        online = self.topology.online_sites()

        def cost(s: int) -> float:
            t = 0.0
            for lfn in job.required:
                if self.catalog.has_replica(lfn, s):
                    continue
                # Durable masters keep this non-empty even when every
                # holder's site is down (same rule replica fetches use).
                holders = self.catalog.fetchable_holders(lfn, self.topology)
                bw = max((self.topology.point_bandwidth(h, s) for h in holders),
                         default=0.0)
                if bw <= 0.0:
                    return float("inf")
                t += self.catalog.size(lfn) / bw
            return max(t, self.topology.sites[s].relative_load())

        return min(online, key=lambda s: (cost(s), s))


#: Scheduling-policy registry, keyed by each policy's ``name`` attribute:
#: ``dataaware`` (the paper's §3.2 algorithm), ``random``, ``leastloaded``,
#: ``shortesttransfer``. These names are what ``GridSimulator``,
#: ``run_experiment`` and ``ScenarioSpec.scheduler`` accept.
SCHEDULERS: dict[str, type[SchedulerPolicy]] = {
    c.name: c for c in (DataAwareScheduler, RandomScheduler, LeastLoadedScheduler,
                        ShortestTransferScheduler)
}


def make_scheduler(name: str, catalog: ReplicaCatalog, topology: GridTopology,
                   seed: int = 0) -> SchedulerPolicy:
    """Instantiate a scheduling policy from :data:`SCHEDULERS` by name.

    ``seed`` only matters for stochastic policies (``random``); the rest are
    deterministic functions of catalog + topology state. Raises ``KeyError``
    for unknown names — callers validate against ``SCHEDULERS`` for nicer
    errors (e.g. ``ScenarioSpec.__post_init__``).
    """
    return SCHEDULERS[name](catalog, topology, seed=seed)
