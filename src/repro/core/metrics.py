"""Aggregate metrics + the paper's experiment driver."""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .quantities import GB
from .simulator import GridSimulator, SimResult
from .workload import GridConfig, build_catalog, build_topology, generate_jobs


@dataclasses.dataclass
class ExperimentResult:
    scheduler: str
    strategy: str
    n_jobs: int                  # submitted count (resubmissions not included)
    avg_job_time: float
    avg_inter_comms: float
    total_wan_gb: float
    total_lan_gb: float
    makespan: float
    completed_jobs: int = 0      # jobs that actually produced a record
    # engine-internal counters surfaced per run (PR 9): NetworkEngine
    # kernel stats and the speculative-prefetch ledger
    net_stats: dict = dataclasses.field(default_factory=dict)
    prefetches: int = 0
    prefetch_gb: float = 0.0
    #: :class:`repro.obs.TelemetryReport` when an ``obs=`` mode is on
    telemetry: object | None = None


def run_experiment(
    cfg: GridConfig,
    *,
    scheduler: str = "dataaware",
    strategy: str = "hrs",
    strategy_mode: str = "sequential",
    n_jobs: int | None = None,
    failures: list[tuple[int, float, float]] | None = None,
    slowdowns: list[tuple[int, float, float, float]] | None = None,
    speculative_backups: bool = False,
    broker: str = "event",
    batch_window: float = 0.0,
    arrival_burst: int = 1,
    arrival_times: Sequence[float] | None = None,
    net: str = "numpy",
    econ: str = "numpy",
    econ_interval: float | None = None,
    obs: str | None = None,
    obs_interval: float | None = None,
) -> ExperimentResult:
    """One full simulation run (the unit behind every paper figure).

    Builds the grid described by ``cfg``, bootstraps master replicas,
    submits the generated workload, and runs the discrete-event engine to
    completion. ``scheduler``/``strategy`` name entries in the
    :data:`repro.core.SCHEDULERS` / :data:`repro.core.STRATEGIES`
    registries.

    Arrivals: by default job ``j`` is submitted at ``j * cfg.interarrival``.
    ``arrival_burst`` > 1 submits jobs in bursts of that size (same mean
    arrival rate); combined with ``broker="jax"`` each burst is dispatched
    as one jitted batch decision. ``arrival_times`` (seconds, one per job)
    overrides both — this is how the scenario engine injects Poisson /
    flash-crowd / diurnal arrival processes.

    ``failures`` is a list of ``(site, at, duration)`` outages and
    ``slowdowns`` a list of ``(site, at, duration, factor)`` stragglers;
    see :mod:`repro.fault.failures` for spec-driven generation.

    ``net`` picks the network-engine backend (see
    :data:`repro.core.simulator.NETS`): ``"numpy"`` incremental re-rating,
    ``"pallas"`` the vectorized/kernel full re-rate, ``"topmost"`` the
    legacy single-uplink accounting (fidelity baseline). Identical results
    on two-level grids under all of them.

    ``strategy_mode`` picks the planning engine of the replication
    strategy: ``"sequential"`` (one ``plan_fetch`` per missing file — the
    default, the golden-pinned path) or ``"batch"`` (whole arrival bursts
    planned in one :mod:`repro.kernels.strategy_plan` pass; singleton
    plans are bit-identical to the sequential twin, multi-job bursts
    share one state snapshot — the jax-broker convention).

    ``econ`` picks the value-scoring backend of the replication economy
    (:data:`repro.core.economy.ECON_BACKENDS`, mirroring ``net``) and
    ``econ_interval`` its period in sim seconds — ``None`` arms the
    periodic optimizer only for the access-aware strategies
    (``economic`` / ``predictive``), an explicit value > 0 forces it on
    for any strategy, 0 disables it outright.

    ``obs`` picks the telemetry mode (:data:`repro.obs.OBS_MODES`:
    ``"off"``/``"report"``/``"series"``/``"trace"``; ``None`` defers to
    the ``REPRO_OBS`` env override, default off) and ``obs_interval``
    the sim-seconds between ring-buffer samples. Observation-only: every
    metric above is bit-identical under any mode; the report lands on
    ``ExperimentResult.telemetry``.
    """
    topology = build_topology(
        cfg, path_model="topmost" if net == "topmost" else "full")
    catalog = build_catalog(cfg, topology)
    sim = GridSimulator(topology, catalog, scheduler=scheduler, strategy=strategy,
                        strategy_mode=strategy_mode,
                        seed=cfg.seed, speculative_backups=speculative_backups,
                        broker=broker, batch_window=batch_window, net=net,
                        econ=econ, econ_interval=econ_interval,
                        obs=obs, obs_interval=obs_interval)
    for info in catalog.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    jobs = generate_jobs(cfg, n_jobs)
    if arrival_times is not None and len(arrival_times) < len(jobs):
        raise ValueError(f"arrival_times has {len(arrival_times)} entries "
                         f"for {len(jobs)} jobs")
    for j, job in enumerate(jobs):
        if arrival_times is not None:
            at = float(arrival_times[j])
        else:
            at = (j // arrival_burst) * cfg.interarrival * arrival_burst
        sim.submit_job(job, at=at)
    for site, at, dur in failures or []:
        sim.inject_failure(site, at, dur)
    for site, at, dur, factor in slowdowns or []:
        sim.inject_slowdown(site, at, dur, factor)
    res = sim.run()
    return ExperimentResult(
        scheduler=scheduler, strategy=strategy, n_jobs=len(jobs),
        avg_job_time=res.avg_job_time, avg_inter_comms=res.avg_inter_comms,
        total_wan_gb=res.total_wan_bytes / GB, total_lan_gb=res.total_lan_bytes / GB,
        makespan=res.makespan,
        completed_jobs=len(res.records),
        net_stats=res.net_stats,
        prefetches=res.prefetches,
        prefetch_gb=res.prefetch_bytes / GB,
        telemetry=res.telemetry,
    )
