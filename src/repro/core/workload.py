"""Workload generation per the paper's Table 1.

5 job types; each job requires 12 files out of a catalog of 100 files
(50 GB total / 500 MB each); jobs are drawn uniformly from the 5 types.
Masters are distributed round-robin over sites (the paper does not fix the
initial placement; round-robin across all regions gives every region some
local data, which is the setting where the hierarchy matters).

Reproduction note (see DESIGN.md §8 and EXPERIMENTS.md): with *literally
fixed* 12-file sets per type, the system reaches a static equilibrium — each
type claims one home site holding its whole 6 GB working set (< 10 GB SE),
no eviction ever fires, and all replication strategies coincide exactly. The
paper's reported differences require per-job variation in the accessed
files. We therefore draw each job's 12 files Zipf-weighted from a
type-specific preference order over the catalog (``zipf_alpha``); setting
``zipf_alpha=None`` recovers the degenerate fixed-set reading.
"""

from __future__ import annotations

import dataclasses
import random as _random

from .catalog import ReplicaCatalog
from .quantities import GB, MB, MBPS_TO_BYTES_PER_S
from .scheduler import Job
from .topology import GridTopology


@dataclasses.dataclass
class GridConfig:
    """One experiment's full grid + workload configuration.

    The defaults reproduce the paper's Table 1 exactly: a 4-region x 13-site
    two-level grid with 10 GB SEs, 1000/10 Mbps LAN/WAN, 500 jobs drawn from
    5 types each requiring 12 of 100 x 500 MB files. Bandwidths are in
    bytes/s, sizes in bytes, job length in ops.

    Beyond-paper topology knobs (all default to "off", i.e. the paper grid):

    ``tier_fanouts``
        An n-level tier tree, e.g. ``(2, 4, 7)`` = 2 clusters of 4 groups of
        7 sites. When set it overrides ``n_regions``/``sites_per_region``
        (which describe the two-level special case) and requires
        ``uplink_bandwidths``, one per internal level, top-down.
    ``uplink_scale``
        Per-uplink bandwidth multipliers ``(level, node, factor)`` for
        heterogeneous ("fat-region") fabrics; level 1 is the topmost.
    ``storage_scale``
        Per-region SE-capacity multipliers ``(region, factor)``.

    Instances are usually produced from a named :class:`repro.core.scenarios.
    ScenarioSpec` via ``to_grid_config`` rather than built by hand.
    """

    n_regions: int = 4
    sites_per_region: int = 13
    storage_capacity: float = 10 * GB
    lan_bandwidth: float = 1000.0 * MBPS_TO_BYTES_PER_S
    wan_bandwidth: float = 10.0 * MBPS_TO_BYTES_PER_S
    n_jobs: int = 500
    n_job_types: int = 5
    files_per_job: int = 12
    file_size: float = 500 * MB
    total_file_bytes: float = 50 * GB        # -> 100 distinct files
    job_length: float = 60e9                 # ops; transfer-dominated regime
    interarrival: float = 60.0               # seconds between submissions
    zipf_alpha: float | None = 0.9           # per-job file draw skew (None=fixed sets)
    hotset_shifts: int = 0                   # mid-run hot-set reshuffles (drift)
    seed: int = 0
    # -- beyond-paper topology shape (None/() = the paper's 2-level grid) --
    tier_fanouts: tuple[int, ...] | None = None
    uplink_bandwidths: tuple[float, ...] | None = None   # bytes/s, top-down
    uplink_scale: tuple[tuple[int, int, float], ...] = ()
    storage_scale: tuple[tuple[int, float], ...] = ()

    @property
    def n_files(self) -> int:
        return int(self.total_file_bytes / self.file_size)

    @property
    def n_sites(self) -> int:
        if self.tier_fanouts is not None:
            n = 1
            for f in self.tier_fanouts:
                n *= f
            return n
        return self.n_regions * self.sites_per_region


def build_topology(cfg: GridConfig, path_model: str = "full") -> GridTopology:
    return GridTopology(
        cfg.n_regions, cfg.sites_per_region,
        lan_bandwidth=cfg.lan_bandwidth, wan_bandwidth=cfg.wan_bandwidth,
        storage_capacity=cfg.storage_capacity, seed=cfg.seed,
        tier_fanouts=cfg.tier_fanouts,
        uplink_bandwidths=cfg.uplink_bandwidths,
        uplink_scale=cfg.uplink_scale, storage_scale=cfg.storage_scale,
        path_model=path_model,
    )


def build_catalog(cfg: GridConfig, topology: GridTopology) -> ReplicaCatalog:
    catalog = ReplicaCatalog()
    n_sites = topology.n_sites
    for i in range(cfg.n_files):
        master = (i * 7) % n_sites    # deterministic spread over regions
        catalog.register_file(f"lfn{i:04d}", cfg.file_size, master)
    return catalog


def job_type_filesets(cfg: GridConfig) -> list[list[str]]:
    """Each job type's 12 required files, deterministic under the seed.

    Types overlap partially (drawn without replacement per type from the
    full catalog) — overlap is what makes replication pay off.
    """
    rng = _random.Random(cfg.seed + 1)
    names = [f"lfn{i:04d}" for i in range(cfg.n_files)]
    return [rng.sample(names, cfg.files_per_job) for _ in range(cfg.n_job_types)]


def type_preference_orders(cfg: GridConfig, phase: int = 0) -> list[list[str]]:
    """A preference-ordered permutation of the whole catalog per job type.

    ``phase`` re-seeds the permutation — phase 0 is the classic ordering
    (bit-identical to the pre-``hotset_shifts`` generator); higher phases
    are the shifted hot sets of a drifting workload.
    """
    rng = _random.Random(cfg.seed + 1 + 7919 * phase)
    names = [f"lfn{i:04d}" for i in range(cfg.n_files)]
    orders = []
    for _ in range(cfg.n_job_types):
        perm = list(names)
        rng.shuffle(perm)
        orders.append(perm)
    return orders


def _zipf_draw(rng: _random.Random, order: list[str], k: int, alpha: float,
               cum: list[float]) -> list[str]:
    """k distinct files, position i of `order` weighted 1/(i+1)^alpha."""
    chosen: set[int] = set()
    total = cum[-1]
    while len(chosen) < k:
        u = rng.random() * total
        lo, hi = 0, len(cum) - 1
        while lo < hi:                      # first cum[i] > u
            mid = (lo + hi) // 2
            if cum[mid] > u:
                hi = mid
            else:
                lo = mid + 1
        chosen.add(lo)
    return [order[i] for i in sorted(chosen)]


def generate_jobs(cfg: GridConfig, n_jobs: int | None = None) -> list[Job]:
    rng = _random.Random(cfg.seed + 2)
    n = cfg.n_jobs if n_jobs is None else n_jobs
    jobs = []
    if cfg.zipf_alpha is None:
        if cfg.hotset_shifts:
            raise ValueError("hotset_shifts needs a Zipf workload "
                             "(zipf_alpha=None draws fixed per-type "
                             "filesets, which cannot drift)")
        filesets = job_type_filesets(cfg)
        for j in range(n):
            jt = rng.randrange(cfg.n_job_types)
            jobs.append(Job(job_id=j, job_type=jt, required=list(filesets[jt]),
                            length=cfg.job_length))
        return jobs
    # hot-set drift: the job stream is split into hotset_shifts + 1 equal
    # phases, each drawing from its own preference orders. With the default
    # hotset_shifts=0 this is exactly the classic single-phase generator
    # (same rng consumption, same orders) — bit-identical workloads.
    n_phases = cfg.hotset_shifts + 1
    orders_by_phase = [type_preference_orders(cfg, p) for p in range(n_phases)]
    weights = [1.0 / (i + 1) ** cfg.zipf_alpha for i in range(cfg.n_files)]
    cum = []
    acc = 0.0
    for w in weights:
        acc += w
        cum.append(acc)
    for j in range(n):
        jt = rng.randrange(cfg.n_job_types)
        orders = orders_by_phase[j * n_phases // max(1, n)]
        req = _zipf_draw(rng, orders[jt], cfg.files_per_job, cfg.zipf_alpha, cum)
        jobs.append(Job(job_id=j, job_type=jt, required=req,
                        length=cfg.job_length))
    return jobs
