"""Feed-forward blocks: SwiGLU / GeGLU (gated) and plain GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, normal_init, silu


def init_gated_mlp(kg: KeyGen, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    return {
        "wg": normal_init(kg(), (d_model, d_ff), dtype=dtype),
        "wu": normal_init(kg(), (d_model, d_ff), dtype=dtype),
        "wd": normal_init(kg(), (d_ff, d_model), dtype=dtype),
    }


def gated_mlp(params, x, act: str = "swiglu"):
    fn = silu if act == "swiglu" else jax.nn.gelu
    g = fn(x @ params["wg"])
    u = x @ params["wu"]
    return (g * u) @ params["wd"]


def init_gelu_mlp(kg: KeyGen, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    return {
        "w1": normal_init(kg(), (d_model, d_ff), dtype=dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": normal_init(kg(), (d_ff, d_model), dtype=dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = jax.nn.gelu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]
