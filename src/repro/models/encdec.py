"""Whisper-style encoder-decoder backbone (audio arch).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D); the encoder is the transformer
stack over those frames (non-causal MHA), the decoder is causal self-attn +
cross-attn. LayerNorm + GELU + biases, per the published architecture.

Shape convention (DESIGN.md §5): for a cell with ``seq_len`` S, the encoder
sees S frames and the decoder S // 8 tokens; decode cells decode one token
against a decoder self-KV of S // 8 and cross-KV of S.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import shardctx
from .attention import attention_blockwise, attention_decode
from .common import KeyGen, layer_norm, normal_init
from .mlp import gelu_mlp, init_gelu_mlp

DEC_RATIO = 8   # decoder length = seq_len // DEC_RATIO


def _init_ln(kg, D):
    return {"w": jnp.ones((D,), jnp.float32), "b": jnp.zeros((D,), jnp.float32)}


def _init_mha(kg: KeyGen, cfg: ArchConfig):
    D = cfg.d_model
    hd = cfg.resolved_head_dim()
    H = cfg.n_heads
    return {
        "wq": normal_init(kg(), (D, H * hd)), "bq": jnp.zeros((H * hd,), jnp.bfloat16),
        "wk": normal_init(kg(), (D, H * hd)),
        "wv": normal_init(kg(), (D, H * hd)), "bv": jnp.zeros((H * hd,), jnp.bfloat16),
        "wo": normal_init(kg(), (H * hd, D)), "bo": jnp.zeros((D,), jnp.bfloat16),
    }


def _mha_qkv(cfg, p, xq, xkv):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    hd = cfg.resolved_head_dim()
    H = cfg.n_heads
    q = (xq @ p["wq"] + p["bq"]).reshape(B, Sq, H, hd)
    k = (xkv @ p["wk"]).reshape(B, Skv, H, hd)
    v = (xkv @ p["wv"] + p["bv"]).reshape(B, Skv, H, hd)
    return q, k, v


def _init_enc_block(kg: KeyGen, cfg: ArchConfig):
    return {"ln1": _init_ln(kg, cfg.d_model), "attn": _init_mha(kg, cfg),
            "ln2": _init_ln(kg, cfg.d_model),
            "mlp": init_gelu_mlp(kg, cfg.d_model, cfg.d_ff)}


def _init_dec_block(kg: KeyGen, cfg: ArchConfig):
    return {"ln1": _init_ln(kg, cfg.d_model), "self_attn": _init_mha(kg, cfg),
            "ln2": _init_ln(kg, cfg.d_model), "cross_attn": _init_mha(kg, cfg),
            "ln3": _init_ln(kg, cfg.d_model),
            "mlp": init_gelu_mlp(kg, cfg.d_model, cfg.d_ff)}


def init_encdec_params(cfg: ArchConfig, key, *, max_enc: int, max_dec: int):
    kg = KeyGen(key)

    def stack(init_fn, n):
        blocks = [init_fn(kg, cfg) for _ in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    return {
        "enc_pos": normal_init(kg(), (max_enc, cfg.d_model)),
        "enc_blocks": stack(_init_enc_block, cfg.n_encoder_layers),
        "enc_norm": _init_ln(kg, cfg.d_model),
        "embed": normal_init(kg(), (cfg.vocab, cfg.d_model)),
        "dec_pos": normal_init(kg(), (max_dec, cfg.d_model)),
        "dec_blocks": stack(_init_dec_block, cfg.n_layers),
        "dec_norm": _init_ln(kg, cfg.d_model),
    }


def _ln(p, x):
    return layer_norm(x, p["w"], p["b"])


def encode(cfg: ArchConfig, params, frames, remat: bool = False):
    """frames: (B, S_enc, D) precomputed frame embeddings (stub frontend)."""
    S = frames.shape[1]
    x = shardctx.anchor_batch(frames + params["enc_pos"][None, :S])

    def body(x, bp):
        h = _ln(bp["ln1"], x)
        q, k, v = _mha_qkv(cfg, bp["attn"], h, h)
        o = attention_blockwise(q, k, v, causal=False)
        x = x + o.reshape(*x.shape[:2], -1) @ bp["attn"]["wo"] + bp["attn"]["bo"]
        x = x + gelu_mlp(bp["mlp"], _ln(bp["ln2"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return _ln(params["enc_norm"], x)


def decode_train(cfg: ArchConfig, params, enc_out, tokens, remat: bool = False):
    """Teacher-forced decoder forward. tokens: (B, S_dec). Returns hidden."""
    S = tokens.shape[1]
    x = shardctx.anchor_batch(
        jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][None, :S])

    def body(x, bp):
        h = _ln(bp["ln1"], x)
        q, k, v = _mha_qkv(cfg, bp["self_attn"], h, h)
        o = attention_blockwise(q, k, v, causal=True)
        x = x + o.reshape(*x.shape[:2], -1) @ bp["self_attn"]["wo"] \
            + bp["self_attn"]["bo"]
        h = _ln(bp["ln2"], x)
        q, k, v = _mha_qkv(cfg, bp["cross_attn"], h, enc_out)
        o = attention_blockwise(q, k, v, causal=False)
        x = x + o.reshape(*x.shape[:2], -1) @ bp["cross_attn"]["wo"] \
            + bp["cross_attn"]["bo"]
        x = x + gelu_mlp(bp["mlp"], _ln(bp["ln3"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return _ln(params["dec_norm"], x)


def init_decoder_caches(cfg: ArchConfig, batch: int, max_dec: int, max_enc: int):
    hd = cfg.resolved_head_dim()
    H = cfg.n_heads
    L = cfg.n_layers
    return {
        "self_k": jnp.zeros((L, batch, max_dec, H, hd), jnp.bfloat16),
        "self_v": jnp.zeros((L, batch, max_dec, H, hd), jnp.bfloat16),
        "cross_k": jnp.zeros((L, batch, max_enc, H, hd), jnp.bfloat16),
        "cross_v": jnp.zeros((L, batch, max_enc, H, hd), jnp.bfloat16),
    }


def precompute_cross_caches(cfg: ArchConfig, params, enc_out):
    """Cross K/V per decoder layer from encoder output (once per request)."""
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim()
    H = cfg.n_heads

    def body(_, bp):
        k = (enc_out @ bp["cross_attn"]["wk"]).reshape(B, S, H, hd)
        v = (enc_out @ bp["cross_attn"]["wv"] + bp["cross_attn"]["bv"]) \
            .reshape(B, S, H, hd)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["dec_blocks"])
    return ks.astype(jnp.bfloat16), vs.astype(jnp.bfloat16)


def decode_step(cfg: ArchConfig, params, caches, token, pos):
    """One decoder token. token: (B, 1) int32; pos: scalar int32."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0) \
        + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1)[None]
    hd = cfg.resolved_head_dim()
    H = cfg.n_heads

    def body(x, xs):
        bp, sk, sv, ck, cv = xs
        h = _ln(bp["ln1"], x)
        q = (h @ bp["self_attn"]["wq"] + bp["self_attn"]["bq"]).reshape(B, 1, H, hd)
        k = (h @ bp["self_attn"]["wk"]).reshape(B, 1, H, hd)
        v = (h @ bp["self_attn"]["wv"] + bp["self_attn"]["bv"]).reshape(B, 1, H, hd)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), pos, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), pos, axis=1)
        o = attention_decode(q, sk, sv, cache_len=pos)
        x = x + o.reshape(B, 1, -1) @ bp["self_attn"]["wo"] + bp["self_attn"]["bo"]
        h = _ln(bp["ln2"], x)
        q = (h @ bp["cross_attn"]["wq"] + bp["cross_attn"]["bq"]).reshape(B, 1, H, hd)
        o = attention_decode(q, ck, cv, cache_len=ck.shape[1] - 1)
        x = x + o.reshape(B, 1, -1) @ bp["cross_attn"]["wo"] + bp["cross_attn"]["bo"]
        x = x + gelu_mlp(bp["mlp"], _ln(bp["ln3"], x))
        return x, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        body, x, (params["dec_blocks"], caches["self_k"], caches["self_v"],
                  caches["cross_k"], caches["cross_v"]))
    x = _ln(params["dec_norm"], x)
    logits = x @ params["embed"].T
    new_caches = dict(caches, self_k=new_sk, self_v=new_sv)
    return logits, new_caches
