"""GQA attention: reference, blockwise (flash-style jnp), and decode paths.

``attention_blockwise`` is the model default for training/prefill: an online
softmax over KV chunks inside a ``lax.scan``, so peak memory is
O(q_chunk x kv_chunk) rather than O(S^2) — required for the 32k prefill
dry-runs (a full 32k x 32k score tensor would not fit any HBM). On real TPU
the Pallas flash kernel (repro.kernels.flash_attention) replaces it; both
match ``attention_reference`` which is the oracle in tests.

All functions take q: (B, Sq, H, hd) and k, v: (B, Skv, KV, hd) with
H = G * KV (grouped-query attention), and support:
  * causal masking with a query position offset (prefill/decode),
  * sliding-window locality (gemma local layers),
  * logit soft-capping (gemma2),
  * non-causal (whisper encoder / cross attention).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import softcap as _softcap

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal: bool, window: int | None, kv_len=None):
    """(..., Sq, Skv) boolean mask of *allowed* positions."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def attention_reference(q, k, v, *, causal=True, window=None, softcap=None,
                        q_offset=0, kv_len=None):
    """Materialized-scores oracle. Only for small shapes/tests."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        scores = _softcap(scores, softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = _mask(q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_blockwise(q, k, v, *, causal=True, window=None, softcap=None,
                        q_offset=0, kv_len=None,
                        q_chunk=1024, kv_chunk=1024):
    """Flash-style online-softmax attention in pure jnp.

    Scans over KV chunks per Q chunk, carrying (running max, running sum,
    running output). Equivalent to attention_reference to within bf16/f32
    rounding; memory is O(q_chunk*kv_chunk) per step.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad S to chunk multiples
    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sqp, Skvp = Sq + pq, Skv + pk
    nq, nk = Sqp // q_chunk, Skvp // kv_chunk
    scale = hd ** -0.5
    # effective kv length: padded keys are invalid
    eff_kv = jnp.minimum(jnp.asarray(Skv), kv_len) if kv_len is not None else Skv

    qg = q.reshape(B, nq, q_chunk, KV, G, hd)
    kg = k.reshape(B, nk, kv_chunk, KV, hd)
    vg = v.reshape(B, nk, kv_chunk, KV, hd)

    # band-limited iteration for sliding-window layers: a q chunk at block
    # qi only attends to kv blocks in [qi*qc - window - kc, (qi+1)*qc), so
    # the kv scan runs over a fixed-size band gathered with dynamic slices
    # instead of the full sequence — S/(window+qc) x fewer score tiles
    # (8x for gemma3's 512-token window at 4k).
    band = None
    if window is not None and causal:
        band = min(nk, (q_chunk + window) // kv_chunk + 2)

    def per_batch(qb, kb, vb):
        # qb: (nq, qc, KV, G, hd); kb, vb: (nk, kc, KV, hd)
        def q_block(args):
            qi, qc = args
            q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
            if band is not None:
                last = ((qi + 1) * q_chunk - 1) // kv_chunk
                start = jnp.clip(last - band + 1, 0, nk - band)
            else:
                start = 0

            def kv_step(carry, j):
                m_run, l_run, acc = carry
                ki = start + j
                kc = jax.lax.dynamic_index_in_dim(kb, ki, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vb, ki, keepdims=False)
                k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.einsum("qkgd,skd->kgqs", qc.astype(jnp.float32),
                               kc.astype(jnp.float32)) * scale
                if softcap is not None:
                    s = _softcap(s, softcap)
                mask = _mask(q_pos, k_pos, causal=causal, window=window,
                             kv_len=eff_kv)
                s = jnp.where(mask[None, None], s, NEG_INF)
                m_new = jnp.maximum(m_run, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m_run - m_new)
                l_new = l_run * corr + p.sum(axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "kgqs,skd->kgqd", p, vc.astype(jnp.float32))
                return (m_new, l_new, acc), None

            m0 = jnp.full((KV, G, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((KV, G, q_chunk), jnp.float32)
            a0 = jnp.zeros((KV, G, q_chunk, hd), jnp.float32)
            # checkpoint each KV block: without it, AD saves every block's
            # (qc x kc) score tensor for the backward pass — O(S^2) memory,
            # exactly what blockwise attention exists to avoid.
            n_steps = band if band is not None else nk
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(kv_step), (m0, l0, a0), jnp.arange(n_steps))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return out.transpose(2, 0, 1, 3)        # (q_chunk, KV, G, hd)

        out = jax.lax.map(q_block, (jnp.arange(nq), qb))
        return out.reshape(Sqp, KV, G, hd)

    out = jax.vmap(per_batch)(qg, kg, vg)
    out = out[:, :Sq].reshape(B, Sq, H, hd).astype(q.dtype)
    return out


def attention_decode(q, k_cache, v_cache, *, cache_len, window=None,
                     softcap=None):
    """One-token decode: q (B, 1, H, hd) against caches (B, S, KV, hd).

    ``cache_len`` is the number of valid cache entries; the new token's
    position is cache_len (its own K/V must already be written at that slot
    by the caller). Linear in S; no blocking needed.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = _softcap(s, softcap)
    k_pos = jnp.arange(k_cache.shape[1])
    valid = k_pos[None] <= cache_len                   # includes current token
    if window is not None:
        valid &= k_pos[None] > cache_len - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)
