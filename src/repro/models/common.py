"""Shared layers: norms, RoPE, activations, initializers.

Dtype policy: parameters and activations in bf16; norms, softmax, RoPE and
the loss in f32 (standard TPU mixed-precision discipline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Param = jnp.ndarray


def rms_norm(x: jnp.ndarray, weight: Param, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jnp.ndarray, weight: Param, bias: Param,
               eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S) or (S,)."""
    dtype = x.dtype
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq            # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]                                 # (B,S,1,half)
    sin = jnp.sin(ang)[:, :, None, :]
    x = x.astype(jnp.float32)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.nn.sigmoid(x)


ACTS = {"swiglu": silu, "geglu": jax.nn.gelu, "gelu": jax.nn.gelu}


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# -- initializers -----------------------------------------------------------

def normal_init(key, shape, scale: float = 0.02, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype)


class KeyGen:
    """Sequential PRNG key dispenser for bulk param init."""

    def __init__(self, key: jax.Array) -> None:
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub
