"""Decoder-only LM assembled from an ArchConfig.

The layer stack is organized as ``n_groups`` identical *groups* scanned with
``lax.scan`` (weights stacked on a leading group axis) plus an unrolled
remainder. A group is a short statically-unrolled pattern of blocks — e.g.
gemma2 = (local, global), gemma3 = (local x5, global), zamba2 =
(mamba2 x6, shared_attn) — which keeps heterogeneous architectures inside a
single scan so HLO size is O(pattern), not O(n_layers).

Three modes share the same block code:
  * train:   causal forward, no caches;
  * prefill: causal forward, returns per-layer KV/state caches;
  * decode:  one token against the caches (``pos`` = current length).

zamba2's ``shared_attn`` blocks share one physical weight set across all
groups (passed as a closed-over constant in the scan body) while each group
application keeps its own KV cache (stacked, scanned) — matching the
published architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .attention import attention_blockwise, attention_decode
from .common import KeyGen, normal_init, rms_norm, layer_norm, rope
from .mamba import (init_mamba1, init_mamba2, mamba1_forward, mamba2_forward)
from .mlp import gated_mlp, gelu_mlp, init_gated_mlp, init_gelu_mlp
from .moe import init_moe, moe_ffn


# --------------------------------------------------------------------------
# per-block init
# --------------------------------------------------------------------------

def _init_attn_block(kg: KeyGen, cfg: ArchConfig, with_mlp: bool = True):
    D = cfg.d_model
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    p: dict[str, Any] = {
        "ln1": jnp.zeros((D,), jnp.float32),
        "wq": normal_init(kg(), (D, H * hd)),
        "wk": normal_init(kg(), (D, KV * hd)),
        "wv": normal_init(kg(), (D, KV * hd)),
        "wo": normal_init(kg(), (H * hd, D)),
        "ln2": jnp.zeros((D,), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((KV * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((KV * hd,), jnp.bfloat16)
    if with_mlp:
        if cfg.moe is not None:
            p["moe"] = init_moe(kg, D, cfg.d_ff, cfg.moe.num_experts,
                                cfg.moe.dense_residual)
        elif cfg.act == "gelu":
            p["mlp"] = init_gelu_mlp(kg, D, cfg.d_ff)
        else:
            p["mlp"] = init_gated_mlp(kg, D, cfg.d_ff)
    return p


def init_block(kg: KeyGen, cfg: ArchConfig, kind: str):
    if kind in ("attn", "attn_local", "shared_attn"):
        return _init_attn_block(kg, cfg)
    if kind == "mamba1":
        return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "mixer": init_mamba1(kg, cfg.d_model, cfg.ssm)}
    if kind == "mamba2":
        return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "mixer": init_mamba2(kg, cfg.d_model, cfg.ssm)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# per-block apply
# --------------------------------------------------------------------------

def _norm(cfg: ArchConfig, p, x):
    return rms_norm(x, p)    # decoder-only archs here are all RMSNorm


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    hd = cfg.resolved_head_dim()
    if kind in ("attn", "attn_local", "shared_attn"):
        shape = (batch, max_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, jnp.bfloat16),
                "v": jnp.zeros(shape, jnp.bfloat16)}
    ssm = cfg.ssm
    di = ssm.d_inner(cfg.d_model)
    K = ssm.conv_kernel
    if kind == "mamba1":
        return {"conv": jnp.zeros((batch, K - 1, di), jnp.bfloat16),
                "ssm": jnp.zeros((batch, di, ssm.state_dim), jnp.float32)}
    if kind == "mamba2":
        nh = di // ssm.head_dim
        N = ssm.state_dim
        return {"conv": {"x": jnp.zeros((batch, K - 1, di), jnp.bfloat16),
                         "B": jnp.zeros((batch, K - 1, N), jnp.bfloat16),
                         "C": jnp.zeros((batch, K - 1, N), jnp.bfloat16)},
                "ssm": jnp.zeros((batch, nh, ssm.head_dim, N), jnp.float32)}
    raise ValueError(kind)


def apply_block(cfg: ArchConfig, kind: str, p, x, *, mode: str,
                cache=None, pos=None):
    """Returns (x, new_cache). new_cache is None in train mode."""
    if kind in ("attn", "attn_local", "shared_attn"):
        return _apply_attn(cfg, kind, p, x, mode=mode, cache=cache, pos=pos)
    ln = p["ln"]
    mixer = p["mixer"]
    h = _norm(cfg, ln, x)
    fwd = mamba1_forward if kind == "mamba1" else mamba2_forward
    to_bf16 = lambda c: jax.tree.map(lambda a: a.astype(jnp.bfloat16), c)
    if mode == "train":
        y, _ = fwd(mixer, h, cfg.ssm, chunk=cfg.scan_chunk)
        return x + y, None
    if mode == "prefill":
        y, (conv, ssm_state) = fwd(mixer, h, cfg.ssm, chunk=cfg.scan_chunk)
        return x + y, {"conv": to_bf16(conv), "ssm": ssm_state}
    # decode
    y, (conv, ssm_state) = fwd(mixer, h, cfg.ssm,
                               state=(cache["conv"], cache["ssm"]))
    return x + y, {"conv": to_bf16(conv), "ssm": ssm_state}


def _apply_attn(cfg: ArchConfig, kind: str, p, x, *, mode, cache, pos):
    B, S, D = x.shape
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    window = cfg.local_window if kind == "attn_local" else None

    h = _norm(cfg, p["ln1"], x)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if mode == "decode":
        positions = jnp.full((B, 1), pos, jnp.int32)
    else:
        positions = jnp.arange(S)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "train":
        o = attention_blockwise(q, k, v, causal=True, window=window,
                                softcap=cfg.attn_softcap,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk)
    elif mode == "prefill":
        o = attention_blockwise(q, k, v, causal=True, window=window,
                                softcap=cfg.attn_softcap,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk)
        new_cache = {"k": k, "v": v}
    else:  # decode: write the new token's k/v at slot ``pos``
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        o = attention_decode(q, kc, vc, cache_len=pos, window=window,
                             softcap=cfg.attn_softcap)
        new_cache = {"k": kc, "v": vc}
    x = x + (o.reshape(B, S, H * hd) @ p["wo"])

    h2 = _norm(cfg, p["ln2"], x)
    if cfg.moe is not None and "moe" in p:
        y = moe_ffn(p["moe"], h2, top_k=cfg.moe.top_k,
                    capacity_factor=cfg.moe.capacity_factor)
        if cfg.moe.dense_residual:
            y = y + gated_mlp(p["moe"]["res"], h2, cfg.act)
    elif cfg.act == "gelu":
        y = gelu_mlp(p["mlp"], h2)
    else:
        y = gated_mlp(p["mlp"], h2, cfg.act)
    return x + y, new_cache


# --------------------------------------------------------------------------
# full stack
# --------------------------------------------------------------------------

def init_lm_params(cfg: ArchConfig, key) -> dict:
    kg = KeyGen(key)
    pattern = cfg.layer_pattern
    has_shared = "shared_attn" in pattern

    def one_group():
        return {f"b{i}": (None if k == "shared_attn" else init_block(kg, cfg, k))
                for i, k in enumerate(pattern)}

    # stacked group weights: init G copies and stack leaves
    groups = [one_group() for _ in range(cfg.n_groups)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) if cfg.n_groups \
        else {}
    # drop shared placeholders (None) from the stacked tree
    stacked = {k: v for k, v in stacked.items() if v is not None} \
        if isinstance(stacked, dict) else stacked

    params: dict[str, Any] = {
        "embed": normal_init(kg(), (cfg.vocab, cfg.d_model)),
        "groups": stacked,
        "rest": [init_block(kg, cfg, k) for k in cfg.remainder_pattern],
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if has_shared:
        params["shared_attn"] = init_block(kg, cfg, "shared_attn")
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(kg(), (cfg.d_model, cfg.vocab))
    return params


def _group_body(cfg: ArchConfig, mode: str, shared, pos):
    """Returns the scan body over one group. xs = (group_params, group_cache)."""
    pattern = cfg.layer_pattern

    def body(x, xs):
        gp, gcache = xs
        new_caches = {}
        for i, kind in enumerate(pattern):
            p = shared if kind == "shared_attn" else gp[f"b{i}"]
            c = None if gcache is None else gcache.get(f"b{i}")
            x, nc = apply_block(cfg, kind, p, x, mode=mode, cache=c, pos=pos)
            if nc is not None:
                new_caches[f"b{i}"] = nc
        return x, (new_caches if new_caches else None)

    return body


def lm_apply(cfg: ArchConfig, params, x, *, mode: str, caches=None, pos=None,
             remat: bool = False):
    """Run the full stack on hidden states x (B, S, D).

    caches: {"groups": stacked-per-group cache pytree, "rest": [cache, ...]}
    Returns (hidden, new_caches or None). ``remat=True`` checkpoints each
    scanned group (training memory = one group's activations).
    """
    shared = params.get("shared_attn")
    body = _group_body(cfg, mode, shared, pos)
    gcaches = None if caches is None else caches["groups"]
    if cfg.n_groups > 0:
        if mode == "train":
            train_body = lambda c, gp: (body(c, (gp, None))[0], None)
            if remat:
                train_body = jax.checkpoint(train_body)
            x, _ = jax.lax.scan(train_body, x, params["groups"])
            new_group_caches = None
        elif mode == "prefill":
            x, stacked = jax.lax.scan(lambda c, xs: body(c, xs), x,
                                      (params["groups"], None))
            # caches live as a LIST of per-group trees: decode updates them
            # in place per group (unrolled), which lets XLA alias the
            # donated buffers instead of double-buffering a stacked tensor.
            new_group_caches = [
                jax.tree.map(lambda t, g=g: t[g], stacked)
                for g in range(cfg.n_groups)
            ]
        else:  # decode: unrolled loop, per-group cache aliasing
            new_group_caches = []
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda t, g=g: t[g], params["groups"])
                x, nc = body(x, (gp, gcaches[g]))
                new_group_caches.append(nc)
    else:
        new_group_caches = gcaches

    new_rest = []
    for i, kind in enumerate(cfg.remainder_pattern):
        c = None if caches is None else caches["rest"][i]
        if remat and mode == "train":
            fn = jax.checkpoint(
                lambda p, h, _cfg=cfg, _kind=kind:
                apply_block(_cfg, _kind, p, h, mode="train")[0])
            x, nc = fn(params["rest"][i], x), None
        else:
            x, nc = apply_block(cfg, kind, params["rest"][i], x, mode=mode,
                                cache=c, pos=pos)
        new_rest.append(nc)
    x = rms_norm(x, params["final_norm"])
    if mode == "train":
        return x, None
    return x, {"groups": new_group_caches, "rest": new_rest}


def init_lm_caches(cfg: ArchConfig, batch: int, max_len: int):
    pattern = cfg.layer_pattern

    def one_group():
        return {f"b{i}": init_block_cache(cfg, k, batch, max_len)
                for i, k in enumerate(pattern)}

    groups = [one_group() for _ in range(cfg.n_groups)]
    rest = [init_block_cache(cfg, k, batch, max_len)
            for k in cfg.remainder_pattern]
    return {"groups": groups, "rest": rest}
