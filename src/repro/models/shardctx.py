"""Activation-sharding context for model code.

SPMD propagation loses the batch sharding at a few ops (most notably the
embedding gather over a vocab-sharded table, where XLA falls back to
"involuntary full rematerialization" and emits a replicated result). Every
activation downstream then computes replicated over the data axis — a
silent dp-x compute/memory multiplier.

The launcher installs the mesh here before lowering; model code calls
``anchor_batch`` at a handful of propagation roots (post-embedding, post
layer-stack, CE chunks). On a single device (tests, examples) the context
is unset and everything is a no-op.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None
_DP: tuple[str, ...] = ("data",)


def set_mesh(mesh, dp_axes: tuple[str, ...]) -> None:
    global _MESH, _DP
    _MESH = mesh
    _DP = tuple(dp_axes)


def clear() -> None:
    global _MESH
    _MESH = None


def dp_size() -> int:
    if _MESH is None:
        return 1
    sizes = dict(zip(_MESH.axis_names, _MESH.devices.shape))
    out = 1
    for a in _DP:
        out *= sizes.get(a, 1)
    return out


def anchor_batch(x, batch_axis: int = 0):
    """Constrain dim ``batch_axis`` of x to the data axes (if divisible)."""
    if _MESH is None or x is None:
        return x
    n = dp_size()
    if x.shape[batch_axis] % n or x.shape[batch_axis] < n:
        return x
    spec = [None] * x.ndim
    spec[batch_axis] = _DP
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))
