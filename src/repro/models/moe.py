"""Mixture-of-Experts FFN with capacity-bucketed sort-based dispatch.

TPU adaptation notes: GPU MoE implementations scatter tokens with
per-expert dynamic buffers; on TPU everything must be static-shaped, so we
use the standard grouped-einsum formulation (as in MaxText/Mixtral-JAX):

  1. top-k routing over E experts (softmax over the selected k);
  2. sort expanded token-slots by expert id; position-within-expert via a
     cumulative count, dropping tokens beyond ``capacity``;
  3. scatter into a dense (E, C, D) buffer, one grouped einsum per FFN
     matmul with the expert dimension sharded over the ``model`` mesh axis
     (expert parallelism), gather-combine weighted by the gates.

Everything is differentiable (gather/scatter-add); dropped tokens simply
contribute their residual stream unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, normal_init, silu


def init_moe(kg: KeyGen, d_model: int, d_ff: int, num_experts: int,
             dense_residual: bool, dtype=jnp.bfloat16):
    p = {
        "router": normal_init(kg(), (d_model, num_experts), scale=0.02,
                              dtype=jnp.float32),
        "wg": normal_init(kg(), (num_experts, d_model, d_ff), dtype=dtype),
        "wu": normal_init(kg(), (num_experts, d_model, d_ff), dtype=dtype),
        "wd": normal_init(kg(), (num_experts, d_ff, d_model), dtype=dtype),
    }
    if dense_residual:
        p["res"] = {
            "wg": normal_init(kg(), (d_model, d_ff), dtype=dtype),
            "wu": normal_init(kg(), (d_model, d_ff), dtype=dtype),
            "wd": normal_init(kg(), (d_ff, d_model), dtype=dtype),
        }
    return p


def _dispatch_one_row(xt, router, wg, wu, wd, *, top_k: int, C: int, act):
    """Sort-based dispatch for ONE batch row. xt: (S, D)."""
    S, D = xt.shape
    E = router.shape[-1]
    logits = xt.astype(jnp.float32) @ router                     # (S, E)
    gates, idx = jax.lax.top_k(logits, top_k)                    # (S, k)
    gates = jax.nn.softmax(gates, axis=-1)                       # renormalize

    flat_e = idx.reshape(-1)                                     # (S*k,)
    order = jnp.argsort(flat_e)                                  # stable
    sorted_e = flat_e[order]
    ranks = jnp.arange(S * top_k)
    counts = jnp.bincount(sorted_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = ranks - starts[sorted_e]

    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)       # overflow bin
    token_of = order // top_k
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(xt[token_of])
    buf = buf[: E * C].reshape(E, C, D)

    g = act(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", g * u, wd)                    # (E, C, D)

    yf = y.reshape(E * C, D)
    flat_gate = gates.reshape(-1)[order]
    contrib = jnp.where(
        keep[:, None], yf[jnp.clip(slot, 0, E * C - 1)], 0.0
    ) * flat_gate[:, None].astype(xt.dtype)
    return jnp.zeros((S, D), xt.dtype).at[token_of].add(contrib)


def moe_ffn(params, x, *, top_k: int, capacity_factor: float = 1.25,
            act=silu):
    """x: (B, S, D) -> (B, S, D).

    Dispatch is LOCAL per batch row (vmap over B): a global argsort over
    all B*S tokens would run on the batch-sharded token stream and drag
    all-gathers/all-to-alls through every layer; per-row sort keeps the
    whole routing computation on the row's own shard. Capacity is per-row
    (S*k/E*factor), so the kept-token semantics match per-shard dispatch
    on a real EP deployment. Expert weights stay sharded over ``model``
    (expert parallelism); the grouped einsums contract locally per expert
    shard."""
    from . import shardctx
    B, S, D = x.shape
    E = params["router"].shape[-1]
    C = int(max(1, int(S * top_k / E * capacity_factor)))
    # anchor around the vmapped dispatch: the data-dependent token gather
    # inside is another SPMD gather-fallback site that would otherwise
    # replicate the expanded (B, S*k, D) stream over the data axis
    x = shardctx.anchor_batch(x)
    out = jax.vmap(
        lambda row: _dispatch_one_row(
            row, params["router"], params["wg"], params["wu"], params["wd"],
            top_k=top_k, C=C, act=act))(x)
    return shardctx.anchor_batch(out)


def moe_aux_loss(params, x, *, top_k: int) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)."""
    B, S, D = x.shape
    E = params["router"].shape[-1]
    logits = x.reshape(-1, D).astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    _, idx = jax.lax.top_k(logits, top_k)
    hard = jax.nn.one_hot(idx, E).sum(axis=1)                    # (T, E)
    f = hard.mean(axis=0)
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)
