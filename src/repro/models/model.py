"""Model facade: one entry point per mode for every architecture family.

  init_params(cfg, key)                 -> params pytree
  train_logits(cfg, params, batch)      -> (B, S, V) f32
  loss_fn(cfg, params, batch)           -> scalar loss, metrics
  prefill(cfg, params, batch)           -> (last_logits, caches)
  decode_step(cfg, params, caches, token, pos) -> (logits, caches)
  init_caches(cfg, batch, max_len)      -> cache pytree
  input_specs(cfg, shape)               -> ShapeDtypeStruct batch for dry-runs

``batch`` is a dict: tokens/labels for LMs; + vision_embeds (vlm stub) or
frames (audio stub).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from . import encdec, shardctx
from .common import KeyGen, normal_init, rms_norm, softcap
from .transformer import (apply_block, init_block, init_lm_caches,
                          init_lm_params, lm_apply)

DEC_RATIO = encdec.DEC_RATIO


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key, *, max_enc: int = 4096,
                max_dec: int = 512):
    if cfg.enc_dec:
        return encdec.init_encdec_params(cfg, key, max_enc=max_enc,
                                         max_dec=max_dec)
    return init_lm_params(cfg, key)


def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.enc_dec:
        return encdec.init_decoder_caches(cfg, batch,
                                          max_dec=max(1, max_len // DEC_RATIO),
                                          max_enc=max_len)
    return init_lm_caches(cfg, batch, max_len)


# --------------------------------------------------------------------------
# embedding front ends (modality stubs live here)
# --------------------------------------------------------------------------

def _embed_tokens(cfg: ArchConfig, params, batch):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.vision_tokens and "vision_embeds" in batch:
        # VLM stub: first ``vision_tokens`` positions carry patch embeddings
        v = batch["vision_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, v, (0, 0, 0))
    # the gather over the vocab-sharded table emits a replicated result
    # (SPMD fallback); re-anchor the batch sharding or every downstream
    # activation computes dp-x replicated.
    return shardctx.anchor_batch(x)


def _lm_logits(cfg: ArchConfig, params, hidden):
    if cfg.tie_embeddings:
        logits = hidden @ params["embed"].T
    else:
        logits = hidden @ params["lm_head"]
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits


# --------------------------------------------------------------------------
# train / loss
# --------------------------------------------------------------------------

def _train_hidden(cfg: ArchConfig, params, batch, remat: bool = False):
    if cfg.enc_dec:
        enc_out = encdec.encode(cfg, params, batch["frames"], remat=remat)
        return encdec.decode_train(cfg, params, enc_out, batch["tokens"],
                                   remat=remat)
    x = _embed_tokens(cfg, params, batch)
    hidden, _ = lm_apply(cfg, params, x, mode="train", remat=remat)
    return hidden


def train_logits(cfg: ArchConfig, params, batch, remat: bool = False):
    hidden = _train_hidden(cfg, params, batch, remat=remat)
    if cfg.enc_dec:
        return hidden @ params["embed"].T
    return _lm_logits(cfg, params, hidden)


def _head_matrix(cfg: ArchConfig, params):
    return params["embed"].T if cfg.tie_embeddings or cfg.enc_dec \
        else params["lm_head"]


def chunked_cross_entropy(cfg: ArchConfig, params, hidden, labels,
                          chunk: int = 512):
    """Token-chunked CE: the (tokens x vocab) logits tensor never exists in
    full — each chunk's logits are computed, reduced and (via checkpoint)
    recomputed in the backward pass. The gold logit uses an iota mask, not
    a gather, so the vocab axis stays sharded."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    nc = S // chunk if S % chunk == 0 else 1
    chunk = S // nc
    hs = hidden.reshape(B, nc, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    head = _head_matrix(cfg, params)

    def body(acc, xs):
        h, l = xs
        h = shardctx.anchor_batch(h)           # chunk transpose drops it
        logits = (h @ head).astype(jnp.float32)
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(iota == l[..., None], logits, 0.0), axis=-1)
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.float32(0.0), (hs, ls))
    return total / (B * S)


def loss_fn(cfg: ArchConfig, params, batch, remat: bool = False):
    hidden = shardctx.anchor_batch(
        _train_hidden(cfg, params, batch, remat=remat))
    nll = chunked_cross_entropy(cfg, params, hidden, batch["labels"])
    metrics = {"loss": nll, "perplexity": jnp.exp(nll)}
    return nll, metrics


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params, batch):
    """Full-prompt forward that returns caches + last-position logits."""
    if cfg.enc_dec:
        enc_out = encdec.encode(cfg, params, batch["frames"])
        ck, cv = encdec.precompute_cross_caches(cfg, params, enc_out)
        B = enc_out.shape[0]
        dec_len = max(1, batch["frames"].shape[1] // DEC_RATIO)
        hd = cfg.resolved_head_dim()
        caches = {
            "self_k": jnp.zeros((cfg.n_layers, B, dec_len, cfg.n_heads, hd),
                                jnp.bfloat16),
            "self_v": jnp.zeros((cfg.n_layers, B, dec_len, cfg.n_heads, hd),
                                jnp.bfloat16),
            "cross_k": ck, "cross_v": cv,
        }
        bos = jnp.zeros((B, 1), jnp.int32)
        logits, caches = encdec.decode_step(cfg, params, caches, bos,
                                            jnp.int32(0))
        return logits, caches
    x = _embed_tokens(cfg, params, batch)
    hidden, caches = lm_apply(cfg, params, x, mode="prefill")
    logits = _lm_logits(cfg, params, hidden[:, -1:])
    return logits, caches


def decode_step(cfg: ArchConfig, params, caches, token, pos):
    """token: (B, 1) int32; pos: scalar int32 (current cache length)."""
    if cfg.enc_dec:
        return encdec.decode_step(cfg, params, caches, token, pos)
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    hidden, new_caches = lm_apply(cfg, params, x, mode="decode",
                                  caches=caches, pos=pos)
    return _lm_logits(cfg, params, hidden), new_caches


# --------------------------------------------------------------------------
# dry-run input specs
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.enc_dec:
            return {"frames": f((B, S, cfg.d_model), jnp.bfloat16),
                    "tokens": f((B, S // DEC_RATIO), jnp.int32),
                    "labels": f((B, S // DEC_RATIO), jnp.int32)}
        batch = {"tokens": f((B, S), jnp.int32), "labels": f((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        if cfg.enc_dec:
            return {"frames": f((B, S, cfg.d_model), jnp.bfloat16)}
        batch = {"tokens": f((B, S), jnp.int32)}
    else:  # decode: inputs are (caches, token, pos); caches specs built via
        # eval_shape in the launcher
        batch = {"tokens": f((B, 1), jnp.int32)}
    if cfg.vision_tokens and not cfg.enc_dec and shape.kind != "decode":
        batch["vision_embeds"] = f((B, cfg.vision_tokens, cfg.d_model),
                                   jnp.bfloat16)
    return batch


# --------------------------------------------------------------------------
# analytic parameter counts (roofline bookkeeping)
# --------------------------------------------------------------------------

def count_params_analytic(cfg: ArchConfig, active_only: bool = False) -> int:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads

    def attn_block(mlp: bool = True) -> int:
        n = D * H * hd * 2 + D * KV * hd * 2 + 2 * D
        if cfg.qkv_bias:
            n += H * hd + 2 * KV * hd
        if not mlp:
            return n
        if cfg.moe is not None:
            e = cfg.moe.top_k if active_only else cfg.moe.num_experts
            n += D * cfg.moe.num_experts + e * 3 * D * F
            if cfg.moe.dense_residual:
                n += 3 * D * F
        elif cfg.act == "gelu":
            n += 2 * D * F + F + D
        else:
            n += 3 * D * F
        return n

    def mamba1_block() -> int:
        ssm = cfg.ssm
        di = ssm.d_inner(D)
        dtr = ssm.resolved_dt_rank(D)
        N = ssm.state_dim
        return (D * 2 * di + di * ssm.conv_kernel + di
                + di * (dtr + 2 * N) + dtr * di + di + di * N + di
                + di * D + D)

    def mamba2_block() -> int:
        ssm = cfg.ssm
        di = ssm.d_inner(D)
        N = ssm.state_dim
        nh = di // ssm.head_dim
        conv_dim = di + 2 * N
        return (D * (2 * di + 2 * N + nh) + conv_dim * ssm.conv_kernel
                + conv_dim + 3 * nh + di + di * D + D)

    kind_count = {"attn": attn_block, "attn_local": attn_block,
                  "mamba1": mamba1_block, "mamba2": mamba2_block}
    total = 0
    full = list(cfg.layer_pattern) * cfg.n_groups + list(cfg.remainder_pattern)
    shared_counted = False
    for kind in full:
        if kind == "shared_attn":
            if not shared_counted:
                total += attn_block()
                shared_counted = True
            continue
        total += kind_count[kind]()
    total += V * D                       # embeddings
    if not cfg.tie_embeddings:
        total += D * V
    total += D                           # final norm
    if cfg.enc_dec:
        # encoder stack (MHA + gelu) + positional tables
        enc_block = D * H * hd * 4 + 2 * D * F + F + D + 4 * D
        total += cfg.n_encoder_layers * enc_block
        # decoder cross-attn already excluded from `full` (enc_dec uses its
        # own path); approximate: + cross attn per decoder layer
        total += cfg.n_layers * (D * H * hd * 4 + 4 * D)
    return int(total)
