"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD), plus their
single-token decode steps.

TPU adaptation (see DESIGN.md §6): the CUDA selective-scan kernel is a
warp-parallel recurrence; on TPU we use
  * mamba1: chunked associative scan — ``lax.scan`` over sequence chunks
    (HBM-resident carry) with ``associative_scan`` inside the chunk
    (VMEM-sized working set, VPU-friendly elementwise ops);
  * mamba2: the SSD chunked matmul formulation, which maps the recurrence
    onto MXU matmuls (intra-chunk "attention" + inter-chunk state carry).

Both have exact sequential references in kernels/selective_scan/ref.py; the
Pallas kernel accelerates the mamba1 inner chunk on real TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, normal_init, rms_norm, silu


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def causal_conv1d(x, weight, bias, state=None):
    """Depthwise causal conv. x: (B, S, C), weight: (C, K), bias: (C,).

    If ``state`` (B, K-1, C) is given (decode), it is prepended and the new
    state returned; else zero left-padding (train/prefill).
    """
    K = weight.shape[1]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):                      # K is 4: unrolled shifts
        out = out + xp[:, i : i + x.shape[1], :] * weight[:, i][None, None, :]
    out = out + bias[None, None, :]
    new_state = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros_like(x[:, :0])
    return out, new_state


# --------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# --------------------------------------------------------------------------

def init_mamba1(kg: KeyGen, d_model: int, ssm, dtype=jnp.bfloat16):
    di = ssm.d_inner(d_model)
    dtr = ssm.resolved_dt_rank(d_model)
    N = ssm.state_dim
    K = ssm.conv_kernel
    return {
        "in_proj": normal_init(kg(), (d_model, 2 * di), dtype=dtype),
        "conv_w": normal_init(kg(), (di, K), scale=0.1, dtype=jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": normal_init(kg(), (di, dtr + 2 * N), dtype=dtype),
        "dt_proj": normal_init(kg(), (dtr, di), scale=dtr ** -0.5, dtype=jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),   # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": normal_init(kg(), (di, d_model), dtype=dtype),
    }


def _selective_scan_chunked(dt, xf, B_ssm, C_ssm, A, h0, chunk: int):
    """Fused chunked selective scan + output projection.

    dt, xf: (B, S, Di) f32; B_ssm, C_ssm: (B, S, N) f32; A: (Di, N);
    h0: (B, Di, N). Returns (y (B, S, Di), h_last).

    The (B, S, Di, N) decay/input tensors are never materialized for the
    full sequence — each lax.scan step builds them for one chunk in VMEM-
    sized working set, runs the associative scan, and immediately contracts
    with C to the (B, chunk, Di) output. This is the memory shape the
    Pallas kernel (kernels/selective_scan) implements natively on TPU.
    """
    B, S, Di = xf.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        B_ssm = jnp.pad(B_ssm, ((0, 0), (0, pad), (0, 0)))
        C_ssm = jnp.pad(C_ssm, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    swap = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    dtc, xfc, bc, cc = swap(dt), swap(xf), swap(B_ssm), swap(C_ssm)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    def chunk_step(h, xs):
        dtk, xk, bk, ck = xs                        # (B, chunk, ...)
        a_k = jnp.exp(dtk[..., None] * A[None, None])        # (B,c,Di,N)
        b_k = (dtk * xk)[..., None] * bk[:, :, None, :]      # (B,c,Di,N)
        aprod, bsum = jax.lax.associative_scan(combine, (a_k, b_k), axis=1)
        h_all = aprod * h[:, None] + bsum
        y = jnp.einsum("bsdn,bsn->bsd", h_all, ck)
        return h_all[:, -1], y

    h_last, y_chunks = jax.lax.scan(chunk_step, h0, (dtc, xfc, bc, cc))
    y = y_chunks.swapaxes(0, 1).reshape(B, nc * chunk, Di)[:, :S]
    return y, h_last


def mamba1_forward(params, x, ssm, *, chunk: int = 256, state=None):
    """x: (B, S, D). state: optional (conv_state, ssm_state) for streaming.
    Returns (y, new_state)."""
    B, S, D = x.shape
    N = ssm.state_dim
    dtr = ssm.resolved_dt_rank(D)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)              # (B, S, Di)
    conv_state = None if state is None else state[0]
    xc, new_conv = causal_conv1d(xin, params["conv_w"], params["conv_b"],
                                 state=conv_state)
    xc = silu(xc)
    proj = xc @ params["x_proj"]
    dt_raw = proj[..., :dtr]
    B_ssm = proj[..., dtr:dtr + N].astype(jnp.float32)          # (B,S,N)
    C_ssm = proj[..., dtr + N:].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                                # (Di, N)
    xf = xc.astype(jnp.float32)
    h0 = (jnp.zeros((B, xc.shape[-1], N), jnp.float32)
          if state is None else state[1])
    y, h_last = _selective_scan_chunked(dt, xf, B_ssm, C_ssm, A, h0, chunk)
    y = y + params["D_skip"][None, None] * xf
    y = (y.astype(x.dtype) * silu(z)) @ params["out_proj"]
    return y, (new_conv, h_last)


def mamba1_decode(params, x, state, ssm):
    """One token: x (B, 1, D); state = (conv_state (B,K-1,Di), h (B,Di,N))."""
    y, new_state = mamba1_forward(params, x, ssm, chunk=1, state=state)
    return y, new_state


# --------------------------------------------------------------------------
# Mamba2 / SSD (zamba2)
# --------------------------------------------------------------------------

def init_mamba2(kg: KeyGen, d_model: int, ssm, dtype=jnp.bfloat16):
    """Projections are stored *separately* (z, x, B, C, dt) rather than as
    one packed in_proj: the packed layout's split points do not align with
    tensor-parallel shard boundaries, while separate matrices shard cleanly
    (x and z head-aligned over the model axis; B/C/dt small). Depthwise
    convs factor the same way (mathematically identical)."""
    di = ssm.d_inner(d_model)
    N = ssm.state_dim
    nh = di // ssm.head_dim
    K = ssm.conv_kernel
    return {
        "in_z": normal_init(kg(), (d_model, di), dtype=dtype),
        "in_x": normal_init(kg(), (d_model, di), dtype=dtype),
        "in_B": normal_init(kg(), (d_model, N), dtype=dtype),
        "in_C": normal_init(kg(), (d_model, N), dtype=dtype),
        "in_dt": normal_init(kg(), (d_model, nh), dtype=dtype),
        "conv_x_w": normal_init(kg(), (di, K), scale=0.1, dtype=jnp.float32),
        "conv_x_b": jnp.zeros((di,), jnp.float32),
        "conv_B_w": normal_init(kg(), (N, K), scale=0.1, dtype=jnp.float32),
        "conv_B_b": jnp.zeros((N,), jnp.float32),
        "conv_C_w": normal_init(kg(), (N, K), scale=0.1, dtype=jnp.float32),
        "conv_C_b": jnp.zeros((N,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), jnp.float32),
        "out_proj": normal_init(kg(), (di, d_model), dtype=dtype),
    }


def mamba2_forward(params, x, ssm, *, chunk: int = 256, state=None):
    """SSD chunked forward. x: (B, S, D) -> (y, (conv_states, ssm_state)).

    ssm_state: (B, nh, P, N). Single group (G=1) B/C shared across heads.
    conv_states: dict {x, B, C} of (B, K-1, dim).
    """
    Bsz, S, D = x.shape
    di = ssm.d_inner(D)
    N = ssm.state_dim
    P = ssm.head_dim
    nh = di // P
    chunk = min(chunk, S)

    z = x @ params["in_z"]
    xr = x @ params["in_x"]
    br = x @ params["in_B"]
    cr = x @ params["in_C"]
    dt_raw = x @ params["in_dt"]
    cs = state[0] if state is not None else {"x": None, "B": None, "C": None}
    xc, ncx = causal_conv1d(xr, params["conv_x_w"], params["conv_x_b"],
                            state=cs["x"])
    bc, ncb = causal_conv1d(br, params["conv_B_w"], params["conv_B_b"],
                            state=cs["B"])
    cc, ncc = causal_conv1d(cr, params["conv_C_w"], params["conv_C_b"],
                            state=cs["C"])
    new_conv = {"x": ncx, "B": ncb, "C": ncc}
    xs = silu(xc).reshape(Bsz, S, nh, P).astype(jnp.float32)
    B_ssm = silu(bc).astype(jnp.float32)                         # (B,S,N)
    C_ssm = silu(cc).astype(jnp.float32)                         # (B,S,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                                 # (nh,)

    pad = (-S) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ssm = jnp.pad(B_ssm, ((0, 0), (0, pad), (0, 0)))
        C_ssm = jnp.pad(C_ssm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    # chunked views, scan axis first: (nc, B, L, ...)
    xs_c = xs.reshape(Bsz, nc, chunk, nh, P).swapaxes(0, 1)
    b_c = B_ssm.reshape(Bsz, nc, chunk, N).swapaxes(0, 1)
    c_c = C_ssm.reshape(Bsz, nc, chunk, N).swapaxes(0, 1)
    dt_c = dt.reshape(Bsz, nc, chunk, nh).swapaxes(0, 1)

    def chunk_step(carry, xs_in):
        S_state = carry                                   # (B, nh, P, N)
        xk, bk, ck, dtk = xs_in
        dA = dtk * A[None, None]                          # (B, L, nh)
        cum = jnp.cumsum(dA, axis=1)
        # intra-chunk: scores[b,h,i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j
        seg = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,L,L,nh)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        cb = jnp.einsum("bin,bjn->bij", ck, bk)                   # (B,L,L)
        w = jnp.where(causal[None, :, :, None], seg, 0.0) * cb[..., None] \
            * dtk[:, None, :, :]                                  # (B,L,L,nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xk)            # (B,L,nh,P)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp",
                             ck, S_state, jnp.exp(cum))
        # new chunk state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)              # (B,L,nh)
        contrib = jnp.einsum("bjhp,bjn,bjh->bhpn",
                             xk, bk, decay_to_end * dtk)
        S_new = S_state * jnp.exp(cum[:, -1])[:, :, None, None] + contrib
        return S_new, y_intra + y_inter

    S0 = (jnp.zeros((Bsz, nh, P, N), jnp.float32)
          if state is None else state[1])
    S_last, y_chunks = jax.lax.scan(chunk_step, S0, (xs_c, b_c, c_c, dt_c))
    y = y_chunks.swapaxes(0, 1).reshape(Bsz, Sp, nh, P)[:, :S]
    y = y + params["D_skip"][None, None, :, None] * xs[:, :S]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rms_norm(y * silu(z), params["norm_w"])
    return y @ params["out_proj"], (new_conv, S_last)


def mamba2_decode(params, x, state, ssm):
    y, new_state = mamba2_forward(params, x, ssm, chunk=1, state=state)
    return y, new_state
