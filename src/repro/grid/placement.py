"""Map a JAX device mesh onto the paper's grid topology.

Pods (the slow-interconnect level) become regions; hosts become sites. On a
real multi-pod deployment the region boundary is the DCN hop; here we build
the same two-level ``GridTopology`` from the mesh shape so the control plane
(scheduler + HRS) reasons about the actual hardware hierarchy.

Hardware constants are TPU v5e: 197 bf16 TFLOP/s per chip, ~50 GB/s/link
ICI inside a pod, DCN-class bandwidth across pods (the 2010 paper's
LAN:WAN = 100:1 hierarchy maps to ICI:DCN ≈ 16:1..100:1 depending on the
deployment; the ratio is configurable).
"""

from __future__ import annotations

import numpy as np

from repro.core.topology import GridTopology

TPU_V5E_FLOPS = 197e12          # bf16 peak per chip
ICI_BW = 50e9                   # bytes/s per link (intra-pod)
DCN_BW = 3.125e9                # bytes/s per host (cross-pod)
HBM_BW = 819e9                  # bytes/s per chip
HBM_BYTES = 16e9                # v5e HBM capacity
HOST_STORAGE = 512e9            # host RAM/SSD tier for data artifacts


def mesh_to_topology(mesh, *, chips_per_host: int = 8,
                     host_storage: float = HOST_STORAGE) -> GridTopology:
    """Build the two-level grid from a ('pod', ...) or (...,) mesh."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pods = axis_sizes.get("pod", 1)
    chips = int(np.prod(mesh.devices.shape)) // n_pods
    hosts_per_pod = max(1, chips // chips_per_host)
    return GridTopology(
        n_regions=n_pods,
        sites_per_region=hosts_per_pod,
        lan_bandwidth=ICI_BW,
        wan_bandwidth=DCN_BW,
        storage_capacity=host_storage,
        compute_capacities=[TPU_V5E_FLOPS * chips_per_host],
    )


def host_of_device(device_index: int, chips_per_host: int = 8) -> int:
    return device_index // chips_per_host
