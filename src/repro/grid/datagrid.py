"""DataGridService — the paper's control plane as a runtime service.

One object owns the catalog, the topology (built from the device mesh), the
per-host replica managers (HRS by default) and the data-aware scheduler.
Three framework substrates consume it:

  * the input pipeline (``repro.data.pipeline``): dataset shards are files;
    each read is a job routed to the host holding the most bytes;
  * checkpoint restore (``repro.checkpoint``): parameter shards are files;
    restore sources are HRS replica selections (intra-pod first);
  * serving (``repro.serve.engine``): prefix-KV blocks / adapters are files;
    requests are jobs.

The service tracks simulated transfer cost (bytes x link) so examples and
tests can assert the hierarchy is respected without real hardware.
"""

from __future__ import annotations

import dataclasses

from repro.core.catalog import ReplicaCatalog
from repro.core.replica import StorageState, make_strategy
from repro.core.scheduler import Job, make_scheduler
from repro.core.topology import GridTopology


@dataclasses.dataclass
class TransferStat:
    lfn: str
    src: int
    dst: int
    bytes: float
    inter_region: bool
    stored: bool


class DataGridService:
    def __init__(self, topology: GridTopology, *, strategy: str = "hrs",
                 scheduler: str = "dataaware", seed: int = 0) -> None:
        self.topology = topology
        self.catalog = ReplicaCatalog()
        self.storage = StorageState(self.catalog, topology)
        self.strategy = make_strategy(strategy, self.catalog, topology,
                                      self.storage)
        self.scheduler = make_scheduler(scheduler, self.catalog, topology,
                                        seed=seed)
        self.transfers: list[TransferStat] = []
        self._clock = 0.0
        self._job_id = 0

    # -- artifact registry ---------------------------------------------------
    def register(self, lfn: str, size: float, master_site: int) -> None:
        self.catalog.register_file(lfn, size, master_site)
        self.storage.bootstrap(master_site, lfn, self._clock)

    def tick(self, dt: float = 1.0) -> None:
        self._clock += dt

    # -- the paper's operations ----------------------------------------------
    def schedule(self, required: list[str], length: float = 1.0) -> int:
        """Route a work unit to a host (paper §3.2)."""
        self._job_id += 1
        job = Job(job_id=self._job_id, job_type=0, required=list(required),
                  length=length)
        return self.scheduler.select_site(job)

    def ensure_local(self, required: list[str], site: int) -> list[TransferStat]:
        """Run HRS for every missing file of a work unit (paper §3.3).

        Executes the plans immediately (transfer latency is accounted, not
        simulated — the DES in repro.core.simulator does the timing study).
        """
        stats = []
        for lfn in required:
            self.tick(0.001)
            if self.storage.holds(site, lfn):
                self.storage.touch(site, lfn, self._clock)
                continue
            plan = self.strategy.plan_fetch(lfn, site)
            for victim in plan.evictions:
                self.storage.remove(site, victim)
            if plan.store:
                self.storage.add(site, lfn, self._clock)
            st = TransferStat(lfn=lfn, src=plan.src, dst=site,
                              bytes=self.catalog.size(lfn),
                              inter_region=plan.inter_region,
                              stored=plan.store)
            self.transfers.append(st)
            stats.append(st)
        return stats

    def place_job(self, required: list[str], length: float = 1.0):
        """schedule + ensure_local in one call. Returns (site, transfers)."""
        site = self.schedule(required, length)
        stats = self.ensure_local(required, site)
        self.topology.sites[site].queued_work += length
        return site, stats

    def complete_job(self, site: int, length: float = 1.0) -> None:
        self.topology.sites[site].queued_work = max(
            0.0, self.topology.sites[site].queued_work - length)

    # -- bookkeeping -----------------------------------------------------------
    def inter_comm_count(self) -> int:
        return sum(1 for t in self.transfers if t.inter_region)

    def wan_bytes(self) -> float:
        return sum(t.bytes for t in self.transfers if t.inter_region)

    def lan_bytes(self) -> float:
        return sum(t.bytes for t in self.transfers if not t.inter_region)
