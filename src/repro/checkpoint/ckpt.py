"""Sharded checkpointing with HRS-selected restore sources + elastic
re-sharding.

Layout: every pytree leaf is split along axis 0 into ``n_shards`` chunks,
each saved as its own ``.npy`` under ``<dir>/step_<k>/``; ``manifest.json``
records the tree structure, shapes, dtypes and the replica placement of each
chunk (which hosts hold a copy). Restore:

  * works for ANY target topology (elastic re-shard) — chunks are
    reassembled then resplit, so 8-host checkpoints restore onto 4 hosts;
  * picks each chunk's source with the paper's HRS rule: intra-pod replica
    first, max-available-bandwidth holder, cross-pod only as a fallback —
    this is the node-failure recovery path at scale.
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.topology import GridTopology


def _np_save(path: str, arr: np.ndarray) -> None:
    # numpy can't round-trip bfloat16 through .npy: store the bit pattern
    if arr.dtype == ml_dtypes.bfloat16:
        arr = arr.view(np.uint16)
    np.save(path, arr)


def _np_load(path: str, dtype: str) -> np.ndarray:
    raw = np.load(path)
    if dtype == "bfloat16":
        return raw.view(ml_dtypes.bfloat16)
    return raw


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, prefix + (str(i),))
    elif tree is None:
        return
    else:
        yield prefix, tree


def _set_path(out, path, value):
    cur = out
    for k in path[:-1]:
        cur = cur.setdefault(k, {})
    cur[path[-1]] = value


@dataclasses.dataclass
class Manifest:
    step: int
    n_shards: int
    leaves: dict            # name -> {shape, dtype, chunks: [file, ...]}
    replicas: dict          # file -> [site, ...]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        return cls(**json.loads(s))


def save_checkpoint(tree, ckpt_dir: str, step: int, *, n_shards: int = 4,
                    replicate_to: list[int] | None = None) -> Manifest:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves = {}
    replicas = {}
    for path, leaf in _leaf_paths(tree):
        name = "/".join(path)
        arr = np.asarray(leaf)
        chunks = np.array_split(arr, min(n_shards, max(1, arr.shape[0]))
                                if arr.ndim else 1, axis=0) if arr.ndim else [arr]
        files = []
        for i, c in enumerate(chunks):
            fn = name.replace("/", ".") + f".{i}.npy"
            _np_save(os.path.join(d, fn), c)
            files.append(fn)
            replicas[fn] = list(replicate_to or [0])
        leaves[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                        "chunks": files}
    m = Manifest(step=step, n_shards=n_shards, leaves=leaves, replicas=replicas)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        f.write(m.to_json())
    return m


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
             if n.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like=None):
    """Reassemble the pytree. ``like`` (optional) restores list/tuple types
    and device placement/sharding by structure."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        m = Manifest.from_json(f.read())
    out: dict = {}
    for name, info in m.leaves.items():
        chunks = [_np_load(os.path.join(d, fn), info["dtype"])
                  for fn in info["chunks"]]
        arr = np.concatenate(chunks, axis=0) if chunks[0].ndim else chunks[0]
        leaf = jnp.asarray(arr.reshape(info["shape"]))
        _set_path(out, tuple(name.split("/")), leaf)
    if like is not None:
        out = _match_structure(like, out)
    return out, m


def _match_structure(like, loaded):
    if isinstance(like, dict):
        return {k: _match_structure(like[k], loaded.get(k, {}))
                for k in like}
    if isinstance(like, (list, tuple)):
        vals = [_match_structure(v, loaded.get(str(i), {})
                                 if isinstance(loaded, dict) else loaded)
                for i, v in enumerate(like)]
        return type(like)(vals)
    if like is None:
        return None
    return loaded


def choose_restore_sources(manifest: Manifest, topology: GridTopology,
                           dst_site: int) -> dict[str, int]:
    """HRS replica selection per chunk (paper §3.3, applied to restart).

    Intra-region holders first; among candidates, max available bandwidth.
    """
    out = {}
    for fn, sites in manifest.replicas.items():
        region = topology.region_of(dst_site)
        local = [s for s in sites if topology.region_of(s) == region]
        cands = local if local else sites
        out[fn] = max(cands,
                      key=lambda s: (topology.point_bandwidth(s, dst_site), -s))
    return out


def reshard_for_mesh(tree, mesh, sharding_fn):
    """Elastic re-shard: place restored arrays for a (new) mesh.

    sharding_fn(path, leaf) -> NamedSharding or None (replicate).
    """
    out = []
    for path, leaf in _leaf_paths(tree):
        s = sharding_fn(path, leaf)
        out.append((path, jax.device_put(leaf, s) if s is not None else leaf))
    res: dict = {}
    for path, leaf in out:
        _set_path(res, path, leaf)
    return res
