"""granite-3-8b [dense] — 40L d4096 32H (kv8) d_ff 12800 vocab 49155, GQA.
[hf:ibm-granite/granite-3.0-2b-base family] Full attention => long_500k
skipped."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    head_dim=128,
    layer_pattern=("attn",),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)
