"""arctic-480b [moe] — 35L d7168 56H (kv8) d_ff=4864/expert, vocab 32000,
MoE 128 experts top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base]"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual=True),
    layer_pattern=("attn",),
    tie_embeddings=False,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
