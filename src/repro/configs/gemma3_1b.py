"""gemma3-1b [dense] — 26L d1152 4H (kv1) d_ff 6912 vocab 262144; 5:1
local:global attention, 128k context. [hf:google/gemma-3-1b-pt]
26 layers = 4 groups of (local x5, global) + 2 remainder local layers.
Global layers are full attention => long_500k skipped."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    local_window=512,
    attn_q_chunk=256,    # §Perf it.4: tiles matched to the 512 window cut
    attn_kv_chunk=256,   # the causal/band over-compute ~12% further
    rope_theta=1e6,
    embed_scale=True,
    layer_pattern=("attn_local",) * 5 + ("attn",),
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
