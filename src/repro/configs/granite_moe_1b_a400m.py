"""granite-moe-1b-a400m [moe] — 24L d1024 16H (kv8) d_ff=512/expert,
vocab 49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=32, top_k=8),
    layer_pattern=("attn",),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
