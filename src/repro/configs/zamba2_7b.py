"""zamba2-7b [hybrid] — 81L d3584, Mamba2 blocks + a shared attention block
(32H MHA kv=32, d_ff 14336) applied every 6 layers with shared weights,
ssm_state=64. [arXiv:2411.15242]

Group layout: 81 layers = 11 groups of (mamba2 x6, shared_attn) + 4
remainder mamba2 blocks — 70 mamba2 mixers + 11 shared-attention
applications via the scan, 4 unrolled mamba2 at the top.
Sub-quadratic (hybrid) => long_500k runs for this arch.
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,                       # 11 x (6 mamba2 + shared attn) + 4
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm=SSMConfig(state_dim=64, conv_kernel=4, expand=2, head_dim=64,
                  version=2),
    layer_pattern=("mamba2",) * 6 + ("shared_attn",),
    tie_embeddings=True,
    skip_shapes=(),                    # long_500k runs (hybrid)
    source="arXiv:2411.15242; unverified",
)
