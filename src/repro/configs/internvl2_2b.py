"""internvl2-2b [vlm] — InternLM2-1.8B language backbone: 24L d2048 16H
(kv8) d_ff 8192 vocab 92553; InternViT frontend is a STUB (input_specs
provides 256 patch embeddings overwriting the leading positions).
[arXiv:2404.16821] Full attention => long_500k skipped."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    vision_tokens=256,
    layer_pattern=("attn",),
    tie_embeddings=True,
    source="arXiv:2404.16821; hf",
)
