"""Architecture configuration schema + shape registry.

Every assigned architecture is a module ``repro.configs.<id>`` exporting
``CONFIG`` (the exact published config) built from :class:`ArchConfig`.
``ArchConfig.reduced()`` gives the CPU-smoke-test variant of the same family.

Layer patterns are expressed as *groups*: a group is a short, statically
unrolled sequence of block descriptors, and the model scans over ``n_groups``
stacked copies (+ optional unrolled remainder). This keeps heterogeneous
stacks (gemma local/global alternation, zamba mamba+shared-attention) inside
a single ``lax.scan`` so HLO size stays bounded for 80-layer models.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

BlockKind = Literal["attn", "attn_local", "mamba1", "mamba2", "shared_attn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int                    # N
    conv_kernel: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    dt_rank: int | None = None        # mamba1; default ceil(d_model/16)
    head_dim: int = 64                # mamba2 P
    version: int = 1                  # 1 = mamba1 (falcon), 2 = mamba2/SSD (zamba)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank is not None else math.ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None       # default d_model // n_heads
    # attention details
    qkv_bias: bool = False            # qwen2
    rope_theta: float = 10000.0
    local_window: int = 4096          # sliding window for attn_local blocks
    attn_q_chunk: int = 1024          # blockwise-attention tile sizes
    attn_kv_chunk: int = 1024
    scan_chunk: int = 256             # mamba chunked-scan length
    attn_softcap: float | None = None  # gemma2 attention logit softcap
    final_softcap: float | None = None  # gemma2 final logit softcap
    layer_pattern: tuple[BlockKind, ...] = ("attn",)   # one group's blocks
    tie_embeddings: bool = True
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    embed_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    # mixture-of-experts / state-space sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # enc-dec (whisper)
    enc_dec: bool = False
    n_encoder_layers: int = 0
    # modality frontend stubs
    vision_tokens: int = 0             # vlm: positions overwritten by patch embeds
    audio_frontend: bool = False       # audio: encoder input = frame embeddings
    # which shapes this arch supports (see SHAPES); long_500k only for
    # sub-quadratic archs per the assignment
    skip_shapes: tuple[str, ...] = ("long_500k",)
    # source provenance
    source: str = ""

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def remainder_pattern(self) -> tuple[BlockKind, ...]:
        rem = self.n_layers - self.n_groups * len(self.layer_pattern)
        return self.layer_pattern[:rem]

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Same family, laptop scale — used by per-arch smoke tests."""
        pat = self.layer_pattern
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, num_experts=min(4, self.moe.num_experts),
                                      top_k=min(2, self.moe.top_k))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, state_dim=min(8, self.ssm.state_dim),
                                      head_dim=16)
        return dataclasses.replace(
            self,
            n_layers=2 * len(pat),
            n_encoder_layers=2 if self.enc_dec else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=128 if self.moe is None else 64,
            head_dim=16,
            vocab=256,
            local_window=32,
            vision_tokens=min(self.vision_tokens, 8),
            moe=moe,
            ssm=ssm,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, str] = {
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "arctic-480b": "repro.configs.arctic_480b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
}


def arch_ids() -> list[str]:
    return list(_REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    import importlib

    mod = importlib.import_module(_REGISTRY[arch_id])
    return mod.CONFIG


def cells(arch_id: str) -> list[str]:
    """Valid (arch x shape) cells for an architecture."""
    cfg = get_config(arch_id)
    return [s for s in SHAPES if s not in cfg.skip_shapes]
