"""qwen2-72b [dense] — 80L d8192 64H (kv8) d_ff 29568 vocab 152064, GQA with
QKV bias. [arXiv:2407.10671] Full attention => long_500k skipped."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    layer_pattern=("attn",),
    tie_embeddings=False,
    source="arXiv:2407.10671; hf",
)
