"""gemma2-27b [dense] — 46L d4608 32H (kv16) d_ff 36864 vocab 256000;
local+global alternating attention, logit softcaps. [arXiv:2408.00118]
Full attention on global layers => long_500k skipped."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    layer_pattern=("attn_local", "attn"),     # 23 groups
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)
