"""falcon-mamba-7b [ssm] — 64L d4096 attention-free (mamba1), ssm_state=16,
vocab 65024. [arXiv:2410.05355] Attention-free => long_500k runs."""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2, version=1),
    scan_chunk=4096,     # §Perf it.4: N=16 is small enough that long
                         # associative-scan chunks win (2.1x memory term
                         # vs 256); mamba2 (N=64, quadratic intra-chunk)
                         # keeps the short default.
    layer_pattern=("mamba1",),
    tie_embeddings=True,
    skip_shapes=(),                     # long_500k runs (ssm)
    source="arXiv:2410.05355; unverified",
)
