"""whisper-large-v3 [audio] — enc-dec, 32L each side, d1280 20H (MHA kv=20)
d_ff 5120 vocab 51866; conv frontend is a STUB (input_specs provides frame
embeddings). [arXiv:2212.04356]

Shape convention (DESIGN.md §5): seq_len = encoder frames; decoder length =
seq_len // 8. Enc-dec (quadratic encoder) => long_500k skipped.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                       # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    enc_dec=True,
    audio_frontend=True,
    norm="layernorm",
    act="gelu",
    layer_pattern=("attn",),
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)
