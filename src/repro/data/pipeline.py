"""Deterministic synthetic token pipeline with grid-placed shards.

The dataset is a set of shards ("files" in the paper's sense) registered in
the DataGridService. Every training step, each data-parallel group issues a
shard-read job; the data-aware scheduler sends it to the host already
holding the shard bytes, and HRS replicates hot shards intra-pod before the
cross-pod path is ever touched. On this CPU container the token contents are
synthesized deterministically from (shard, position) so any host can
materialize its assignment — exactly the property real object-store-backed
pipelines have.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.grid.datagrid import DataGridService


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 64
    shard_bytes: float = 512e6
    seed: int = 0


def shard_name(i: int) -> str:
    return f"dataset/shard{i:05d}"


class SyntheticShardedDataset:
    """tokens(shard, index) is a pure function — deterministic everywhere.

    Sequences follow a per-shard affine recurrence x_{t+1} = (a x_t + b)
    mod K (K <= vocab), so the stream is *learnable* (a model trained on it
    drives next-token loss well below ln K) while remaining reproducible
    from (seed, shard, index) alone — the property that lets any host
    materialize any shard assignment."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        self.k = min(cfg.vocab, 251)

    def tokens(self, shard: int, index: int) -> np.ndarray:
        cfg = self.cfg
        srng = np.random.default_rng(np.uint64(cfg.seed * 9176 + shard))
        a = int(srng.integers(1, self.k))
        b = int(srng.integers(0, self.k))
        rng = np.random.default_rng(
            np.uint64(cfg.seed * 1_000_003 + shard * 7919 + index))
        toks = np.empty((cfg.seq_len + 1,), np.int32)
        toks[0] = rng.integers(0, self.k)
        for t in range(cfg.seq_len):
            toks[t + 1] = (a * int(toks[t]) + b) % self.k
        return toks


class GridDataLoader:
    """Yields (batch, placement_stats) per step.

    Each step draws ``global_batch`` sequences round-robin over shards; the
    shard-read jobs are routed through the DataGridService so replica
    placement follows the paper's policy.
    """

    def __init__(self, dataset: SyntheticShardedDataset, grid: DataGridService,
                 *, register: bool = True) -> None:
        self.ds = dataset
        self.grid = grid
        cfg = dataset.cfg
        if register:
            n_sites = grid.topology.n_sites
            for i in range(cfg.n_shards):
                grid.register(shard_name(i), cfg.shard_bytes,
                              master_site=(i * 3) % n_sites)
        self._step = 0

    def next_batch(self):
        cfg = self.ds.cfg
        step = self._step
        self._step += 1
        shards = [(step * cfg.global_batch + b) % cfg.n_shards
                  for b in range(cfg.global_batch)]
        uniq = sorted(set(shards))
        site, stats = self.grid.place_job([shard_name(s) for s in uniq],
                                          length=1.0)
        toks = np.stack([self.ds.tokens(s, step) for s in shards])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        self.grid.complete_job(site)
        return batch, {"site": site, "transfers": stats}
