"""Batched serving engine with grid-routed request placement.

Each *replica pool member* (a host group holding the model) is a site; each
request batch carries the artifacts it needs — a prefix-KV block id and/or a
LoRA-adapter id — registered as files. The router is the paper's scheduler:
send the batch where the most required bytes already live, tie-break on
queue load; HRS replicates hot prefixes intra-pod first.

The compute side is a jitted (prefill, decode) pair over the model facade.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.grid.datagrid import DataGridService
from repro.models import model as M


@dataclasses.dataclass
class Request:
    request_id: int
    tokens: np.ndarray                  # prompt (S,)
    max_new_tokens: int = 16
    prefix_id: str | None = None        # shared-prefix KV artifact
    adapter_id: str | None = None       # LoRA artifact


class ServeEngine:
    """Single-model compute engine: prefill once, decode step-by-step."""

    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 512) -> None:
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

    def generate(self, tokens: np.ndarray, n_new: int) -> np.ndarray:
        """tokens: (B, S) prompt -> (B, n_new) greedy continuation."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        pad = self.max_len - S
        batch = {"tokens": jnp.asarray(tokens)}
        logits, caches = self._prefill(self.params, batch)
        # grow caches to max_len on the sequence axis
        caches = jax.tree.map(self._pad_cache_leaf, caches)
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        pos = S
        for _ in range(n_new):
            out.append(np.asarray(tok)[:, 0])
            logits, caches = self._decode(self.params, caches, tok,
                                          jnp.int32(pos))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            pos += 1
        return np.stack(out, axis=1)

    def _pad_cache_leaf(self, x):
        # attention caches carry the sequence on axis -3: (B, S, KV, hd)
        if x.ndim >= 4 and x.shape[-3] < self.max_len and x.shape[-2] <= 64:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, self.max_len - x.shape[-3])
            return jnp.pad(x, pad)
        return x


class GridRouter:
    """Routes request batches across a pool of engine sites (paper §3.2)."""

    def __init__(self, grid: DataGridService, n_engines: int) -> None:
        self.grid = grid
        self.n_engines = n_engines
        self.routed: list[tuple[int, int]] = []     # (request_id, site)

    def register_prefix(self, prefix_id: str, kv_bytes: float,
                        master_site: int = 0) -> None:
        self.grid.register(prefix_id, kv_bytes, master_site)

    def route(self, req: Request) -> int:
        required = [a for a in (req.prefix_id, req.adapter_id) if a]
        site, _ = self.grid.place_job(required, length=float(len(req.tokens)))
        self.routed.append((req.request_id, site))
        return site

    def complete(self, site: int, req: Request) -> None:
        self.grid.complete_job(site, length=float(len(req.tokens)))
