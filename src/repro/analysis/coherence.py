"""Snapshot-coherence rules (the PR 5 frozen-lfns bug class).

The engine keeps several derived snapshots of the replica catalog —
incremental presence bitmaps in the jax brokers, decayed access counts
in :class:`repro.core.access.AccessHistory` — maintained by catalog
listeners plus a lazy ``sync()`` that re-bases the file axis when files
were registered after construction. Two invariants make that safe, and
PR 5 shipped a bug (stale ``lfns`` axis read without ``sync()``) that
motivates checking them statically:

* **SL011 — catalog mutations go through the listener-notifying API.**
  Outside ``repro/core/catalog.py`` nobody touches the private
  ``_holders`` replica map: reads go through ``holders()`` /
  ``fetchable_holders()``, writes through ``register_file()`` /
  ``add_replica()`` / ``remove_replica()`` (which fire ``_notify``).
  Inside ``catalog.py``, every method that mutates ``_holders`` must
  call ``_notify`` in the same method body.

* **SL012 — snapshot consumers call sync() before reads.** In any class
  defining a ``sync()`` method, the attributes ``sync()`` reassigns are
  the *synced snapshot state*. Every public method (not ``sync``
  itself, not ``on_*`` listener callbacks, not ``_``-private helpers)
  that reads one of those attributes must be *synced*: it calls
  ``.sync()`` directly, or calls a same-class method that is synced
  (transitively). Listener callbacks are exempt because they are the
  incremental maintainers; private helpers are exempt because their
  public callers carry the obligation.

* **SL013 — storage mutations go through the listener-notifying API.**
  The batched strategy engine (PR 8) mirrors ``StorageState`` into
  :class:`repro.core.replica.StorageTensorView` via storage listeners,
  so the storage maps got the same contract the catalog's ``_holders``
  has under SL011. Outside ``repro/core/replica.py`` nobody touches the
  private ``_contents`` / ``_pins`` / ``_add_seq`` / ``_lru`` maps:
  reads go through ``has()`` / ``site_contents()`` / ``is_pinned()`` /
  ``lru_order()``, writes through ``add()`` / ``touch()`` / ``remove()``
  / ``pin()`` / ``unpin()`` (which fire ``_notify``). Inside
  ``replica.py``, every public method that mutates one of those maps
  must call ``_notify`` in the same body (``_``-private helpers are
  exempt — their public callers carry the obligation, same as SL012).

* **SL014 — obs probe callbacks are observation-only.** The telemetry
  package (PR 9) is handed live engine objects — ``GridSampler.sample``
  receives the running ``GridSimulator`` — and is simultaneously the
  one sim-adjacent package exempt from the SL005 wall-clock ban. The
  bit-identity contract ("any obs mode leaves the goldens untouched")
  therefore rests on obs code never *writing* through those handles.
  Inside ``repro/obs/``, any function body that (a) calls a mutating
  method (``submit_job``, ``add_replica``, ``rerate``, ``append``,
  ``pop``, ...) on a receiver rooted at one of its own parameters, or
  (b) assigns/augments/deletes through an attribute or subscript chain
  rooted at a parameter, is flagged. ``self``/``cls`` are excluded —
  mutating the probe's *own* bookkeeping is the package's job; the rule
  polices the boundary to foreign objects passed in.
"""

from __future__ import annotations

import ast

from .findings import Finding

CATALOG_OWNER_PATH = "repro/core/catalog.py"
PRIVATE_REPLICA_MAP = "_holders"
STORAGE_OWNER_PATH = "repro/core/replica.py"
PRIVATE_STORAGE_MAPS = frozenset(("_contents", "_pins", "_add_seq", "_lru"))
LISTENER_PREFIX = "on_"
#: SL014 scope: files whose path contains this substring.
OBS_PATH = "repro/obs/"
#: Method names that mutate their receiver. Covers the engine's own
#: mutators (simulator / catalog / storage / network / access-history
#: APIs) plus the builtin container mutators — calling any of these on
#: an object that arrived as a parameter is a state write, which obs
#: code must never perform.
OBS_MUTATOR_CALLS = frozenset((
    # catalog / storage / replica-strategy
    "register_file", "add_replica", "remove_replica", "bootstrap",
    "add", "remove", "touch", "pin", "unpin", "lose", "plan_fetch",
    "plan_batch", "refresh_plan",
    # simulator / scheduler / broker
    "submit_job", "inject_failure", "run", "dispatch", "select",
    "select_batch",
    # network engine
    "alloc", "release", "rerate", "flush", "advance", "step",
    # access history / economy
    "record_access", "record_fetch", "record_prefetch",
    "invalidate_online", "decay",
    # builtin / heapq container mutators
    "heappush", "heappop", "heapreplace", "heapify",
    "append", "appendleft", "extend", "insert", "pop", "popleft",
    "popitem", "update", "setdefault", "clear", "discard", "sort",
    "reverse", "fill", "put", "resize",
))


def _flag(findings: list[Finding], rule: str, path: str, lines: list[str],
          node: ast.AST, message: str) -> None:
    line = getattr(node, "lineno", 1)
    snippet = lines[line - 1].strip() if 0 < line <= len(lines) else ""
    findings.append(Finding(rule=rule, path=path, line=line,
                            message=message, snippet=snippet))


# ---------------------------------------------------------------------------
# SL011
# ---------------------------------------------------------------------------


def _mutates_holders(node: ast.AST) -> bool:
    """Does this statement mutate an element of ``self._holders``?"""
    for sub in ast.walk(node):
        # self._holders[lfn] = ... / del self._holders[lfn]
        if isinstance(sub, (ast.Assign, ast.Delete)):
            targets = sub.targets
            for t in targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Attribute)
                        and t.value.attr == PRIVATE_REPLICA_MAP):
                    return True
        # self._holders[lfn].add(...) / .discard(...) / .pop(...)
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute):
            recv = sub.func.value
            if sub.func.attr in ("add", "discard", "remove", "pop", "clear",
                                 "update", "setdefault"):
                for part in ast.walk(recv):
                    if (isinstance(part, ast.Attribute)
                            and part.attr == PRIVATE_REPLICA_MAP):
                        return True
    return False


def check_catalog_bypass(tree: ast.Module, path: str,
                         source: str) -> list[Finding]:
    """SL011: private replica-map access outside the catalog module, and
    notify-less mutations inside it."""
    findings: list[Finding] = []
    lines = source.splitlines()
    inside_catalog = path.endswith(CATALOG_OWNER_PATH) or \
        path == CATALOG_OWNER_PATH

    if not inside_catalog:
        for node in ast.walk(tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr == PRIVATE_REPLICA_MAP):
                _flag(findings, "SL011", path, lines, node,
                      "direct access to ReplicaCatalog._holders bypasses "
                      "the listener-notifying API; use holders()/"
                      "add_replica()/remove_replica()")
        return findings

    # inside catalog.py: mutating methods must notify listeners
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name == "__init__" or not _mutates_holders(node):
            continue
        notifies = any(
            isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Attribute)
            and sub.func.attr == "_notify"
            for sub in ast.walk(node))
        if not notifies:
            _flag(findings, "SL011", path, lines, node,
                  f"catalog method {node.name}() mutates _holders without "
                  "firing _notify — listener snapshots (presence bitmaps, "
                  "access axes) go stale")
    return findings


# ---------------------------------------------------------------------------
# SL013
# ---------------------------------------------------------------------------


def _mutated_storage_maps(node: ast.AST) -> set[str]:
    """Private-storage-map names this statement mutates through ``self``.

    Unlike ``_holders`` (a flat dict), the storage maps are nested
    (``self._contents[site][lfn] = ...``), so the target walk descends
    through arbitrarily many subscripts.
    """
    hit: set[str] = set()

    def _collect(expr: ast.AST) -> None:
        for part in ast.walk(expr):
            if (isinstance(part, ast.Attribute)
                    and part.attr in PRIVATE_STORAGE_MAPS
                    and isinstance(part.value, ast.Name)
                    and part.value.id == "self"):
                hit.add(part.attr)

    for sub in ast.walk(node):
        if isinstance(sub, (ast.Assign, ast.Delete)):
            for t in sub.targets:
                if isinstance(t, ast.Subscript):
                    _collect(t)
        elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
            if isinstance(sub.target, ast.Subscript):
                _collect(sub.target)
        elif isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute):
            if sub.func.attr in ("add", "discard", "remove", "pop", "clear",
                                 "update", "setdefault", "insert", "append"):
                _collect(sub.func.value)
    return hit


def check_storage_bypass(tree: ast.Module, path: str,
                         source: str) -> list[Finding]:
    """SL013: private storage-map access outside the replica module, and
    notify-less mutations inside it (see module doc)."""
    findings: list[Finding] = []
    lines = source.splitlines()
    inside_owner = path.endswith(STORAGE_OWNER_PATH) or \
        path == STORAGE_OWNER_PATH

    if not inside_owner:
        for node in ast.walk(tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr in PRIVATE_STORAGE_MAPS):
                _flag(findings, "SL013", path, lines, node,
                      f"direct access to StorageState.{node.attr} bypasses "
                      "the listener-notifying API; use site_contents()/"
                      "lru_order()/is_pinned() or add_listener() — stale "
                      "StorageTensorView tensors otherwise")
        return findings

    # inside replica.py: public mutators must notify listeners, directly or
    # via a same-class mutator that does (lose() delegates to remove()).
    for cls in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        methods = _class_methods(cls)
        mutators = {name: _mutated_storage_maps(fn)
                    for name, fn in methods.items()}
        compliant = {name for name, fn in methods.items()
                     if any(isinstance(sub, ast.Call)
                            and isinstance(sub.func, ast.Attribute)
                            and sub.func.attr == "_notify"
                            for sub in ast.walk(fn))}
        changed = True
        while changed:
            changed = False
            for name, fn in methods.items():
                if name not in compliant and _self_calls(fn) & compliant:
                    compliant.add(name)
                    changed = True
        for name in sorted(methods):
            if name.startswith("_") or name.startswith(LISTENER_PREFIX):
                continue   # helpers: public callers carry the obligation
            if mutators[name] and name not in compliant:
                _flag(findings, "SL013", path, lines, methods[name],
                      f"{cls.name}.{name}() mutates "
                      f"{', '.join(sorted(mutators[name]))} without firing "
                      "_notify — listener mirrors (StorageTensorView) go "
                      "stale")
    return findings


# ---------------------------------------------------------------------------
# SL012
# ---------------------------------------------------------------------------


def _self_attr_stores(fn: ast.AST) -> set[str]:
    """Names X for every ``self.X = ...`` in the function body."""
    out: set[str] = set()
    for sub in ast.walk(fn):
        targets = []
        if isinstance(sub, ast.Assign):
            targets = sub.targets
        elif isinstance(sub, (ast.AnnAssign, ast.AugAssign)):
            targets = [sub.target]
        for t in targets:
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                out.add(t.attr)
    return out


def _self_attr_reads(fn: ast.AST) -> dict[str, ast.Attribute]:
    """Names X (with a representative node) for ``self.X`` reads."""
    out: dict[str, ast.Attribute] = {}
    for sub in ast.walk(fn):
        if (isinstance(sub, ast.Attribute)
                and isinstance(sub.value, ast.Name)
                and sub.value.id == "self"
                and not isinstance(sub.ctx, ast.Store)):
            out.setdefault(sub.attr, sub)
    # AugAssign targets read too (self.x += 1) but are ctx=Store; catch them
    for sub in ast.walk(fn):
        if isinstance(sub, ast.AugAssign) and isinstance(
                sub.target, ast.Attribute) \
                and isinstance(sub.target.value, ast.Name) \
                and sub.target.value.id == "self":
            out.setdefault(sub.target.attr, sub.target)
    return out


def _self_calls(fn: ast.AST) -> set[str]:
    """Names of same-instance method calls (``self.m(...)``)."""
    out: set[str] = set()
    for sub in ast.walk(fn):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and isinstance(sub.func.value, ast.Name)
                and sub.func.value.id == "self"):
            out.add(sub.func.attr)
    return out


def _calls_any_sync(fn: ast.AST) -> bool:
    """Does the body call a ``sync()`` method on anything?"""
    return any(
        isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
        and sub.func.attr == "sync"
        for sub in ast.walk(fn))


def _class_methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def check_sync_coherence(tree: ast.Module, path: str,
                         source: str) -> list[Finding]:
    """SL012: public snapshot readers must be synced (see module doc)."""
    findings: list[Finding] = []
    lines = source.splitlines()
    classes = {n.name: n for n in ast.walk(tree)
               if isinstance(n, ast.ClassDef)}

    for cls in classes.values():
        methods = _class_methods(cls)
        own = set(methods)     # report on methods *defined* here only, so
        #                        subclasses don't re-report inherited ones
        # resolve single-inheritance sync() from same-module bases
        sync_fn = methods.get("sync")
        seen = {cls.name}
        base_cls = cls
        while sync_fn is None:
            base_names = [b.id for b in base_cls.bases
                          if isinstance(b, ast.Name)]
            base_cls = next((classes[b] for b in base_names
                             if b in classes and b not in seen), None)
            if base_cls is None:
                break
            seen.add(base_cls.name)
            methods = {**_class_methods(base_cls), **methods}
            sync_fn = _class_methods(base_cls).get("sync")
        if sync_fn is None:
            continue

        # synced attrs: assigned in sync() or in same-class methods sync()
        # calls (transitively — sync may delegate to _resync helpers)
        synced_attrs: set[str] = set()
        frontier = ["sync"]
        visited: set[str] = set()
        while frontier:
            name = frontier.pop()
            if name in visited or name not in methods:
                continue
            visited.add(name)
            synced_attrs |= _self_attr_stores(methods[name])
            frontier.extend(sorted(_self_calls(methods[name])))

        # methods that are synced: call .sync() directly, or call a synced
        # same-class method (fixed point)
        synced_methods = {name for name, fn in methods.items()
                          if _calls_any_sync(fn)}
        changed = True
        while changed:
            changed = False
            for name, fn in methods.items():
                if name in synced_methods:
                    continue
                if _self_calls(fn) & synced_methods:
                    synced_methods.add(name)
                    changed = True

        for name, fn in sorted(methods.items()):
            if name not in own:
                continue
            if (name == "sync" or name.startswith("_")
                    or name.startswith(LISTENER_PREFIX)):
                continue
            if name in synced_methods:
                continue
            reads = _self_attr_reads(fn)
            stale = sorted(set(reads) & synced_attrs)
            if stale:
                _flag(findings, "SL012", path, lines, reads[stale[0]],
                      f"{cls.name}.{name}() reads synced snapshot state "
                      f"({', '.join(stale)}) without calling sync() — "
                      "stale file axis after late register_file()")
    return findings


# ---------------------------------------------------------------------------
# SL014
# ---------------------------------------------------------------------------


def _root_name(expr: ast.expr) -> str | None:
    """The base ``Name`` of an attribute/subscript chain, or ``None``.

    ``sim.catalog._holders[lfn]`` -> ``"sim"``; chains rooted at calls
    or literals return ``None`` (a call result is a fresh object the
    caller owns).
    """
    while isinstance(expr, (ast.Attribute, ast.Subscript)):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else None


def _fn_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    args = fn.args
    names = {a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)}
    if args.vararg is not None:
        names.add(args.vararg.arg)
    if args.kwarg is not None:
        names.add(args.kwarg.arg)
    return names - {"self", "cls"}


def check_obs_observation_only(tree: ast.Module, path: str,
                               source: str) -> list[Finding]:
    """SL014: obs code may not mutate objects handed in as parameters
    (see module doc). Scope: files under ``repro/obs/``."""
    findings: list[Finding] = []
    if OBS_PATH not in path:
        return findings
    lines = source.splitlines()

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = _fn_params(fn)
        if not params:
            continue
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call) \
                    and isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in OBS_MUTATOR_CALLS:
                root = _root_name(sub.func.value)
                if root in params:
                    _flag(findings, "SL014", path, lines, sub,
                          f"{fn.name}() calls mutating "
                          f"{root}...{sub.func.attr}() on a parameter — "
                          "obs probes are observation-only; copy the data "
                          "out instead of writing through the handle")
            elif isinstance(sub, (ast.Assign, ast.Delete)):
                for t in sub.targets:
                    if isinstance(t, (ast.Attribute, ast.Subscript)) \
                            and _root_name(t) in params:
                        _flag(findings, "SL014", path, lines, sub,
                              f"{fn.name}() writes through parameter "
                              f"{_root_name(t)!r} — obs probes are "
                              "observation-only")
            elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
                t = sub.target
                if isinstance(t, (ast.Attribute, ast.Subscript)) \
                        and _root_name(t) in params:
                    _flag(findings, "SL014", path, lines, sub,
                          f"{fn.name}() writes through parameter "
                          f"{_root_name(t)!r} — obs probes are "
                          "observation-only")
    return findings


def lint_coherence(source: str, path: str) -> list[Finding]:
    """Run all four coherence rules over one file."""
    tree = ast.parse(source, filename=path)
    findings = check_catalog_bypass(tree, path, source)
    findings += check_storage_bypass(tree, path, source)
    findings += check_sync_coherence(tree, path, source)
    findings += check_obs_observation_only(tree, path, source)
    return sorted(findings, key=lambda f: (f.line, f.rule))
