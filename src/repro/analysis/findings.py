"""Finding records, inline suppressions, and the JSON baseline.

A :class:`Finding` is one rule violation at one source location. Three
suppression layers exist, checked in this order:

1. **Inline, same line** — ``# simlint: disable=SL001`` (or a bare
   ``# simlint: disable`` for all rules) on the flagged line.
2. **Inline, next line** — ``# simlint: disable-next-line=SL001`` on the
   line above the flagged one.
3. **Baseline file** — a JSON file of finding fingerprints
   (``analysis_baseline.json``), for grandfathering legacy findings
   without touching the code. Fingerprints hash rule + path + the
   normalized source line (not the line *number*), so unrelated edits
   above a baselined finding do not invalidate it.

Inline suppressions should carry a justification comment; the baseline
is for bulk-adopting the linter on code you cannot touch yet. This
repo's own ``src/repro`` tree carries **zero** baseline entries — the
acceptance bar is a clean run, not a long baseline.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from pathlib import Path

_SUPPRESS_RE = re.compile(
    r"#\s*simlint:\s*disable(?P<next>-next-line)?"
    r"(?:\s*=\s*(?P<rules>[A-Z0-9, ]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str          # e.g. "SL001"
    path: str          # repo-relative posix path
    line: int          # 1-indexed
    message: str
    snippet: str = ""  # stripped source line, for fingerprints + display

    def fingerprint(self) -> str:
        norm = re.sub(r"\s+", " ", self.snippet.strip())
        digest = hashlib.sha1(
            f"{self.rule}|{self.path}|{norm}".encode()).hexdigest()
        return digest[:16]

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def inline_suppressions(source: str) -> dict[int, set[str] | None]:
    """Map line number -> suppressed rule set (``None`` = all rules)."""
    out: dict[int, set[str] | None] = {}

    def merge(lineno: int, rules: set[str] | None) -> None:
        if rules is None or out.get(lineno, set()) is None:
            out[lineno] = None if rules is None else rules
        else:
            out.setdefault(lineno, set()).update(rules)  # type: ignore

    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = m.group("rules")
        ruleset = (None if rules is None else
                   {r.strip() for r in rules.split(",") if r.strip()})
        merge(i + 1 if m.group("next") else i, ruleset)
    return out


def is_inline_suppressed(finding: Finding,
                         suppressions: dict[int, set[str] | None]) -> bool:
    rules = suppressions.get(finding.line, set())
    return rules is None or finding.rule in (rules or set())


class Baseline:
    """Fingerprint set loaded from / written to a JSON baseline file."""

    def __init__(self, fingerprints: set[str] | None = None):
        self.fingerprints = set(fingerprints or ())

    @classmethod
    def load(cls, path: Path | str | None) -> "Baseline":
        if path is None or not Path(path).exists():
            return cls()
        data = json.loads(Path(path).read_text())
        return cls(set(data.get("suppressions", [])))

    def write(self, path: Path | str, findings: list[Finding]) -> None:
        payload = {
            "version": 1,
            "suppressions": sorted({f.fingerprint() for f in findings}),
        }
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")

    def __contains__(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.fingerprints
