"""CLI for ``python -m repro.analysis``.

Default run: both static passes (simlint + coherence) over ``src/repro``
plus the jaxpr kernel audit when jax is importable. ``--units`` adds the
unit/dimension pass (writes ``results/ANALYSIS_units.json``),
``--conserve`` the runtime conservation-audit smoke.
``--fail-on-findings`` makes any unsuppressed finding (or audit/
conservation failure) exit non-zero — this is what CI gates on.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import RULE_FAMILIES, RULES, Baseline, default_target, run_analysis

DEFAULT_BASELINE = "analysis_baseline.json"
DEFAULT_KERNELS_JSON = "results/ANALYSIS_kernels.json"
DEFAULT_UNITS_JSON = "results/ANALYSIS_units.json"


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Determinism / kernel-invariant / snapshot-coherence "
                    "static analysis for the repro codebase.")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files or directories to lint "
                             "(default: the installed repro package)")
    parser.add_argument("--fail-on-findings", action="store_true",
                        help="exit 1 if any unsuppressed finding or audit "
                             "failure remains (CI gate)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help=f"baseline JSON (default: {DEFAULT_BASELINE} "
                             "next to the lint root, if present)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write current findings to the baseline file "
                             "and exit 0")
    jaxpr = parser.add_mutually_exclusive_group()
    jaxpr.add_argument("--jaxpr", dest="jaxpr", action="store_true",
                       default=None, help="force the jaxpr kernel audit "
                       "(error if jax is missing)")
    jaxpr.add_argument("--no-jaxpr", dest="jaxpr", action="store_false",
                       help="skip the jaxpr kernel audit")
    jaxpr.add_argument("--jaxpr-only", action="store_true",
                       help="run only the jaxpr kernel audit")
    parser.add_argument("--kernels-json", type=Path,
                        default=Path(DEFAULT_KERNELS_JSON),
                        help="where the jaxpr audit report is written "
                             f"(default: {DEFAULT_KERNELS_JSON})")
    parser.add_argument("--tierace", action="store_true",
                        help="also run the dynamic tie-race sanitizer "
                             "smoke scenario and print its report")
    parser.add_argument("--units", action="store_true",
                        help="also run the unit/dimension checker over the "
                             "dimension-carrying modules and write "
                             f"{DEFAULT_UNITS_JSON}")
    parser.add_argument("--units-json", type=Path,
                        default=Path(DEFAULT_UNITS_JSON),
                        help="where the units report is written "
                             f"(default: {DEFAULT_UNITS_JSON})")
    parser.add_argument("--conserve", action="store_true",
                        help="also run the runtime conservation-audit "
                             "smoke (ledger-closure invariants)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog (grouped by family) "
                             "and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        listed: set[str] = set()
        for family, rules in RULE_FAMILIES:
            print(f"{family}:")
            for rule in rules:
                print(f"  {rule}  {RULES[rule]}")
                listed.add(rule)
        leftover = sorted(set(RULES) - listed)     # never drop a rule
        if leftover:
            print("other:")
            for rule in leftover:
                print(f"  {rule}  {RULES[rule]}")
        return 0

    failed = False

    # -- static passes -----------------------------------------------------
    if not args.jaxpr_only:
        baseline_path = args.baseline
        if baseline_path is None:
            candidate = default_target() / DEFAULT_BASELINE
            baseline_path = candidate if candidate.exists() else None
        baseline = Baseline.load(baseline_path)
        new, old, inline = run_analysis(args.paths or None, baseline)

        if args.write_baseline:
            target = args.baseline or default_target() / DEFAULT_BASELINE
            Baseline().write(target, new + old)
            print(f"wrote {len(new) + len(old)} fingerprints to {target}")
            return 0

        for finding in new:
            print(finding.render())
        print(f"simlint: {len(new)} finding(s), {len(old)} baselined, "
              f"{inline} inline-suppressed")
        failed |= bool(new)

    # -- jaxpr kernel audit ------------------------------------------------
    run_jaxpr = args.jaxpr_only or args.jaxpr
    if run_jaxpr is None:  # auto-detect
        run_jaxpr = _jax_available()
        if not run_jaxpr:
            print("jaxpr audit: skipped (jax not importable; "
                  "use --jaxpr to force)")
    if run_jaxpr:
        if not _jax_available():
            print("jaxpr audit: jax requested but not importable",
                  file=sys.stderr)
            return 2
        from .jaxpr_audit import run_jaxpr_audit
        report, failures = run_jaxpr_audit(args.kernels_json)
        for line in failures:
            print(f"jaxpr audit: FAIL {line}")
        print(f"jaxpr audit: {len(report['kernels'])} kernel(s), "
              f"{len(failures)} failure(s) -> {args.kernels_json}")
        failed |= bool(failures)

    # -- unit/dimension pass -----------------------------------------------
    if args.units:
        from .units import run_units
        findings, inline, report = run_units(
            [str(p) for p in args.paths] if args.paths else None)
        for finding in findings:
            print(finding.render())
        args.units_json.parent.mkdir(parents=True, exist_ok=True)
        args.units_json.write_text(
            json.dumps(report, indent=2, sort_keys=True) + "\n")
        print(f"units: {len(findings)} finding(s), {inline} "
              f"inline-suppressed, {len(report['files'])} file(s) "
              f"-> {args.units_json}")
        failed |= bool(findings)

    # -- runtime conservation audit ----------------------------------------
    if args.conserve:
        from .conserve import run_conservation_smoke
        for rep in run_conservation_smoke():
            bad = [n for n, c in rep["checks"].items() if not c["ok"]]
            status = "ok" if rep["ok"] else f"FAIL ({', '.join(bad)})"
            print(f"conserve: {rep['scenario']} ({rep['n_jobs']} jobs, "
                  f"net={rep['net']}): {len(rep['checks'])} invariant(s) "
                  f"{status}")
            for name in bad:
                c = rep["checks"][name]
                print(f"  FAIL {name}: lhs={c['lhs']} rhs={c['rhs']} "
                      f"({c['what']})")
            failed |= not rep["ok"]

    # -- dynamic tie-race smoke --------------------------------------------
    if args.tierace:
        from .tierace import sanitize_smoke
        rep = sanitize_smoke()
        print(f"tie-race smoke: {rep['ties_seen']} tie instant(s) "
              f"replayed, {len(rep['tie_races'])} order-dependent")
        for race in rep["tie_races"]:
            kinds = ",".join(sorted(set(race["kinds"])))
            print(f"  t={race['time']:.1f} [{kinds}] {race['detail']}")

    return 1 if (failed and args.fail_on_findings) else 0


if __name__ == "__main__":
    sys.exit(main())
