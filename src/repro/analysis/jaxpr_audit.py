"""jaxpr auditor — structural invariants for every registered kernel.

Generalizes the one-off ``st_cost`` rank-3 shape-guard test to every
kernel package discovered by :func:`repro.kernels.registered_kernels`.
For each kernel spec the auditor traces the raw kernel entry point
(``interpret=True``, so the pallas_call body is abstractly evaluated
too) at the spec's representative float32 shapes and walks the full
jaxpr, nested sub-jaxprs included:

* **rank** — no intermediate aval exceeds ``spec.max_rank``. For the
  sim kernels that bans any ``(sites, files, sites)`` /
  ``(jobs, files, sites)`` rank-3 broadcast anywhere; for
  ``selective_scan`` (rank cap 3) it bans the ``(B, S, D, N)`` dense
  scan blow-up.
* **dtype** — a float32 trace contains no float64 avals: device
  execution is f32 by contract, f64 belongs to the oracles and the x64
  interpret route only.
* **callbacks** — no host-callback primitives inside the traced
  computation (``pure_callback``, ``io_callback``, ``debug_callback``,
  ``custom_partitioning`` call-outs): host round-trips inside jit break
  both determinism and TPU performance.
* **budget** — per-eqn peak intermediate bytes: for each equation, sum
  the aval bytes of operands + results; the max over equations must
  stay <= ``spec.budget_bytes``. Constants/literals count at their aval
  size; the estimate is deliberately simple and conservative — it
  exists to catch order-of-magnitude regressions (a materialized
  logits plane, a dense scan state), not to model XLA buffer reuse.
* **units** — every spec declares a complete per-operand dimension
  signature (``arg_units`` one entry per ``make_inputs`` arg,
  ``out_units`` nonempty, vocabulary :data:`repro.analysis.units.
  DIMENSIONS`); the signature is recorded alongside the structural
  evidence so the JSON doubles as the kernels' unit registry.

Runtime oracle checks (sim kernels, ``make_small_inputs``):

* the float64 numpy oracle returns float64 (dtype discipline), and
* the kernel under x64 interpret mode is **bit-identical** to it — the
  same contract the golden suite pins end-to-end.

Results (measured peaks, budgets, verdicts) are written to
``results/ANALYSIS_kernels.json`` so CI archives the audit evidence.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import numpy as np

from .units import DIMENSIONS

#: substrings identifying host-callback primitives in any jax version
CALLBACK_PRIMITIVES = ("callback", "outside_call", "host_call",
                      "infeed", "outfeed")


def check_unit_signature(spec, n_args: int) -> bool:
    """True when the spec's dimension signature is complete and valid.

    jax-free (operates on the spec alone) so the kernels-interpret CI
    job can assert it without tracing.
    """
    arg_units = tuple(getattr(spec, "arg_units", ()))
    out_units = tuple(getattr(spec, "out_units", ()))
    return (len(arg_units) == n_args
            and len(out_units) > 0
            and all(u in DIMENSIONS for u in arg_units + out_units))


def _iter_eqns(jaxpr):
    """Yield every equation, recursing into nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    yield from _iter_eqns(inner)
                elif hasattr(sub, "eqns"):
                    yield from _iter_eqns(sub)


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def audit_kernel(spec) -> dict[str, Any]:
    """Audit one kernel spec. Returns a JSON-ready report dict."""
    import jax

    kernel = spec.load_kernel()
    args, kwargs = spec.make_inputs()
    jaxpr = jax.make_jaxpr(
        lambda *a: kernel(*a, **kwargs, interpret=True))(*args)

    max_rank = 0
    peak_bytes = 0
    peak_eqn = ""
    bad_dtypes: list[str] = []
    callbacks: list[str] = []
    n_eqns = 0
    for eqn in _iter_eqns(jaxpr.jaxpr):
        n_eqns += 1
        prim = eqn.primitive.name
        if any(s in prim for s in CALLBACK_PRIMITIVES):
            callbacks.append(prim)
        eqn_bytes = 0
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            max_rank = max(max_rank, len(aval.shape))
            eqn_bytes += _aval_bytes(aval)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and np.dtype(dtype) == np.float64:
                bad_dtypes.append(f"{prim}: {aval}")
        if eqn_bytes > peak_bytes:
            peak_bytes, peak_eqn = eqn_bytes, prim

    checks = {
        "rank_ok": max_rank <= spec.max_rank,
        "budget_ok": peak_bytes <= spec.budget_bytes,
        "no_callbacks": not callbacks,
        "f32_trace_has_no_f64": not bad_dtypes,
        "units_declared": check_unit_signature(spec, len(args)),
    }
    report: dict[str, Any] = {
        "domain": spec.domain,
        "audit_shapes": [list(np.shape(a)) for a in args],
        "arg_units": list(getattr(spec, "arg_units", ())),
        "out_units": list(getattr(spec, "out_units", ())),
        "n_eqns": n_eqns,
        "max_rank": max_rank,
        "max_rank_allowed": spec.max_rank,
        "peak_eqn_bytes": peak_bytes,
        "peak_eqn_primitive": peak_eqn,
        "budget_bytes": spec.budget_bytes,
        "callbacks": sorted(set(callbacks)),
        "f64_avals_in_f32_trace": bad_dtypes[:5],
    }

    if spec.make_small_inputs is not None:
        report["oracle"] = _audit_oracle(spec)
        checks["oracle_f64"] = report["oracle"]["returns_float64"]
        checks["x64_interpret_identity"] = \
            report["oracle"]["interpret_bit_identical"]

    report["checks"] = checks
    report["ok"] = all(checks.values())
    return report


def _audit_oracle(spec) -> dict[str, Any]:
    """Runtime dtype + bit-identity checks for a sim kernel's oracle."""
    from jax.experimental import enable_x64

    ref = spec.load_ref()
    kernel = spec.load_kernel()
    args, kwargs = spec.make_small_inputs()
    args64 = tuple(np.asarray(a, np.float64)
                   if np.asarray(a).dtype.kind == "f" else np.asarray(a)
                   for a in args)
    ref_out = ref(*args64, **kwargs)
    ref_flat = (ref_out if isinstance(ref_out, tuple) else (ref_out,))
    returns_f64 = all(
        np.asarray(r).dtype == np.float64 or np.asarray(r).ndim == 0
        for r in ref_flat)

    with enable_x64():
        k_out = kernel(*args64, **kwargs, interpret=True)
    k_flat = (k_out if isinstance(k_out, tuple) else (k_out,))
    identical = len(k_flat) == len(ref_flat) and all(
        np.array_equal(np.asarray(a, np.float64), np.asarray(b, np.float64))
        for a, b in zip(k_flat, ref_flat))
    return {"returns_float64": bool(returns_f64),
            "interpret_bit_identical": bool(identical)}


def run_jaxpr_audit(json_path: Path | str | None = None
                    ) -> tuple[dict[str, Any], list[str]]:
    """Audit every registered kernel.

    Returns ``(report, failures)`` where failures is a list of
    human-readable failed-check strings (empty = all pass). Writes the
    report JSON to ``json_path`` when given.
    """
    from repro.kernels import registered_kernels

    kernels: dict[str, Any] = {}
    report: dict[str, Any] = {"kernels": kernels}
    failures: list[str] = []
    for name, spec in registered_kernels().items():
        entry = audit_kernel(spec)
        kernels[name] = entry
        for check, ok in entry["checks"].items():
            if not ok:
                failures.append(f"{name}: {check} failed "
                                f"(peak={entry['peak_eqn_bytes']}B, "
                                f"rank={entry['max_rank']}, "
                                f"callbacks={entry['callbacks']})")
    if json_path is not None:
        path = Path(json_path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return report, failures
