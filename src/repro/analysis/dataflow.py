"""Intraprocedural dataflow framework for the AST lints.

The original simlint linter (PR 6) tracked "which names hold sets" with
ad-hoc env dicts inside one monolithic visitor. Two rule families now
need exactly that machinery — set-origin tracking (SL001/SL003) and
unit-dimension inference (SL020-SL025) — so the propagation core lives
here as a small abstract-interpretation framework over ``ast``:

* **Labels.** Abstract values are opaque strings chosen by the client
  analysis (``'set'``/``'container_of_set'`` for simlint,
  ``'bytes'``/``'sim_seconds'``/... for units). ``None`` means unknown;
  the framework never invents labels of its own.
* **Environments.** Scope-stacked ``name -> label`` dicts: one per
  module / function (closures start from a copy of the enclosing env,
  matching Python's lexical capture of the *binding*), plus a parallel
  ``self.<attr> -> label`` stack per class body, seeded by a pre-pass
  over every ``self.X`` assignment/annotation in the class.
* **Transfer functions.** Clients override :meth:`ann_label` (what a
  type annotation means) and :meth:`expr_label` (what an expression
  evaluates to, given the current env). The framework applies them at
  every binding site — ``Assign``, ``AnnAssign``, annotated function
  parameters, class-body attribute collection — and leaves all *rule*
  checks (what to flag at a use site) to subclass visitors.
* **Per-function fixpoints.** With ``fixpoint = True`` each function
  body is re-visited (findings muted) until its environment stops
  changing or ``max_passes`` is hit, then visited once more with
  findings live — so a label assigned at the bottom of a loop body
  reaches uses at the top. Single-pass mode (``fixpoint = False``)
  reproduces the original simlint visiting order exactly, which is what
  keeps the ported SL001 finding-for-finding identical to the legacy
  implementation (pinned by ``tests/test_units.py``).
"""

from __future__ import annotations

import ast
from typing import Optional

from .findings import Finding


class FlowAnalysis(ast.NodeVisitor):
    """Base visitor owning scopes, propagation, and finding collection.

    Subclasses implement ``ann_label`` / ``expr_label`` and add
    ``visit_*`` methods that call :meth:`flag` at rule sites. They must
    call ``self.generic_visit(node)`` (or the framework's binding
    visitors) to keep propagation running under their own visitors.
    """

    #: Re-visit function bodies to a label fixpoint before reporting.
    fixpoint: bool = False
    #: Safety valve on fixpoint iteration per function.
    max_passes: int = 8

    def __init__(self, path: str, source: str) -> None:
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[Finding] = []
        self.module_aliases: dict[str, str] = {}   # name -> module path
        self.from_imports: dict[str, str] = {}     # name -> "module.func"
        self.env_stack: list[dict[str, str]] = [{}]
        self.attr_env_stack: list[dict[str, str]] = [{}]
        self._mute = 0

    # -- client hooks ------------------------------------------------------

    def ann_label(self, ann: ast.expr | None) -> Optional[str]:
        """Label carried by a type annotation (``None`` = unknown)."""
        return None

    def expr_label(self, node: ast.expr | None) -> Optional[str]:
        """Label an expression evaluates to under the current env."""
        return None

    # -- findings ----------------------------------------------------------

    def flag(self, rule: str, node: ast.AST, message: str) -> None:
        if self._mute:
            return
        line = getattr(node, "lineno", 1)
        snippet = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
        self.findings.append(
            Finding(rule=rule, path=self.path, line=line, message=message,
                    snippet=snippet))

    # -- environments ------------------------------------------------------

    @property
    def env(self) -> dict[str, str]:
        return self.env_stack[-1]

    @property
    def attr_env(self) -> dict[str, str]:
        return self.attr_env_stack[-1]

    def bind(self, name: str, label: Optional[str]) -> None:
        """Strong update: rebind ``name``, dropping it when unknown."""
        if label is not None:
            self.env[name] = label
        else:
            self.env.pop(name, None)

    # -- imports (shared by _qualified-style rule helpers) ------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.module_aliases[alias.asname or
                                alias.name.split(".")[0]] = alias.name

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        for alias in node.names:
            if node.module:
                self.from_imports[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"

    def func_name(self, fn: ast.expr) -> str:
        if isinstance(fn, ast.Name):
            return fn.id
        if isinstance(fn, ast.Attribute):
            return fn.attr
        return ""

    def qualified(self, fn: ast.expr) -> str:
        """'mod.attr' when the receiver is an imported module alias."""
        if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
            mod = self.module_aliases.get(fn.value.id)
            if mod is not None:
                return f"{mod}.{fn.attr}"
            src = self.from_imports.get(fn.value.id)
            if src is not None:
                return f"{src.rsplit('.', 1)[-1]}.{fn.attr}"
        if isinstance(fn, ast.Name) and fn.id in self.from_imports:
            return self.from_imports[fn.id]
        return ""

    # -- scope handling ----------------------------------------------------

    def class_attr_labels(self, node: ast.ClassDef) -> dict[str, str]:
        """Pre-pass: labels of every ``self.X`` assigned in the class."""
        attrs: dict[str, str] = {}
        for sub in ast.walk(node):
            target = None
            kind = None
            if isinstance(sub, ast.AnnAssign) and isinstance(
                    sub.target, ast.Attribute):
                target, kind = sub.target, self.ann_label(sub.annotation)
            elif isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                    and isinstance(sub.targets[0], ast.Attribute):
                target = sub.targets[0]
            if (target is not None and isinstance(target.value, ast.Name)
                    and target.value.id == "self"):
                if kind is None and isinstance(sub, ast.Assign):
                    kind = self.expr_label(sub.value)
                if kind is not None:
                    attrs[target.attr] = kind
        return attrs

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.attr_env_stack.append(self.class_attr_labels(node))
        self.generic_visit(node)
        self.attr_env_stack.pop()

    def _function_env(self, node) -> dict[str, str]:
        env = dict(self.env)         # closures see enclosing bindings
        for arg in (node.args.posonlyargs + node.args.args
                    + node.args.kwonlyargs):
            kind = self.ann_label(arg.annotation)
            if kind is not None:
                env[arg.arg] = kind
        return env

    def _visit_function(self, node) -> None:
        base = self._function_env(node)
        if self.fixpoint:
            # warm-up passes (muted) until the post-body env stabilizes
            self._mute += 1
            env = dict(base)
            try:
                for _ in range(self.max_passes):
                    self.env_stack.append(dict(env))
                    self.generic_visit(node)
                    after = self.env_stack.pop()
                    if after == env:
                        break
                    env = after
            finally:
                self._mute -= 1
            self.env_stack.append(env)
        else:
            self.env_stack.append(base)
        self.generic_visit(node)
        self.env_stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def _visit_loop(self, node) -> None:
        """Fixpoint mode: iterate the loop body (muted) until the env
        stabilizes, so labels bound at the bottom of the body reach
        uses at the top during the final reporting visit."""
        if self.fixpoint:
            self._mute += 1
            try:
                for _ in range(self.max_passes):
                    before = dict(self.env)
                    self.generic_visit(node)
                    if self.env == before:
                        break
            finally:
                self._mute -= 1
        self.generic_visit(node)

    visit_For = _visit_loop
    visit_AsyncFor = _visit_loop
    visit_While = _visit_loop

    # -- binding sites -----------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        kind = self.expr_label(node.value)
        for t in node.targets:
            if isinstance(t, ast.Name):
                self.bind(t.id, kind)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        kind = self.ann_label(node.annotation) or self.expr_label(node.value)
        if isinstance(node.target, ast.Name) and kind is not None:
            self.env[node.target.id] = kind

    # -- driver ------------------------------------------------------------

    def run(self, tree: ast.Module) -> list[Finding]:
        """Visit ``tree`` and return findings sorted by (line, rule)."""
        self.visit(tree)
        return sorted(self.findings, key=lambda f: (f.line, f.rule))
