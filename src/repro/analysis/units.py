"""Unit/dimension inference for the grid engine (rules SL020-SL025).

The simulator carries every quantity as a bare ``float``: bytes,
bytes/s, sim-clock seconds, wall-clock probe spans, Mbps-vocabulary
config fields, counts, and dimensionless scores all look identical to
Python. The golden suites pin *values*, so a dropped ``* 1e6 / 8`` or a
``now``-vs-``elapsed_us`` mixup ships as a silently-wrong constant
factor rather than a crash. This checker recovers the dimensions
statically and flags arithmetic that cannot be dimensionally sound.

It rides on :class:`repro.analysis.dataflow.FlowAnalysis` in fixpoint
mode: a **declaration registry** seeds dimensions for core attributes /
dataclass fields (``ATTR_UNITS``), well-known local names
(``NAME_UNITS``), and API return values (``CALL_UNITS``); a small unit
algebra then propagates them through assignments and expressions
(``bytes / bytes_per_s -> sim_seconds`` and so on). The algebra is
deliberately *forgiving*: unknown (``None``) never fires a rule, and a
known dimension absorbs an unknown operand (``now + 5.0`` stays
``sim_seconds``), so sound code produces **zero findings** — the CI gate
(``python -m repro.analysis --units --fail-on-findings``) relies on
that.

Dimensions: ``sim_seconds`` (DES clock), ``wall_seconds`` (host probe),
``bytes``, ``bytes_per_s``, ``mbps`` (config vocabulary), ``count``,
``score``. The named constants of :mod:`repro.core.quantities` get
``conv:*`` pseudo-labels so sanctioned conversions type-check
(``lan_mbps * MBPS_TO_BYTES_PER_S -> bytes_per_s``) while raw literal
conversions outside ``quantities.py`` trip SL024.

Rules:

* **SL020** — adding/subtracting different dimensions.
* **SL021** — comparing different dimensions.
* **SL022** — an ``mbps`` value used where ``bytes_per_s`` is declared
  (bandwidth kwargs, bandwidth-typed assignments, ``bytes / mbps``
  transfer-time math) without the ``MBPS_TO_BYTES_PER_S`` conversion.
* **SL023** — sim-clock and wall-clock time mixed in one expression.
* **SL024** — raw conversion literal (``1e6``, ``1e9``, ``125000.0``...)
  scaling a dimensioned value outside :mod:`repro.core.quantities`.
* **SL025** — assignment/keyword binding contradicting the declared
  dimension of the target (non-mbps mismatches).
"""

from __future__ import annotations

import ast
from typing import Optional

from .dataflow import FlowAnalysis
from .findings import Finding, inline_suppressions, is_inline_suppressed

#: Real dimensions (``conv:*`` pseudo-labels are not in this set).
DIMENSIONS = frozenset(
    {"sim_seconds", "wall_seconds", "bytes", "bytes_per_s", "mbps",
     "count", "score"})

#: repro.core.quantities constants -> conversion pseudo-label.
CONV_CONSTANTS = {
    "KB": "conv:bytes_scale", "MB": "conv:bytes_scale",
    "GB": "conv:bytes_scale", "TB": "conv:bytes_scale",
    "MBPS_TO_BYTES_PER_S": "conv:mbps_to_bytes_per_s",
    "US_PER_S": "conv:us_per_s",
    "BITS_PER_BYTE": "conv:bits_per_byte",
}

#: Attribute / dataclass-field declarations across the scoped modules
#: (GridSimulator, NetworkEngine, GridTopology/Site/Link, AccessHistory,
#: SimResult/JobRecord, GridConfig/ScenarioSpec, obs series).
ATTR_UNITS = {
    # sim-clock seconds
    "now": "sim_seconds", "makespan": "sim_seconds", "last": "sim_seconds",
    "eta": "sim_seconds", "due": "sim_seconds",
    "interarrival": "sim_seconds", "interarrival_s": "sim_seconds",
    "econ_interval": "sim_seconds", "econ_interval_s": "sim_seconds",
    "batch_window": "sim_seconds", "last_now": "sim_seconds",
    "start_time": "sim_seconds", "end_time": "sim_seconds",
    "data_ready_time": "sim_seconds", "mean_downtime_s": "sim_seconds",
    "half_life": "sim_seconds",
    # bytes
    "rem": "bytes", "size": "bytes", "file_size": "bytes",
    "total_file_bytes": "bytes", "used_storage": "bytes",
    "storage_capacity": "bytes", "free_storage": "bytes",
    "total_wan_bytes": "bytes", "total_lan_bytes": "bytes",
    "wan_bytes": "bytes", "lan_bytes": "bytes", "prefetch_bytes": "bytes",
    "budget_bytes": "bytes",
    # bytes per second
    "bandwidth": "bytes_per_s", "lan_bandwidth": "bytes_per_s",
    "wan_bandwidth": "bytes_per_s", "uplink_bandwidths": "bytes_per_s",
    "link_bw": "bytes_per_s", "rate": "bytes_per_s", "share": "bytes_per_s",
    # config (paper) vocabulary
    "lan_mbps": "mbps", "uplink_mbps": "mbps",
    # counters
    "n_jobs": "count", "n_active": "count", "n_links": "count",
    "n_sites": "count", "fetches": "count", "remote_fetches": "count",
    "prefetches": "count", "accesses": "count", "hits": "count",
    "total_inter_comms": "count",
}

#: Bare-name fallbacks for unannotated params/locals (env wins when a
#: name is rebound).
NAME_UNITS = {
    "now": "sim_seconds", "at": "sim_seconds", "dt": "sim_seconds",
    "eta": "sim_seconds", "deadline": "sim_seconds",
    "duration": "sim_seconds", "makespan": "sim_seconds",
    "size": "bytes", "n_bytes": "bytes",
    "bw": "bytes_per_s", "bandwidth": "bytes_per_s", "rate": "bytes_per_s",
    "share": "bytes_per_s",
}

#: Name-suffix heuristics (kept deliberately short).
SUFFIX_UNITS = (("_bytes", "bytes"), ("_mbps", "mbps"),
                ("_us", "wall_seconds"))

#: API return dimensions (matched on the called attribute/function name).
CALL_UNITS = {
    "point_bandwidth": "bytes_per_s", "point_bandwidth_matrix": "bytes_per_s",
    "point_bandwidth_columns": "bytes_per_s",
    "point_bandwidth_column": "bytes_per_s",
    "mbps_to_bytes_per_s": "bytes_per_s",
    "free": "bytes", "rem_now": "bytes", "size": "bytes",
    "rerate": "sim_seconds", "flush": "sim_seconds",
    "us_to_s": "wall_seconds", "elapsed_us": "wall_seconds",
    "bytes_to_gb": None,
}

#: Calls whose result carries the dimension of their (first labelled)
#: argument — reductions, casts, elementwise array builders.
PASSTHROUGH_CALLS = frozenset(
    {"min", "max", "abs", "float", "sum", "round", "minimum", "maximum",
     "array", "asarray", "concatenate", "stack", "sorted"})

#: Keyword-parameter declarations checked at call sites (SL022/SL025).
PARAM_UNITS = {
    "lan_bandwidth": "bytes_per_s", "wan_bandwidth": "bytes_per_s",
    "bandwidth": "bytes_per_s", "uplink_bandwidths": "bytes_per_s",
    "storage_capacity": "bytes", "file_size": "bytes",
    "total_file_bytes": "bytes", "interarrival": "sim_seconds",
}

#: Magic scale factors SL024 hunts for outside quantities.py.
RAW_CONV_LITERALS = frozenset({1e3, 1e6, 1e9, 1e12, 125000.0})

#: Posix path substrings the shipped-tree units pass is scoped to: the
#: modules whose floats carry physical dimensions. quantities.py is in
#: scope (its constants must type-check) but exempt from SL024.
UNIT_SCOPE = (
    "repro/core/network.py", "repro/core/simulator.py",
    "repro/core/economy.py", "repro/core/metrics.py",
    "repro/core/access.py", "repro/core/scenarios.py",
    "repro/core/workload.py", "repro/core/topology.py",
    "repro/core/replica.py", "repro/core/quantities.py",
    "repro/obs/series.py",
)


def _is_real(label: Optional[str]) -> bool:
    return label in DIMENSIONS


def _is_conv(label: Optional[str]) -> bool:
    return label is not None and label.startswith("conv:")


class _UnitChecker(FlowAnalysis):
    """Dimension propagation + SL020-SL025, fixpoint mode."""

    fixpoint = True

    def __init__(self, path: str, source: str):
        super().__init__(path, source)
        self.in_quantities = path.replace("\\", "/").endswith(
            "core/quantities.py")
        self._class_depth = 0

    # -- registry lookups --------------------------------------------------

    def _name_decl(self, name: str) -> Optional[str]:
        label = NAME_UNITS.get(name)
        if label is not None:
            return label
        for suffix, unit in SUFFIX_UNITS:
            if name.endswith(suffix) and name != suffix:
                return unit
        return None

    def _attr_decl(self, attr: str) -> Optional[str]:
        """Registry first: a declared dimension outranks labels inferred
        from (possibly buggy) in-class assignments."""
        label = ATTR_UNITS.get(attr)
        if label is not None:
            return label
        label = self.attr_env.get(attr)
        if label is not None:
            return label
        for suffix, unit in SUFFIX_UNITS:
            if attr.endswith(suffix) and attr != suffix:
                return unit
        return None

    # -- the unit algebra --------------------------------------------------

    def _mul(self, left: Optional[str], right: Optional[str]) -> Optional[str]:
        if _is_conv(right) and not _is_conv(left):
            left, right = right, left       # conv handling is symmetric
        if _is_conv(left):
            if left == "conv:mbps_to_bytes_per_s":
                return "bytes_per_s" if right in (None, "mbps", "count") \
                    else None
            if left == "conv:bytes_scale":
                return "bytes" if right in (None, "count") else None
            if left == "conv:us_per_s":
                return "wall_seconds" if right in (None, "wall_seconds") \
                    else None
            return None
        pair = {left, right}
        if pair == {"bytes_per_s", "sim_seconds"}:
            return "bytes"
        if left == "count":
            return right
        if right == "count":
            return left
        if pair == {"score"}:
            return "score"
        if left is None:
            return right
        if right is None:
            return left
        return None                          # both known, no product rule

    def _div(self, left: Optional[str], right: Optional[str]) -> Optional[str]:
        if _is_conv(right):
            if right == "conv:mbps_to_bytes_per_s":
                return "mbps" if left == "bytes_per_s" else None
            if right == "conv:us_per_s" and left in (None, "wall_seconds"):
                return "wall_seconds"
            return None                      # e.g. report-scale `x / GB`
        if _is_conv(left):
            return None
        if left == "bytes" and right == "bytes_per_s":
            return "sim_seconds"
        if left == "bytes" and right == "sim_seconds":
            return "bytes_per_s"
        if left is not None and left == right:
            return "count"                   # dimensionless ratio
        if right == "count":
            return left
        if right is None:
            return left
        return None

    def _addsub(self, left: Optional[str], right: Optional[str]
                ) -> Optional[str]:
        if _is_conv(left) or _is_conv(right):
            return None
        if left == right:
            return left
        if left is None:
            return right
        if right is None:
            return left
        return None                          # mismatch: flagged at the site

    # -- expression labelling (the FlowAnalysis hook) ----------------------

    def expr_label(self, node: ast.expr | None) -> Optional[str]:
        if node is None:
            return None
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            if node.id in CONV_CONSTANTS:
                return CONV_CONSTANTS[node.id]
            return self._name_decl(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in CONV_CONSTANTS:
                return CONV_CONSTANTS[node.attr]
            return self._attr_decl(node.attr)
        if isinstance(node, ast.Subscript):
            return self.expr_label(node.value)   # arrays carry one unit
        if isinstance(node, ast.UnaryOp):
            return self.expr_label(node.operand)
        if isinstance(node, ast.IfExp):
            return (self.expr_label(node.body)
                    or self.expr_label(node.orelse))
        if isinstance(node, (ast.List, ast.Tuple)):
            return self.expr_label(node.elts[0]) if node.elts else None
        if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            return self.expr_label(node.elt)
        if isinstance(node, ast.BinOp):
            left = self.expr_label(node.left)
            right = self.expr_label(node.right)
            if isinstance(node.op, ast.Mult):
                return self._mul(left, right)
            if isinstance(node.op, (ast.Div, ast.FloorDiv)):
                return self._div(left, right)
            if isinstance(node.op, (ast.Add, ast.Sub)):
                return self._addsub(left, right)
            return None
        if isinstance(node, ast.Call):
            name = self.func_name(node.func)
            if name in CALL_UNITS:
                return CALL_UNITS[name]
            if name in PASSTHROUGH_CALLS:
                for arg in node.args:
                    label = self.expr_label(arg)
                    if label is not None:
                        return label
            return None
        return None

    # -- rule sites --------------------------------------------------------

    def _mismatch_rule(self, left: str, right: str) -> str:
        return ("SL023" if {left, right} == {"sim_seconds", "wall_seconds"}
                else "SL020")

    def visit_BinOp(self, node: ast.BinOp) -> None:
        left = self.expr_label(node.left)
        right = self.expr_label(node.right)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if _is_real(left) and _is_real(right) and left != right:
                rule = self._mismatch_rule(left, right)
                what = ("sim-clock and wall-clock time"
                        if rule == "SL023" else f"{left} and {right}")
                self.flag(rule, node,
                          f"adding/subtracting {what}: convert one side "
                          "first (see repro.core.quantities)")
        elif isinstance(node.op, (ast.Div, ast.FloorDiv)):
            if _is_real(left) and right == "mbps":
                self.flag("SL022", node,
                          f"dividing {left} by an Mbps-vocabulary value; "
                          "convert with MBPS_TO_BYTES_PER_S first")
            self._check_raw_literal(node, left, right)
        elif isinstance(node.op, ast.Mult):
            self._check_raw_literal(node, left, right)
        self.generic_visit(node)

    def _check_raw_literal(self, node: ast.BinOp, left: Optional[str],
                           right: Optional[str]) -> None:
        if self.in_quantities:
            return
        for lit, other in ((node.left, right), (node.right, left)):
            if (isinstance(lit, ast.Constant)
                    and isinstance(lit.value, (int, float))
                    and float(lit.value) in RAW_CONV_LITERALS
                    and _is_real(other)):
                self.flag("SL024", node,
                          f"raw conversion literal {lit.value!r} scales a "
                          f"{other} value; use the named constant from "
                          "repro.core.quantities")

    def visit_Compare(self, node: ast.Compare) -> None:
        left_node = node.left
        for op, comp in zip(node.ops, node.comparators):
            if isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE,
                               ast.Eq, ast.NotEq)):
                left = self.expr_label(left_node)
                right = self.expr_label(comp)
                if _is_real(left) and _is_real(right) and left != right:
                    rule = self._mismatch_rule(left, right)
                    rule = "SL023" if rule == "SL023" else "SL021"
                    what = ("sim-clock against wall-clock time"
                            if rule == "SL023" else f"{left} against {right}")
                    self.flag(rule, node, f"comparing {what}")
            left_node = comp
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            target = self.expr_label(node.target)
            value = self.expr_label(node.value)
            if _is_real(target) and _is_real(value) and target != value:
                rule = self._mismatch_rule(target, value)
                self.flag(rule, node,
                          f"accumulating {value} into a {target} target")
        self.generic_visit(node)

    def _declared_target(self, target: ast.expr) -> Optional[str]:
        if isinstance(target, ast.Attribute):
            return self._attr_decl(target.attr)
        if isinstance(target, ast.Subscript):
            return self.expr_label(target.value)
        if isinstance(target, ast.Name) and self._class_depth:
            return self._attr_decl(target.id)    # dataclass field default
        return None                              # plain locals may rebind

    def _check_binding(self, node: ast.AST, declared: Optional[str],
                       value: Optional[str], what: str) -> None:
        if not (_is_real(declared) and _is_real(value)) or declared == value:
            return
        if declared == "bytes_per_s" and value == "mbps":
            self.flag("SL022", node,
                      f"{what} is declared bytes_per_s but gets an Mbps-"
                      "vocabulary value; multiply by MBPS_TO_BYTES_PER_S")
        else:
            self.flag("SL025", node,
                      f"{what} is declared {declared} but gets a "
                      f"{value} value")

    def visit_Assign(self, node: ast.Assign) -> None:
        super().visit_Assign(node)
        value = self.expr_label(node.value)
        for target in node.targets:
            self._check_binding(node, self._declared_target(target), value,
                                ast.unparse(target))

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        super().visit_AnnAssign(node)
        if node.value is not None:
            self._check_binding(node, self._declared_target(node.target),
                                self.expr_label(node.value),
                                ast.unparse(node.target))

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_depth += 1
        try:
            super().visit_ClassDef(node)
        finally:
            self._class_depth -= 1

    def visit_Call(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if kw.arg is None:
                continue
            declared = PARAM_UNITS.get(kw.arg)
            if declared is not None:
                self._check_binding(kw.value, declared,
                                    self.expr_label(kw.value),
                                    f"keyword {kw.arg}=")
        self.generic_visit(node)


def lint_units(source: str, path: str) -> list[Finding]:
    """Run the unit rules over one file's source text."""
    tree = ast.parse(source, filename=path)
    return _UnitChecker(path, source).run(tree)


def unit_scoped(path: str) -> bool:
    """True when ``path`` is one of the dimension-carrying modules."""
    posix = path.replace("\\", "/")
    return any(posix.endswith(scope) for scope in UNIT_SCOPE)


def run_units(paths: list[str] | None = None) -> tuple[list[Finding], int, dict]:
    """Unit-check the scoped tree (or explicit ``paths``).

    Returns ``(findings, n_inline_suppressed, report)`` where ``report``
    is the JSON-ready payload for ``results/ANALYSIS_units.json``.
    """
    from pathlib import Path

    from . import RULES, _rel_path, collect_files

    if paths is None:
        files = [p for p in collect_files() if unit_scoped(str(p))]
    else:
        files = [Path(p) for p in paths]
    findings: list[Finding] = []
    n_inline = 0
    scanned: list[str] = []
    for path in sorted(files):
        source = path.read_text(encoding="utf-8")
        rel = _rel_path(path)
        scanned.append(rel)
        suppressed = inline_suppressions(source)
        for f in lint_units(source, rel):
            if is_inline_suppressed(f, suppressed):
                n_inline += 1
            else:
                findings.append(f)
    report = {
        "rules": {r: RULES[r] for r in sorted(RULES) if r >= "SL020"},
        "files": scanned,
        "n_findings": len(findings),
        "inline_suppressed": n_inline,
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line,
             "message": f.message, "snippet": f.snippet,
             "fingerprint": f.fingerprint()}
            for f in findings],
    }
    return findings, n_inline, report
