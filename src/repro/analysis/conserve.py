"""Runtime conservation auditor: ledger-closure invariants, replayed.

The static passes (simlint / coherence / units) prove structural
properties of the *source*; this module checks the complementary
dynamic property — that the engine's double-entry accounting actually
closes over a real run. Every byte the network engine bills must show
up in exactly one access-history ledger, every reserved byte of storage
must be backed by a catalogued replica, and every speculative prefetch
the economy started must have been debited once. A drift here is
invisible to the golden suites until it changes a *reported* metric;
the auditor catches the books going out of balance directly, on both
the numpy and on-device engines.

Wired like the tie-race sanitizer (:mod:`repro.analysis.tierace`): the
simulator is built by hand from a named :class:`~repro.core.scenarios.
ScenarioSpec` so the post-run engine objects stay inspectable (the
public :func:`~repro.core.metrics.run_experiment` only returns the
aggregated :class:`ExperimentResult`). Arrival handling matches
``run_experiment`` exactly — bursts and spec-driven arrival processes
included — so the audited runs are the shipped runs.

Invariants (failure-free runs; ``I2``/``I7`` are skipped when the
scenario injects churn because aborted transfers are billed at start):

* **I1 byte ledger** — ``total_wan_bytes + total_lan_bytes`` (billed at
  transfer start by the engine) equals ``wan_bytes + lan_bytes +
  prefetch_bytes`` in the access history (debited by the same call).
* **I2 inter-comms** — ``total_inter_comms`` equals the access ledger's
  ``remote_fetches``: every inter-region job fetch is counted once on
  each side.
* **I3 site occupancy** — per site: ``used_storage`` equals the summed
  catalog sizes of ``storage.site_contents(site)``, never exceeds
  ``storage_capacity``, and the contents set equals the catalog's
  holder view of that site (replica-table coherence, dynamic half of
  SL011/SL013).
* **I4 aggregate replicas** — total ``used_storage`` over sites equals
  ``sum(size(lfn) * n_holders(lfn))`` over the catalog.
* **I5 drained** — no in-flight transfers survive ``run()``.
* **I6 prefetch ledger** — the access history's ``prefetches`` equals
  the result's counter, equals the obs probe's ``econ.prefetch_started``
  count, and never exceeds the optimizer's ``proposed`` total.
* **I7 completion** — every submitted job produced a record.

Float note: file sizes are exact float64 values (multiples of
``500 * MB``) and the summed totals stay far below 2**53, so the
equalities hold *exactly* on a sound engine; the comparisons still use
a relative tolerance so the auditor reports a broken invariant rather
than FP noise if a future scenario uses non-representable sizes.
"""

from __future__ import annotations

import math
from typing import Any

#: Relative tolerance for byte-total comparisons (see float note above).
REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=REL_TOL, abs_tol=1e-6)


def _check(checks: dict[str, Any], name: str, ok: bool, lhs, rhs,
           detail: str) -> None:
    checks[name] = {"ok": bool(ok), "lhs": lhs, "rhs": rhs, "what": detail}


def conservation_audit(scenario: str = "paper_baseline", *,
                       n_jobs: int | None = None,
                       net: str | None = None,
                       seed: int | None = None,
                       obs: str = "report") -> dict[str, Any]:
    """Run a scenario to completion and audit the ledgers.

    ``n_jobs`` / ``net`` / ``seed`` override the spec (the CI smoke
    trims job counts); ``obs="report"`` keeps the probe counters the I6
    prefetch check reads. Returns a JSON-ready report with per-invariant
    ``{ok, lhs, rhs, what}`` entries and an overall ``ok``.
    """
    from repro.core.scenarios import (arrival_schedule, get_scenario,
                                      to_grid_config)
    from repro.core.simulator import GridSimulator
    from repro.core.workload import build_catalog, build_topology, generate_jobs
    from repro.fault.failures import churn_schedule

    spec = get_scenario(scenario)
    cfg = to_grid_config(spec, seed)
    if n_jobs is not None:
        cfg.n_jobs = n_jobs
    net = spec.net if net is None else net
    topology = build_topology(
        cfg, path_model="topmost" if net == "topmost" else "full")
    catalog = build_catalog(cfg, topology)
    sim = GridSimulator(
        topology, catalog, scheduler=spec.scheduler, strategy=spec.strategy,
        strategy_mode=spec.strategy_mode, seed=cfg.seed, broker=spec.broker,
        batch_window=spec.batch_window_s, net=net, econ=spec.econ,
        econ_interval=spec.econ_interval_s, obs=obs)
    for info in catalog.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    jobs = generate_jobs(cfg)
    times = arrival_schedule(spec, len(jobs), seed=cfg.seed)
    for j, job in enumerate(jobs):
        at = (times[j] if times is not None
              else (j // spec.arrival_burst) * cfg.interarrival
              * spec.arrival_burst)
        sim.submit_job(job, at=at)
    failures = churn_schedule(spec.churn, topology.n_sites, seed=cfg.seed)
    for site, at, dur in failures:
        sim.inject_failure(site, at, dur)
    for site, at, dur, factor in spec.slowdowns:
        sim.inject_slowdown(site, at, dur, factor)
    res = sim.run()
    failure_free = not failures and not spec.slowdowns

    checks: dict[str, Any] = {}
    acc = sim.access

    billed = res.total_wan_bytes + res.total_lan_bytes
    debited = acc.wan_bytes + acc.lan_bytes + acc.prefetch_bytes
    _check(checks, "I1_byte_ledger", _close(billed, debited), billed, debited,
           "engine WAN+LAN bytes == access-history fetch+prefetch bytes")

    if failure_free:
        _check(checks, "I2_inter_comms",
               res.total_inter_comms == acc.remote_fetches,
               res.total_inter_comms, acc.remote_fetches,
               "inter-region comms counter == remote fetches debited")

    occupancy_ok = True
    coherent_ok = True
    capacity_ok = True
    bad_site = None
    for site in topology.sites:
        contents = sim.storage.site_contents(site.site_id)
        held = sum(catalog.size(lfn) for lfn in contents)
        cat_view = {lfn for lfn, info in catalog.files.items()
                    if site.site_id in catalog.holders(lfn)}
        if not _close(site.used_storage, held):
            occupancy_ok = False
        if set(contents) != cat_view:
            coherent_ok = False
        if site.used_storage > site.storage_capacity * (1 + REL_TOL):
            capacity_ok = False
        if not (occupancy_ok and coherent_ok and capacity_ok) \
                and bad_site is None:
            bad_site = site.site_id
    _check(checks, "I3_site_occupancy",
           occupancy_ok and coherent_ok and capacity_ok,
           bad_site, None,
           "per-site used_storage == sum(contents sizes) <= capacity, "
           "contents set == catalog holders")

    total_used = sum(s.used_storage for s in topology.sites)
    replica_bytes = sum(info.size * len(catalog.holders(lfn))
                        for lfn, info in catalog.files.items())
    _check(checks, "I4_aggregate_replicas", _close(total_used, replica_bytes),
           total_used, replica_bytes,
           "total used storage == sum(size * n_holders) over the catalog")

    _check(checks, "I5_drained", not sim._transfers, len(sim._transfers), 0,
           "no in-flight transfers survive run()")

    counters = getattr(sim._obs, "counters", {}) or {}
    started = counters.get("econ.prefetch_started", 0)
    proposed = sim._econ.proposed if sim._econ is not None else 0
    _check(checks, "I6_prefetch_ledger",
           (acc.prefetches == res.prefetches == started
            and started <= proposed),
           (acc.prefetches, res.prefetches, started), proposed,
           "prefetch debits == result counter == obs events <= proposals")

    if failure_free:
        _check(checks, "I7_completion", len(res.records) == len(jobs),
               len(res.records), len(jobs),
               "every submitted job produced a record")

    return {
        "scenario": scenario,
        "n_jobs": len(jobs),
        "net": net,
        "seed": cfg.seed,
        "failure_free": failure_free,
        "makespan": res.makespan,
        "checks": checks,
        "ok": all(c["ok"] for c in checks.values()),
    }


def run_conservation_smoke(*, n_jobs: int = 60) -> list[dict[str, Any]]:
    """The CLI/CI conservation gate: paper baseline + the economy
    regime (prefetch ledger live), numpy engine, trimmed workload."""
    return [
        conservation_audit("paper_baseline", n_jobs=n_jobs, net="numpy"),
        conservation_audit("economy_starved", n_jobs=n_jobs, net="numpy"),
    ]
