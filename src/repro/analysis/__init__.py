"""repro.analysis — static analysis for the simulation codebase.

Three passes over ``src/repro/`` (see ``docs/ANALYSIS.md`` for the rule
catalog and suppression syntax):

* **simlint** (:mod:`repro.analysis.simlint`) — AST determinism linter:
  hash-ordered iteration in mutation paths, unseeded/global randomness,
  wall-clock reads in sim-state code, float reductions over unordered
  containers, ``id()``/``hash()`` tie-breaks, heap pushes without the
  ``(time, seq)`` tie key.
* **coherence** (:mod:`repro.analysis.coherence`) — snapshot-coherence
  rules: every replica-table mutation flows through the
  listener-notifying :class:`~repro.core.catalog.ReplicaCatalog` API,
  every public read of engine-shared snapshot state calls ``sync()``
  first, and telemetry probe callbacks (``repro/obs/``) never mutate
  the engine objects they observe.
* **jaxpr audit** (:mod:`repro.analysis.jaxpr_audit`) — traces every
  registered kernel (:func:`repro.kernels.registered_kernels`) and checks
  rank ceilings, dtype discipline, host-callback freedom, per-equation
  intermediate-size budgets and per-operand unit signatures. Requires
  jax; the CLI auto-skips it when jax is unavailable.
* **units** (:mod:`repro.analysis.units`, ``--units``) — unit/dimension
  inference over the dimension-carrying modules on the
  :mod:`repro.analysis.dataflow` framework: cross-dimension arithmetic
  and comparisons, missing Mbps->bytes/s conversions, sim-/wall-clock
  mixing, raw conversion literals (rules SL020-SL025).
* **conserve** (:mod:`repro.analysis.conserve`, ``--conserve``) —
  runtime conservation auditor: replays scenarios and asserts the byte /
  storage / prefetch ledgers close exactly.

Run as ``python -m repro.analysis`` (see ``--help``); CI gates on
``--fail-on-findings``.
"""

from __future__ import annotations

from pathlib import Path

from .coherence import lint_coherence
from .findings import Baseline, Finding, inline_suppressions, is_inline_suppressed
from .simlint import lint_source

__all__ = [
    "Baseline", "Finding", "RULES", "RULE_FAMILIES", "analyze_file",
    "collect_files", "default_target", "run_analysis",
]

#: Rule catalog: id -> one-line description (``--list-rules``).
RULES: dict[str, str] = {
    "SL001": "iteration over a set/frozenset (hash order) outside an "
             "order-free consumer such as sorted()/any()/len()",
    "SL002": "global or unseeded PRNG use (random module, np.random.*) "
             "instead of a seeded Generator",
    "SL003": "float reduction (sum/math.fsum) over an unordered container "
             "— result depends on hash order",
    "SL004": "id()/hash() used in a sort key — ties break on memory "
             "layout, not data",
    "SL005": "wall-clock read (time.time/perf_counter/...) in sim-state "
             "code (repro/core/, repro/grid/, repro/obs/ — the telemetry "
             "probe itself is the sanctioned exemption)",
    "SL010": "heapq.heappush of an event tuple whose second element is "
             "not the monotonic seq tie-breaker",
    "SL011": "ReplicaCatalog._holders touched outside catalog.py, or "
             "mutated inside it without _notify",
    "SL012": "public method reads sync()-maintained snapshot state "
             "without calling sync() first",
    "SL013": "StorageState private maps touched outside replica.py, or "
             "mutated inside it without _notify",
    "SL014": "obs telemetry code mutates an object received as a "
             "parameter (probe callbacks are observation-only)",
    "SL020": "adding/subtracting values of different dimensions "
             "(bytes + seconds, ...)",
    "SL021": "comparing values of different dimensions",
    "SL022": "Mbps-vocabulary value used where bytes/s is declared, "
             "without the MBPS_TO_BYTES_PER_S conversion",
    "SL023": "sim-clock and wall-clock time mixed in one expression",
    "SL024": "raw conversion literal (1e6, 1e9, 125000.0, ...) scales a "
             "dimensioned value outside repro.core.quantities",
    "SL025": "assignment or keyword binding contradicts the declared "
             "dimension of its target",
}

#: ``--list-rules`` grouping: family name -> rule-id prefix test.
RULE_FAMILIES: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("determinism (simlint)",
     ("SL001", "SL002", "SL003", "SL004", "SL005", "SL010")),
    ("coherence", ("SL011", "SL012", "SL013")),
    ("obs", ("SL014",)),
    ("units", ("SL020", "SL021", "SL022", "SL023", "SL024", "SL025")),
)

#: Files skipped entirely (the linter's own test fixtures would flag).
_SKIP_PARTS = ("__pycache__",)


def default_target() -> Path:
    """The in-repo ``src/repro`` tree this package ships in."""
    return Path(__file__).resolve().parents[1]


def collect_files(paths: list[Path] | None = None) -> list[Path]:
    """Expand ``paths`` (files or directories; default the repro package)
    into a sorted list of ``.py`` files."""
    roots = paths or [default_target()]
    out: set[Path] = set()
    for root in roots:
        root = Path(root)
        if root.is_file():
            out.add(root.resolve())
        else:
            for p in root.rglob("*.py"):
                if not any(part in _SKIP_PARTS for part in p.parts):
                    out.add(p.resolve())
    return sorted(out)


def _rel_path(path: Path) -> str:
    """Path as reported in findings: relative to the repo root when the
    file lives under it (stable fingerprints), absolute otherwise."""
    repo_root = default_target().parents[1]
    try:
        return path.resolve().relative_to(repo_root).as_posix()
    except ValueError:
        return path.as_posix()


def analyze_file(path: Path) -> tuple[list[Finding], int]:
    """Run both static passes on one file. Returns ``(findings,
    n_inline_suppressed)`` — inline ``# simlint: disable`` comments are
    applied here, baseline filtering is the caller's job."""
    source = path.read_text()
    rel = _rel_path(path)
    raw = lint_source(source, rel) + lint_coherence(source, rel)
    suppressions = inline_suppressions(source)
    findings = [f for f in raw if not is_inline_suppressed(f, suppressions)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule)), \
        len(raw) - len(findings)


def run_analysis(paths: list[Path] | None = None,
                 baseline: Baseline | None = None,
                 ) -> tuple[list[Finding], list[Finding], int]:
    """Run the static passes over ``paths``. Returns
    ``(new_findings, baselined_findings, n_inline_suppressed)``."""
    new: list[Finding] = []
    old: list[Finding] = []
    inline = 0
    for path in collect_files(paths):
        findings, n_inline = analyze_file(path)
        inline += n_inline
        for f in findings:
            (old if baseline is not None and f in baseline else new).append(f)
    return new, old, inline
