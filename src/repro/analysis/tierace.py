"""Tie-race helpers: static tie-key check + dynamic sanitizer drivers.

Three layers, weakest-to-strongest:

1. :func:`static_tie_key_findings` — simlint rule SL010: every
   ``heapq.heappush`` pushes a literal ``(time, seq, ...)`` tuple, so
   same-timestamp pops are ordered by the monotonic submission counter
   instead of whatever the heap sift happens to do.
2. :func:`canonical_records` — the order-free projection of a
   :class:`~repro.core.simulator.SimResult` used by the determinism
   property tests: per-job records sorted by job id plus the scalar
   totals. Two runs that differ only in same-timestamp *insertion order*
   must agree on this projection exactly.
3. :func:`sanitize_smoke` — runs a small paper-grid scenario with the
   engine's ``sanitize=True`` twin-replay mode and returns the tie
   report (how many tie instants were replayed, which raced).

A note on what "race" means here: the engine is deterministic by
construction — (time, seq) keys pin one canonical order. The sanitizer
asks the stronger question *"would a different causally-valid order at
this instant change observable state?"*. Sequential policies whose
decisions read mutable load state (or consume a shared PRNG stream) are
*expected* to race under reordering; the deterministic seq key is
exactly what makes that acceptable. The sanitizer exists to show the
batched/jax paths and the engine bookkeeping commute, and to surface
*unintended* order dependence before the on-device engine renegotiates
event ordering."""

from __future__ import annotations

from pathlib import Path

from .findings import Finding
from .simlint import lint_source


def static_tie_key_findings(paths: list[Path]) -> list[Finding]:
    """Run only the SL010 heappush-tie-key rule over ``paths``."""
    out: list[Finding] = []
    for path in paths:
        source = path.read_text()
        rel = path.as_posix()
        out.extend(f for f in lint_source(source, rel) if f.rule == "SL010")
    return out


def canonical_records(result) -> dict:
    """Order-free projection of a SimResult / run_experiment records list:
    identical across any causally-equivalent event reordering."""
    return {
        "records": sorted(
            (r.job_id, r.job_type, r.site, r.submit_time,
             r.data_ready_time, r.start_time, r.finish_time,
             r.inter_comms, r.wan_bytes, r.resubmits)
            for r in result.records),
        "total_inter_comms": result.total_inter_comms,
        "total_wan_bytes": result.total_wan_bytes,
        "total_lan_bytes": result.total_lan_bytes,
        "makespan": result.makespan,
    }


def sanitize_smoke(*, n_jobs: int = 40, seed: int = 0,
                   scheduler: str = "dataaware", strategy: str = "hrs"
                   ) -> dict:
    """Run a small paper grid with ``sanitize=True`` and burst arrivals
    (shared arrival timestamps force tie groups), returning the tie
    report. Used by ``python -m repro.analysis --tierace`` and the
    sanitizer tests."""
    from repro.core.simulator import GridSimulator
    from repro.core.workload import (GridConfig, build_catalog,
                                     build_topology, generate_jobs)

    cfg = GridConfig(seed=seed)
    topology = build_topology(cfg)
    catalog = build_catalog(cfg, topology)
    sim = GridSimulator(topology, catalog, scheduler=scheduler,
                        strategy=strategy, seed=cfg.seed, sanitize=True)
    for info in catalog.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    burst = 8
    for j, job in enumerate(generate_jobs(cfg, n_jobs)):
        sim.submit_job(job, at=(j // burst) * cfg.interarrival * burst)
    sim.run()
    return {
        "ties_seen": sim.ties_seen,
        "tie_races": [
            {"time": r.time, "kinds": list(r.kinds), "detail": r.detail}
            for r in sim.tie_races],
    }
