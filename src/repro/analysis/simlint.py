"""simlint — AST determinism linter for the simulation tree.

Flags constructs that let nondeterminism feed simulation state. The DES
engine's bit-identity contract (``tests/golden_metrics.json``) only
holds if every iteration order that touches state, every PRNG draw, and
every tie-break is reproducible across processes and
``PYTHONHASHSEED`` values. CPython dicts are insertion-ordered (and the
engine relies on that); **sets are hash-ordered**, wall clocks are
nondeterministic by definition, and ``id()`` is address-ordered — those
are what the rules target.

Rules (see docs/ANALYSIS.md for the full catalog with examples):

* **SL001** — iteration over a set/frozenset (``for``, comprehensions,
  ``list``/``tuple``/``min``/``max``/``np.fromiter``/star-unpacking
  consumers). Exempt: ``sorted(...)``-wrapped, order-free boolean
  consumers (``any``/``all``), set-to-set rebuilds, ``len``/``bool``.
* **SL002** — module-level / unseeded PRNG use (``random.random()``,
  ``np.random.rand()``...). Seeded instances (``random.Random(seed)``,
  ``np.random.default_rng(seed)``) are the sanctioned form.
* **SL003** — float reductions (``sum``/``math.fsum``) over unordered
  containers: FP addition is order-sensitive, so even a "complete"
  reduction drifts under hash reordering.
* **SL004** — ``id()``/``hash()`` used as a sort/min/max tie-break key.
* **SL005** — wall-clock reads (``time.time``, ``datetime.now``,
  ``uuid.uuid4``, ``os.urandom``) inside the simulation-state packages
  (``repro/core``, ``repro/grid``, ``repro/obs``). Measurement code
  (bench harnesses, the fault-injection *training* supervisor) lives
  outside that scope and may read real clocks. ``repro/obs/`` is the
  one sanctioned in-scope exemption: the telemetry probe exists to
  measure host phase time, and the companion rule SL014 (coherence)
  guarantees its callbacks cannot write engine state back.
* **SL010** — every ``heapq.heappush`` onto an event queue must push a
  ``(time, seq, ...)`` tuple: a literal tuple of length >= 2 whose
  second element mentions the sequence counter. This is the static half
  of the tie-race sanitizer: FIFO seq numbers make same-timestamp pops
  deterministic and independent of heap-sift internals.
"""

from __future__ import annotations

import ast
from typing import Optional

from .dataflow import FlowAnalysis

SET_ANNOTATIONS = frozenset(
    {"set", "frozenset", "Set", "FrozenSet", "AbstractSet", "MutableSet"})
SEQ_ANNOTATIONS = frozenset(
    {"list", "List", "tuple", "Tuple", "Sequence", "MutableSequence",
     "Iterable", "Iterator", "Collection"})
MAP_ANNOTATIONS = frozenset(
    {"dict", "Dict", "Mapping", "MutableMapping", "defaultdict",
     "OrderedDict"})
SET_METHODS = frozenset(
    {"union", "intersection", "difference", "symmetric_difference", "copy"})
#: Repo APIs documented to return sets (ReplicaCatalog.holders).
SET_RETURNING_METHODS = frozenset({"holders"})
#: Consumers whose result cannot depend on iteration order.
ORDER_FREE_CONSUMERS = frozenset(
    {"sorted", "any", "all", "set", "frozenset", "len", "bool"})
#: Order-sensitive consumers that realize iteration order.
ORDERED_CONSUMERS = frozenset(
    {"list", "tuple", "iter", "enumerate", "reversed", "next", "fromiter",
     "min", "max", "concatenate", "stack", "array"})
FLOAT_REDUCERS = frozenset({"sum", "fsum"})
RANDOM_MODULE_FUNCS = frozenset(
    {"random", "randint", "randrange", "choice", "choices", "shuffle",
     "sample", "uniform", "gauss", "normalvariate", "expovariate",
     "betavariate", "triangular", "getrandbits", "seed", "vonmisesvariate",
     "paretovariate", "weibullvariate", "lognormvariate"})
NP_RANDOM_OK = frozenset(
    {"default_rng", "Generator", "RandomState", "SeedSequence", "PCG64",
     "Philox", "SFC64", "MT19937", "BitGenerator"})
CLOCK_CALLS = frozenset(
    {"time.time", "time.monotonic", "time.perf_counter",
     "time.process_time", "time.time_ns", "time.monotonic_ns",
     "time.perf_counter_ns", "datetime.now", "datetime.utcnow",
     "datetime.today", "date.today", "uuid.uuid1", "uuid.uuid4",
     "os.urandom"})
#: Paths (posix substrings) where SL005 wall-clock reads are banned.
SIM_STATE_PATHS = ("repro/core/", "repro/grid/", "repro/obs/")
#: SL005 carve-out: the telemetry probe is *the* sanctioned wall-clock
#: reader — phase timers are host-time by definition. Its inability to
#: feed that nondeterminism back into simulation state is checked by
#: SL014 instead (repro.analysis.coherence).
SL005_EXEMPT_PATHS = ("repro/obs/",)


def _ann_kind(ann: ast.expr | None) -> Optional[str]:
    """Classify a type annotation: 'set', 'container_of_set', or None."""
    if ann is None:
        return None
    if isinstance(ann, ast.Name):
        return "set" if ann.id in SET_ANNOTATIONS else None
    if isinstance(ann, ast.Attribute):       # typing.Set / t.AbstractSet
        return "set" if ann.attr in SET_ANNOTATIONS else None
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return _ann_kind(ann.left) or _ann_kind(ann.right)
    if isinstance(ann, ast.Subscript):
        head = ann.value
        name = (head.id if isinstance(head, ast.Name)
                else head.attr if isinstance(head, ast.Attribute) else None)
        if name in SET_ANNOTATIONS:
            return "set"
        inner = (ann.slice.elts if isinstance(ann.slice, ast.Tuple)
                 else [ann.slice])
        if name in SEQ_ANNOTATIONS | MAP_ANNOTATIONS:
            if any(_ann_kind(a) == "set" for a in inner):
                return "container_of_set"
    return None


class _Linter(FlowAnalysis):
    """Determinism rules riding on the dataflow framework.

    Runs in single-pass mode (``fixpoint = False``) — the visiting order
    and env semantics are exactly the pre-framework linter's, which
    keeps the finding corpus identical (pinned by tests/test_units.py).
    Labels: ``'set'`` / ``'container_of_set'``.
    """

    def __init__(self, path: str, source: str):
        super().__init__(path, source)
        self.in_sim_path = (any(s in path for s in SIM_STATE_PATHS)
                            and not any(s in path
                                        for s in SL005_EXEMPT_PATHS))

    # -- set-expression classification ------------------------------------

    def ann_label(self, ann: ast.expr | None) -> Optional[str]:
        return _ann_kind(ann)

    def expr_label(self, node: ast.expr | None) -> Optional[str]:
        if node is None:
            return None
        if isinstance(node, (ast.Set, ast.SetComp)):
            return "set"
        if isinstance(node, ast.ListComp):
            return ("container_of_set"
                    if self.expr_label(node.elt) == "set" else None)
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                return self.attr_env.get(node.attr)
            return None
        if isinstance(node, ast.Subscript):
            if self.expr_label(node.value) == "container_of_set":
                return "set"
            return None
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            left, right = self.expr_label(node.left), \
                self.expr_label(node.right)
            if "set" in (left, right):
                return "set"
            return None
        if isinstance(node, ast.IfExp):
            return self.expr_label(node.body) or self.expr_label(node.orelse)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in ("set", "frozenset"):
                return "set"
            if isinstance(fn, ast.Attribute):
                if fn.attr in SET_RETURNING_METHODS:
                    return "set"
                if (fn.attr in SET_METHODS
                        and self.expr_label(fn.value) == "set"):
                    return "set"
            return None
        return None

    def _is_set(self, node: ast.expr | None) -> bool:
        return self.expr_label(node) == "set"

    # -- SL001 iteration sites ---------------------------------------------

    def visit_For(self, node: ast.For) -> None:
        if self._is_set(node.iter):
            self.flag("SL001", node,
                      "iteration over a set is hash-ordered; wrap in "
                      "sorted(...) or keep an insertion-ordered dict")
        self.generic_visit(node)

    def _check_comprehension(self, node, *, exempt: bool) -> None:
        for gen in node.generators:
            if self._is_set(gen.iter) and not exempt:
                self.flag("SL001", gen.iter,
                          "comprehension over a set is hash-ordered; wrap "
                          "the iterable in sorted(...)")

    def visit_ListComp(self, node: ast.ListComp) -> None:
        self._check_comprehension(node, exempt=False)
        self.generic_visit(node)

    def visit_DictComp(self, node: ast.DictComp) -> None:
        self._check_comprehension(node, exempt=False)
        self.generic_visit(node)

    def visit_SetComp(self, node: ast.SetComp) -> None:
        self._check_comprehension(node, exempt=True)   # set -> set: unordered
        self.generic_visit(node)

    def visit_Starred(self, node: ast.Starred) -> None:
        if self._is_set(node.value):
            self.flag("SL001", node,
                      "star-unpacking a set realizes hash order")
        self.generic_visit(node)

    # -- calls: consumers, PRNG, clocks, heappush, key= --------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = self.func_name(node.func)
        qual = self.qualified(node.func)

        # SL001/SL003: ordered consumers fed a set
        if name in ORDERED_CONSUMERS or name in FLOAT_REDUCERS:
            for arg in node.args:
                target = arg.value if isinstance(arg, ast.Starred) else arg
                if isinstance(target, ast.GeneratorExp):
                    if name in FLOAT_REDUCERS and any(
                            self._is_set(g.iter)
                            for g in target.generators):
                        self.flag("SL003", node,
                                  f"float reduction {name}() over a "
                                  "hash-ordered set drifts under "
                                  "reordering; sort the iterable")
                    continue       # ordered consumers of generators: the
                                   # generator's own source was checked
                if self._is_set(target):
                    rule = ("SL003" if name in FLOAT_REDUCERS else "SL001")
                    self.flag(rule, node,
                              f"{name}() over a set realizes hash order; "
                              "wrap the set in sorted(...)")
        if name in ORDER_FREE_CONSUMERS:
            # visit children but skip generator-over-set checks: consumer
            # is order-free (any/all/sorted/set/len/bool)
            for arg in node.args:
                if isinstance(arg, ast.GeneratorExp):
                    self._check_comprehension(arg, exempt=True)
                    for g in arg.generators:
                        self.visit(g.iter)
                    self.visit(arg.elt)
                else:
                    self.visit(arg)
            for kw in node.keywords:
                self.visit(kw.value)
            self.visit(node.func)
            self._check_key_kwarg(node, name)
            return

        # SL002: module-level / unseeded PRNG
        self._check_prng(node, name, qual)
        # SL005: wall-clock in sim-state paths
        if self.in_sim_path and qual in CLOCK_CALLS:
            self.flag("SL005", node,
                      f"wall-clock read {qual}() inside simulation state; "
                      "sim time must come from the event loop")
        # SL010: heappush tie keys
        if qual == "heapq.heappush" or (name == "heappush"
                                        and qual.endswith(".heappush")):
            self._check_heappush(node)
        # SL004: id()/hash() tie-breaks in sort keys
        self._check_key_kwarg(node, name)
        self.generic_visit(node)

    def _check_prng(self, node: ast.Call, name: str, qual: str) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
            mod = self.module_aliases.get(fn.value.id)
            if mod == "random" and fn.attr in RANDOM_MODULE_FUNCS:
                self.flag("SL002", node,
                          f"module-level random.{fn.attr}() shares global "
                          "state; use a seeded random.Random instance")
            if mod == "numpy.random" and fn.attr not in NP_RANDOM_OK:
                self.flag("SL002", node,
                          f"global numpy.random.{fn.attr}() is unseeded; "
                          "use np.random.default_rng(seed)")
        # np.random.<fn>(...) — Attribute(Attribute(Name(np), random), fn)
        if (isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Attribute)
                and fn.value.attr == "random"
                and isinstance(fn.value.value, ast.Name)
                and self.module_aliases.get(fn.value.value.id) == "numpy"
                and fn.attr not in NP_RANDOM_OK):
            self.flag("SL002", node,
                      f"global np.random.{fn.attr}() is unseeded; use "
                      "np.random.default_rng(seed)")
        if qual.startswith("random.") and name in RANDOM_MODULE_FUNCS \
                and isinstance(fn, ast.Name):
            self.flag("SL002", node,
                      f"from-imported random.{name}() shares global state; "
                      "use a seeded random.Random instance")

    def _check_heappush(self, node: ast.Call) -> None:
        if len(node.args) < 2:
            return
        item = node.args[1]
        if not isinstance(item, ast.Tuple) or len(item.elts) < 2:
            self.flag("SL010", node,
                      "heappush item must be a literal (time, seq, ...) "
                      "tuple so same-timestamp pops stay deterministic")
            return
        second = ast.unparse(item.elts[1])
        if "seq" not in second.lower():
            self.flag("SL010", node,
                      "heappush tie-break key (2nd tuple element) must be "
                      f"the monotonic seq counter, got {second!r}")

    def _check_key_kwarg(self, node: ast.Call, name: str) -> None:
        if name not in ("sorted", "min", "max", "sort"):
            return
        for kw in node.keywords:
            if kw.arg != "key":
                continue
            expr = kw.value
            if isinstance(expr, ast.Name) and expr.id in ("id", "hash"):
                self.flag("SL004", node,
                          f"{expr.id}() as a sort key is address/hash-"
                          "ordered; use a stable domain key")
            for sub in ast.walk(expr):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Name)
                        and sub.func.id in ("id", "hash")):
                    self.flag("SL004", sub,
                              f"{sub.func.id}() inside a sort key is "
                              "address/hash-ordered; use a stable "
                              "domain key")


def lint_source(source: str, path: str) -> list[Finding]:
    """Run the simlint rules over one file's source text."""
    tree = ast.parse(source, filename=path)
    return _Linter(path, source).run(tree)
