"""repro.obs — phase-timed, trace-exporting telemetry for the simulator.

Zero-overhead-when-disabled observability layer (PR 9). The simulator,
brokers, batched strategy planner, network engine, and economy are
instrumented with :class:`~repro.obs.probe.Probe` spans and counters;
``obs=`` engine flags (plumbed like ``net=``/``econ=`` through
``GridSimulator``, ``run_experiment``, ``ScenarioSpec``, and
``launch/simulate.py``) select how much is collected:

========  ============================================================
mode      collects
========  ============================================================
off       nothing — hot paths pay a single ``is None`` check (default)
report    host-phase timers + counters -> :class:`TelemetryReport`
series    report + sim-time ring-buffer channels (periodic OBS event)
trace     series + Chrome trace (Perfetto) JSON and JSONL event log
========  ============================================================

The layer is observation-only: enabling any mode leaves every golden
metric bit-identical (the same contract ``sanitize=True`` honors), and
simlint rule SL014 machine-checks that obs callbacks never mutate
simulator/catalog/storage state. See ``docs/OBSERVABILITY.md``.
"""

from .probe import (DEFAULT_OBS_INTERVAL_S, OBS_MODES, Probe, make_probe)
from .report import (DISPATCH_PHASES, FLUSH_PHASES, PLAN_PHASES,
                     TelemetryReport)
from .series import CHANNELS, GridSampler, RingBuffer
from .trace import TraceWriter

__all__ = [
    "CHANNELS",
    "DEFAULT_OBS_INTERVAL_S",
    "DISPATCH_PHASES",
    "FLUSH_PHASES",
    "GridSampler",
    "OBS_MODES",
    "PLAN_PHASES",
    "Probe",
    "RingBuffer",
    "TelemetryReport",
    "TraceWriter",
    "make_probe",
]
