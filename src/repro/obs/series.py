"""Sim-time telemetry series: ring buffers fed by the periodic OBS event.

The simulator arms a periodic ``OBS`` event (mirroring the ECON auction
clock) whose handler calls :meth:`GridSampler.sample` with the live
engine. Each call appends one row of grid-state channels — link
utilization, SE occupancy, queue depths, cumulative WAN/LAN bytes,
replica hit/miss totals — into a fixed-capacity :class:`RingBuffer`, so
telemetry memory is O(capacity) regardless of run length. The arrays are
queryable per channel as numpy vectors (:meth:`GridSampler.arrays`) and
are the raw input signal for the ROADMAP's observed-throughput channel
scheduler (sliding-window byte rates come from differencing the
cumulative channels against ``t``).

Everything here is read-only over the engine: ``sample`` touches
``sim.*`` attributes through plain reads and aggregate numpy reductions,
never a mutating call — machine-checked by simlint rule SL014.
"""

from __future__ import annotations

import numpy as np

#: Channels captured per OBS sample, in column order. Cumulative
#: channels (``wan_bytes``, ``lan_bytes``, ``accesses``, ``hits``,
#: ``prefetch_bytes``, ``completed_jobs``) are monotone totals at sample
#: time — difference adjacent rows for rates.
CHANNELS = (
    "t",                  # sim-clock seconds of the sample
    "active_transfers",   # in-flight file transfers (NetworkEngine.n_active)
    "busy_links",         # links (NIC + WAN) with nonzero allocated rate
    "wan_busy_links",     # busy links restricted to the WAN slice
    "link_busy_frac",     # busy_links / n_links
    "queued_jobs",        # CPU-queue depth across sites (incl. tombstones)
    "running_jobs",       # jobs currently holding a CPU slot
    "completed_jobs",     # records emitted so far
    "se_used_frac",       # mean site storage occupancy (used / capacity)
    "wan_bytes",          # cumulative WAN bytes moved
    "lan_bytes",          # cumulative LAN bytes moved
    "accesses",           # cumulative catalog accesses
    "hits",               # cumulative local-replica hits
    "prefetch_bytes",     # cumulative speculative-prefetch bytes
)


class RingBuffer:
    """Fixed-capacity multi-channel sample store.

    Rows are float64; once ``capacity`` rows have been appended the
    oldest are overwritten. :meth:`arrays` returns each channel in
    chronological order (oldest surviving row first).
    """

    def __init__(self, capacity: int, channels: tuple[str, ...]) -> None:
        if capacity <= 0:
            raise ValueError(f"RingBuffer capacity must be > 0, got {capacity}")
        self.capacity = int(capacity)
        self.channels = tuple(channels)
        self._data = np.zeros((self.capacity, len(self.channels)), np.float64)
        self.n_total = 0          # rows ever appended (may exceed capacity)

    def append(self, row) -> None:
        self._data[self.n_total % self.capacity] = row
        self.n_total += 1

    def __len__(self) -> int:
        return min(self.n_total, self.capacity)

    def rows(self) -> np.ndarray:
        """Surviving rows, oldest first, shape ``(len(self), n_channels)``."""
        n = len(self)
        if self.n_total <= self.capacity:
            return self._data[:n].copy()
        head = self.n_total % self.capacity
        return np.concatenate([self._data[head:], self._data[:head]])

    def arrays(self) -> dict[str, np.ndarray]:
        """Per-channel chronological vectors keyed by channel name."""
        rows = self.rows()
        return {name: rows[:, i].copy()
                for i, name in enumerate(self.channels)}


class GridSampler:
    """Reads one row of :data:`CHANNELS` from a live ``GridSimulator``.

    Duck-typed against the engine (``sim.now``, ``sim.network``,
    ``sim.topology`` …) so the obs package never imports ``repro.core``
    — the simulator imports *us*, not the reverse.
    """

    def __init__(self, capacity: int = 8192) -> None:
        self.ring = RingBuffer(capacity, CHANNELS)

    @property
    def n_total(self) -> int:
        return self.ring.n_total

    def sample(self, sim) -> None:
        """Append one sample of grid state at ``sim.now`` (read-only)."""
        net = sim.network
        n_links = net.n_links
        n_sites = len(sim.topology.sites)
        busy = int(np.count_nonzero(net.link_act > 0.0))
        # Link index space is NIC links [0, n_sites) then WAN links.
        wan_busy = int(np.count_nonzero(net.link_act[n_sites:] > 0.0))
        queued = 0
        for q in sim._cpu_queue.values():
            queued += len(q)
        running = 0
        for js in sim._running.values():
            if js is not None:
                running += 1
        used_frac = 0.0
        for site in sim.topology.sites:
            used_frac += site.used_storage / site.storage_capacity
        used_frac /= max(n_sites, 1)
        acc = sim.access
        self.ring.append((
            sim.now,
            float(net.n_active),
            float(busy),
            float(wan_busy),
            busy / max(n_links, 1),
            float(queued),
            float(running),
            float(len(sim.records)),
            used_frac,
            float(sim.total_wan_bytes),
            float(sim.total_lan_bytes),
            float(acc.accesses),
            float(acc.hits),
            float(acc.prefetch_bytes),
        ))

    def arrays(self) -> dict[str, np.ndarray]:
        return self.ring.arrays()
