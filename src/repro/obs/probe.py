"""Host-phase timers + counters: the probe half of the telemetry layer.

A :class:`Probe` carries the three per-run telemetry surfaces:

* **spans** — ``with probe.span("broker.dispatch"): ...`` wall-clock
  phase timers with *exclusive* (self-time) accounting: a span's self
  time is its inclusive wall time minus the inclusive time of the spans
  nested inside it, so the per-phase self times are a partition of
  measured wall and always sum to <= the run's total wall clock (the
  invariant the telemetry property tests pin).
* **counters** — ``probe.count("plan_cache.keep")`` monotonic integer
  counters, plus ``probe.event(name, sim_t)`` which counts one DES
  event (``event.<KIND>``) and, in trace mode, records a sim-time
  instant in the Chrome trace.
* **attachments** — an optional :class:`~repro.obs.series.GridSampler`
  (sim-time ring-buffer series) and
  :class:`~repro.obs.trace.TraceWriter` (Chrome trace export), owned
  here so the simulator holds exactly one telemetry handle.

The probe is *observation-only by construction*: it never holds a
reference to the simulator and none of its methods take mutable engine
state (``GridSampler.sample(sim)`` reads through the sim argument and is
machine-checked by simlint rule SL014). Wall-clock reads are sanctioned
here and only here among the sim-adjacent packages — simlint's SL005
scope explicitly exempts ``repro/obs/``.

Zero-overhead-when-disabled contract: the simulator stores ``None``
instead of a probe when ``obs="off"``, so the engine hot paths pay one
``is None`` check and nothing else; this module is only imported, never
entered.
"""

from __future__ import annotations

import time
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:                      # imports for annotations only
    from .report import TelemetryReport
    from .series import GridSampler
    from .trace import TraceWriter

#: ``obs=`` engine-flag vocabulary, weakest to strongest. Each mode is a
#: superset of the previous one:
#:
#: * ``"off"``     — no probe at all (the default; hot paths pay one
#:                   ``is None`` check).
#: * ``"report"``  — host-phase span timers + counters, aggregated into a
#:                   :class:`~repro.obs.report.TelemetryReport`.
#: * ``"series"``  — report + sim-time ring-buffer samplers driven by the
#:                   periodic OBS event (link/SE/queue utilization).
#: * ``"trace"``   — series + Chrome trace-event export (host-phase spans
#:                   on a wall-clock track, DES events on a sim-time
#:                   track) and a JSONL event log.
OBS_MODES = ("off", "report", "series", "trace")

#: Default sim-seconds between OBS sampling events (series/trace modes).
#: One sample per ~5 simulated minutes keeps a paper-baseline run (~30 k
#: sim-seconds) at ~100 rows and a grid_500 run (~1.5 M sim-seconds) well
#: inside the default ring capacity.
DEFAULT_OBS_INTERVAL_S = 300.0


class _Span:
    """One active ``probe.span(name)`` context. Exclusive-time
    bookkeeping: ``child_s`` accumulates the *inclusive* seconds of
    directly nested spans, so on exit ``inclusive - child_s`` is this
    span's self time."""

    __slots__ = ("probe", "name", "t0", "child_s")

    def __init__(self, probe: "Probe", name: str) -> None:
        self.probe = probe
        self.name = name
        self.t0 = 0.0
        self.child_s = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        self.probe._stack.append(self)
        return self

    def __exit__(self, *exc) -> None:
        p = self.probe
        incl = time.perf_counter() - self.t0
        p._stack.pop()
        name = self.name
        p.phase_self_s[name] = (p.phase_self_s.get(name, 0.0)
                                + incl - self.child_s)
        p.phase_total_s[name] = p.phase_total_s.get(name, 0.0) + incl
        p.phase_calls[name] = p.phase_calls.get(name, 0) + 1
        if p._stack:
            p._stack[-1].child_s += incl
        if p.trace is not None:
            p.trace.add_span(name, self.t0 - p._t0, incl)


class Probe:
    """Per-run telemetry collector (see module doc).

    Spans may nest arbitrarily; re-entering the same name recursively is
    allowed (each activation is its own :class:`_Span`). The probe is
    single-threaded by design — the DES engine is.
    """

    def __init__(self, mode: str, *,
                 sampler: Optional["GridSampler"] = None,
                 trace: Optional["TraceWriter"] = None) -> None:
        if mode not in OBS_MODES or mode == "off":
            raise ValueError(f"Probe mode must be an enabled OBS mode, "
                             f"got {mode!r} (want one of {OBS_MODES[1:]})")
        self.mode = mode
        self.sampler = sampler
        self.trace = trace
        self.counters: dict[str, int] = {}
        self.phase_self_s: dict[str, float] = {}
        self.phase_total_s: dict[str, float] = {}
        self.phase_calls: dict[str, int] = {}
        self._stack: list[_Span] = []
        self._t0 = time.perf_counter()
        self.wall_s = 0.0

    # -- recording ---------------------------------------------------------
    def span(self, name: str) -> _Span:
        """Context manager timing one phase activation."""
        return _Span(self, name)

    def count(self, name: str, n: int = 1) -> None:
        """Bump a monotonic counter."""
        self.counters[name] = self.counters.get(name, 0) + n

    def event(self, kind_name: str, sim_t: float) -> None:
        """Record one handled DES event: bumps ``event.<KIND>`` and, in
        trace mode, adds a sim-time instant to the Chrome trace."""
        key = "event." + kind_name
        self.counters[key] = self.counters.get(key, 0) + 1
        if self.trace is not None:
            self.trace.add_instant(kind_name, sim_t)

    def merge_counters(self, prefix: str, values: dict) -> None:
        """Fold an engine-owned counter dict (e.g. ``NetworkEngine.stats``)
        into the probe under ``prefix.<key>`` names."""
        for k in sorted(values):
            key = f"{prefix}.{k}"
            self.counters[key] = self.counters.get(key, 0) + int(values[k])

    # -- lifecycle ---------------------------------------------------------
    def elapsed_us(self, name: str) -> float:
        """Total *inclusive* microseconds spent in phase ``name`` — the
        drop-in replacement for the bench harness's hand-rolled
        ``perf_counter`` deltas."""
        return self.phase_total_s.get(name, 0.0) * 1e6

    def finalize(self, *, net_stats: dict | None = None) -> "TelemetryReport":
        """Stamp the run's wall clock and build the
        :class:`~repro.obs.report.TelemetryReport`. Idempotent on the
        timing state (wall advances monotonically if called twice)."""
        from .report import TelemetryReport  # deferred: report imports probe
        self.wall_s = time.perf_counter() - self._t0
        series = None
        if self.sampler is not None:
            series = self.sampler.arrays()
        return TelemetryReport(
            mode=self.mode,
            wall_s=self.wall_s,
            phase_self_s=dict(self.phase_self_s),
            phase_total_s=dict(self.phase_total_s),
            phase_calls=dict(self.phase_calls),
            counters=dict(self.counters),
            net_stats=dict(net_stats or {}),
            series=series,
            n_samples=0 if self.sampler is None else self.sampler.n_total,
            trace=self.trace,
            dropped_trace_events=(0 if self.trace is None
                                  else self.trace.dropped),
        )

    def __deepcopy__(self, memo: dict) -> None:
        """Deep copies drop the probe (-> ``None``): the tie-race
        sanitizer's twin engines replay instants for *comparison* and
        must not double-count events into the primary's telemetry —
        the same convention as the catalog/storage ``__deepcopy__``
        contracts dropping listeners."""
        return None


def make_probe(mode: str, *,
               series_capacity: int = 8192,
               trace_max_events: int = 1_000_000) -> Optional[Probe]:
    """Build the probe for an ``obs=`` mode (``None`` for ``"off"``).

    ``"report"`` is timers + counters only; ``"series"`` attaches the
    ring-buffer :class:`~repro.obs.series.GridSampler`; ``"trace"``
    additionally attaches a :class:`~repro.obs.trace.TraceWriter`.
    """
    if mode not in OBS_MODES:
        raise ValueError(f"unknown obs mode {mode!r} "
                         f"(want one of {OBS_MODES})")
    if mode == "off":
        return None
    sampler = None
    trace = None
    if mode in ("series", "trace"):
        from .series import GridSampler
        sampler = GridSampler(capacity=series_capacity)
    if mode == "trace":
        from .trace import TraceWriter
        trace = TraceWriter(max_events=trace_max_events)
    return Probe(mode, sampler=sampler, trace=trace)
