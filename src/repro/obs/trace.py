"""Structured trace export: Chrome trace-event JSON + JSONL event log.

:class:`TraceWriter` accumulates trace events in the Chrome trace-event
format (the ``{"traceEvents": [...]}`` JSON object array flavor), which
loads directly in Perfetto (https://ui.perfetto.dev) and legacy
``chrome://tracing``. Two tracks keep host time and sim time apart:

* **pid 1 "host"** — ``"ph": "X"`` complete events for host-phase spans
  (``broker.dispatch``, ``net.flush`` …). Timestamps are microseconds of
  wall clock relative to probe creation, durations are the span's
  inclusive wall time.
* **pid 2 "sim"** — ``"ph": "i"`` instant events, one per handled DES
  event (SUBMIT, NET, CPU_DONE …). Timestamps are *simulated* seconds
  rendered as microseconds, so one trace-viewer microsecond reads as one
  sim second on this track.

Event volume is bounded by ``max_events``; overflow increments
:attr:`TraceWriter.dropped` instead of growing without limit (the count
is surfaced on the TelemetryReport). The same event list serializes to a
line-per-event JSONL log via :meth:`save_jsonl` for ``jq``-style
post-processing.
"""

from __future__ import annotations

import json

PID_HOST = 1
PID_SIM = 2


class TraceWriter:
    """Bounded in-memory Chrome trace-event accumulator."""

    def __init__(self, max_events: int = 1_000_000) -> None:
        if max_events <= 0:
            raise ValueError(f"max_events must be > 0, got {max_events}")
        self.max_events = int(max_events)
        self.dropped = 0
        self.events: list[dict] = [
            {"ph": "M", "pid": PID_HOST, "tid": 0, "name": "process_name",
             "args": {"name": "host phases (wall us)"}},
            {"ph": "M", "pid": PID_SIM, "tid": 0, "name": "process_name",
             "args": {"name": "DES events (sim s as us)"}},
        ]
        self._meta = len(self.events)

    def __len__(self) -> int:
        return len(self.events) - self._meta

    def add_span(self, name: str, ts_s: float, dur_s: float) -> None:
        """Complete (``"X"``) host-phase event; wall seconds -> us."""
        if len(self) >= self.max_events:
            self.dropped += 1
            return
        self.events.append({
            "ph": "X", "pid": PID_HOST, "tid": 0, "name": name,
            "ts": round(ts_s * 1e6, 3), "dur": round(dur_s * 1e6, 3),
        })

    def add_instant(self, name: str, sim_t_s: float,
                    args: dict | None = None) -> None:
        """Instant (``"i"``) DES event on the sim-time track."""
        if len(self) >= self.max_events:
            self.dropped += 1
            return
        ev = {"ph": "i", "pid": PID_SIM, "tid": 0, "name": name,
              "ts": round(sim_t_s * 1e6, 3), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def to_dict(self) -> dict:
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def save(self, path) -> None:
        """Write the Perfetto-loadable trace JSON."""
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh)

    def save_jsonl(self, path) -> None:
        """Write one JSON object per line (metadata events excluded)."""
        with open(path, "w") as fh:
            for ev in self.events[self._meta:]:
                fh.write(json.dumps(ev))
                fh.write("\n")
