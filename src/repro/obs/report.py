"""Per-run telemetry aggregate: :class:`TelemetryReport`.

The report is what ``GridSimulator.run()`` hands back on ``SimResult``
(and ``run_experiment`` forwards on ``ExperimentResult``) when an
``obs=`` mode is enabled: frozen span totals, counters, the network
engine's kernel stats, the optional sim-time series, and the optional
trace writer. It is a plain data carrier — all measurement happened in
:mod:`repro.obs.probe` — plus two conveniences:

* :meth:`TelemetryReport.phase_breakdown` buckets span *self* times into
  the four-way dispatch / strategy_plan / flush / other split that
  ``benchmarks/run.py scale_sweep`` records per BENCH_scale row. By
  construction the buckets partition ``wall_s`` exactly (``other`` is
  the remainder), which is what makes the "engine-bound vs
  planner-bound" claim in the scale benches measured rather than
  inferred.
* :meth:`TelemetryReport.to_dict` gives a JSON-safe projection (numpy
  series become lists; the trace is summarized, not embedded — use
  :meth:`save_trace` / :meth:`save_events_jsonl` for the full export).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    import numpy as np
    from .trace import TraceWriter

#: Span names feeding each bucket of :meth:`TelemetryReport.phase_breakdown`.
#: ``flush`` covers the whole network-engine surface — per-event rerates,
#: fused flush passes, and NET completion handling — because that is the
#: axis the numpy-vs-device engines trade against each other.
DISPATCH_PHASES = ("broker.dispatch",)
PLAN_PHASES = ("strategy.plan",)
FLUSH_PHASES = ("net.rerate", "net.flush", "net.events")


@dataclasses.dataclass
class TelemetryReport:
    """Aggregated telemetry for one simulator run (see module doc)."""

    mode: str                            # the obs= mode that produced it
    wall_s: float                        # probe-creation -> finalize wall
    phase_self_s: dict[str, float]       # exclusive seconds per span name
    phase_total_s: dict[str, float]      # inclusive seconds per span name
    phase_calls: dict[str, int]          # activations per span name
    counters: dict[str, int]             # probe counters (event.*, plan_cache.*, net.*)
    net_stats: dict[str, int]            # raw NetworkEngine.stats snapshot
    series: Optional[dict[str, "np.ndarray"]] = None   # sim-time channels
    n_samples: int = 0                   # OBS samples taken (may exceed ring)
    trace: Optional["TraceWriter"] = None
    dropped_trace_events: int = 0

    def phase_breakdown(self, wall_s: float | None = None) -> dict[str, float]:
        """Four-bucket wall partition: dispatch / strategy_plan / flush /
        other. ``wall_s`` defaults to the report's own wall clock; pass
        a caller-measured wall (e.g. a BENCH row's ``wall_s``) to
        partition that instead."""
        wall = self.wall_s if wall_s is None else wall_s
        dispatch = sum(self.phase_self_s.get(n, 0.0) for n in DISPATCH_PHASES)
        plan = sum(self.phase_self_s.get(n, 0.0) for n in PLAN_PHASES)
        flush = sum(self.phase_self_s.get(n, 0.0) for n in FLUSH_PHASES)
        other = wall - dispatch - plan - flush
        return {
            "dispatch_s": round(dispatch, 6),
            "strategy_plan_s": round(plan, 6),
            "flush_s": round(flush, 6),
            "other_s": round(other, 6),
        }

    def to_dict(self) -> dict:
        """JSON-safe projection (series as lists, trace summarized)."""
        d = {
            "mode": self.mode,
            "wall_s": round(self.wall_s, 6),
            "phase_self_s": {k: round(v, 6)
                             for k, v in sorted(self.phase_self_s.items())},
            "phase_total_s": {k: round(v, 6)
                              for k, v in sorted(self.phase_total_s.items())},
            "phase_calls": dict(sorted(self.phase_calls.items())),
            "counters": dict(sorted(self.counters.items())),
            "net_stats": dict(sorted(self.net_stats.items())),
            "phases": self.phase_breakdown(),
            "n_samples": self.n_samples,
        }
        if self.series is not None:
            d["series"] = {k: [float(x) for x in v]
                           for k, v in self.series.items()}
        if self.trace is not None:
            d["trace_events"] = len(self.trace)
            d["dropped_trace_events"] = self.dropped_trace_events
        return d

    def save_trace(self, path) -> None:
        """Write the Perfetto-loadable Chrome trace JSON (trace mode only)."""
        if self.trace is None:
            raise ValueError("no trace captured: run with obs='trace'")
        self.trace.save(path)

    def save_events_jsonl(self, path) -> None:
        """Write the line-per-event JSONL log (trace mode only)."""
        if self.trace is None:
            raise ValueError("no trace captured: run with obs='trace'")
        self.trace.save_jsonl(path)
