"""Kernel registry spec — the uniform shape every kernel package exports.

Each package under :mod:`repro.kernels` (``net_rerate``, ``st_cost``,
``value_score``, ``selective_scan``, ``flash_attention``) exposes a
module-level ``SPEC: KernelSpec`` in its ``__init__``. The spec is the
machine-readable contract the jaxpr auditor (:mod:`repro.analysis`)
enforces for *every* kernel instead of the old one-off ``st_cost``
shape-guard test:

* ``max_rank`` — structural rank cap on every intermediate aval in the
  traced jaxpr. For the sim kernels this is 2 (the whole point of the
  blocked formulations is never materializing the
  ``(sites, files, sites)`` / ``(jobs, files, sites)`` broadcasts); for
  the model kernels it is 3/4 (their *inputs* are rank 3/4 — the banned
  ``(B, S, D, N)`` scan blow-up and ``(B, H, Sq, Skv)`` logits plane are
  caught by rank and byte budget respectively).
* ``budget_bytes`` — per-eqn peak-intermediate budget: for each equation
  in the jaxpr (pallas bodies included) the auditor sums the aval bytes
  of its operands and results; the max over equations must stay under
  budget at the spec's representative audit shapes.
* ``make_inputs`` — builds the representative-shape float32 numpy inputs
  the audit traces at (plus the kernel's static kwargs).
* ``make_small_inputs`` — optional small-shape inputs for the runtime
  oracle checks (float64 oracle dtype + x64-interpret bit-identity).
  Only the sim kernels carry these: their refs are pure-numpy oracles
  (not traceable), so dtype discipline is checked by execution.
* ``arg_units`` / ``out_units`` — per-operand dimension signature in the
  vocabulary of :mod:`repro.analysis.units` (``bytes``, ``bytes_per_s``,
  ``sim_seconds``, ``count``, ``score``; model-kernel tensors are
  dimensionless ``score``). The jaxpr auditor asserts every spec
  declares a complete, valid signature and records it in
  ``results/ANALYSIS_kernels.json``.

This module is imported by every kernel ``__init__`` and therefore MUST
stay jax-free (the DES engine imports kernel packages on hosts without
jax installed).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import numpy as np

#: (positional args, static kwargs) pair produced by input builders.
InputCase = tuple[tuple, dict]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Registry entry for one kernel package.

    Attributes:
      name: registry key, matches the package name.
      module: import path of the package (``repro.kernels.<name>``).
      kernel_attr: entry point in ``<module>.kernel`` taking an
        ``interpret=`` kwarg (the raw pallas_call wrapper the auditor
        traces).
      ref_attr: oracle in ``<module>.ref``.
      domain: ``"sim"`` (host-facing DES op, float64 numpy oracle,
        bit-identity contract) or ``"model"`` (jitted device op, jnp
        reference, tolerance contract).
      max_rank: max allowed aval rank anywhere in the traced jaxpr.
      budget_bytes: per-eqn peak intermediate-bytes budget at the audit
        shapes (float32 trace).
      make_inputs: audit-shape input builder.
      make_small_inputs: small-shape builder for runtime oracle checks
        (sim kernels only; ``None`` for model kernels whose identity
        contract lives in tests/test_kernels.py tolerances).
      multi_output: kernel returns a tuple rather than one array.
      arg_units: dimension per positional argument of ``make_inputs``.
      out_units: dimension per output (one entry when single-output).
    """

    name: str
    module: str
    kernel_attr: str
    ref_attr: str
    domain: str
    max_rank: int
    budget_bytes: int
    make_inputs: Callable[[], InputCase]
    make_small_inputs: Callable[[], InputCase] | None = None
    multi_output: bool = False
    arg_units: tuple[str, ...] = ()
    out_units: tuple[str, ...] = ()

    def load_kernel(self) -> Callable[..., Any]:
        """Import and return the raw kernel entry point (needs jax)."""
        mod = importlib.import_module(self.module + ".kernel")
        return getattr(mod, self.kernel_attr)

    def load_ref(self) -> Callable[..., Any]:
        """Import and return the reference/oracle implementation."""
        mod = importlib.import_module(self.module + ".ref")
        return getattr(mod, self.ref_attr)


# ---------------------------------------------------------------------------
# Input builders. Shapes mirror the "representative" parametrizations in
# tests/test_kernels.py (paper grid = 52 sites x 100 files, bulk bursts of
# 50 jobs) so budget numbers line up with what the tests exercise. All
# builders are seeded and pure numpy.
# ---------------------------------------------------------------------------


def _net_rerate_inputs(slots: int, links: int, levels: int,
                       seed: int = 2) -> InputCase:
    rng = np.random.default_rng(seed)
    path = np.where(rng.random((slots, levels)) < 0.35, -1,
                    rng.integers(0, links, (slots, levels)))
    path[:, 0] = rng.integers(0, links, slots)
    rem = (rng.random(slots) * 1e9).astype(np.float32)
    bw = (rng.random(links) * 1e8 + 1e5).astype(np.float32)
    act = rng.integers(0, 12, links).astype(np.float32)
    return ((path.astype(np.int32), rem, bw, act, np.float32(321.5)), {})


def _event_engine_inputs(slots: int, links: int, levels: int,
                         seed: int = 3) -> InputCase:
    rng = np.random.default_rng(seed)
    path = np.where(rng.random((slots, levels)) < 0.35, -1,
                    rng.integers(0, links, (slots, levels)))
    path[:, 0] = rng.integers(0, links, slots)
    # mix of slot states: ~1/4 released (all-padding path, zeroed state),
    # ~1/3 freshly allocated (no cached rate yet, rem used verbatim), the
    # rest carried over from a previous flush with a finite (rate, eta)
    freed = rng.random(slots) < 0.25
    path[freed] = -1
    rem = (rng.random(slots) * 1e9).astype(np.float32)
    rate = (rng.random(slots) * 1e7 + 1.0).astype(np.float32)
    fresh = rng.random(slots) < 0.3
    rate[fresh | freed] = 0.0
    rem[freed] = 0.0
    now = 321.5
    eta = (now + rng.random(slots) * 5e3).astype(np.float32)
    eta[rate == 0.0] = np.inf
    bw = (rng.random(links) * 1e8 + 1e5).astype(np.float32)
    act = rng.integers(0, 12, links).astype(np.float32)
    return ((path.astype(np.int32), rem, rate, eta, bw, act,
             np.float32(now)), {})


def _value_score_inputs(sites: int, files: int, seed: int = 2) -> InputCase:
    rng = np.random.default_rng(seed)
    demand = (rng.random((sites, files)) * 20.0).astype(np.float32)
    sizes = (rng.random(files) * 1e9 + 1e6).astype(np.float32)
    presence = rng.random((sites, files)) < 0.25
    presence[0, :] = True
    bw = (rng.random((sites, sites)) * 1.25e8 + 1e5).astype(np.float32)
    return ((demand, sizes, presence.astype(np.float32), bw),
            {"mode": "cost"})


def _st_cost_inputs(sites: int, files: int, jobs: int,
                    seed: int = 2) -> InputCase:
    rng = np.random.default_rng(seed)
    bw = rng.random((sites, sites)) * 1.25e8 + 1e5
    presence = rng.random((sites, files)) < 0.2
    presence[0, :] = True
    online = rng.random(sites) < 0.85
    online[0] = True
    fetch_mask = presence & online[:, None]
    fetch_mask[0, :] = presence[0, :]
    sizes = rng.random(files) * 1e9 + 1e6
    required = rng.random((jobs, files)) < min(0.5, 12.0 / files)
    rel = rng.random(sites) * 50.0
    args = tuple(np.asarray(a, np.float32)
                 for a in (bw, fetch_mask, presence, sizes, required, rel,
                           online))
    return (args, {})


def _strategy_plan_inputs(sites: int, pairs: int, seed: int = 2) -> InputCase:
    rng = np.random.default_rng(seed)
    bw = rng.random((sites, pairs)) * 1.25e8 + 1e5
    fetch = rng.random((sites, pairs)) < 0.15
    fetch[rng.integers(0, sites, pairs), np.arange(pairs)] = True
    # region-block structure: contiguous site ranges share a region, each
    # pair's destination region is one of them
    n_regions = max(2, sites // 8)
    region = np.arange(sites) * n_regions // sites
    local = region[:, None] == rng.integers(0, n_regions, pairs)[None, :]
    serve = np.where(rng.random(sites) < 0.5, rng.random(sites) * 9.0, 0.0)
    size = rng.random(pairs) * 1e9 + 1e6
    free = np.where(rng.random(pairs) < 0.5,
                    rng.random(pairs) * 2e9, rng.random(pairs) * 1e8)
    args = tuple(np.asarray(a, np.float32)
                 for a in (bw, fetch, local, serve, free, size))
    return (args, {})


def _selective_scan_inputs(Bz: int, S: int, Di: int, N: int,
                           seed: int = 2) -> InputCase:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((Bz, S, Di)).astype(np.float32)
    dt = (np.log1p(np.exp(rng.standard_normal((Bz, S, Di)))) * 0.1
          ).astype(np.float32)
    B = rng.standard_normal((Bz, S, N)).astype(np.float32)
    C = rng.standard_normal((Bz, S, N)).astype(np.float32)
    A = (-np.exp(rng.standard_normal((Di, N)))).astype(np.float32)
    D = rng.standard_normal(Di).astype(np.float32)
    h0 = np.zeros((Bz, Di, N), np.float32)
    return ((x, dt, B, C, A, D, h0), {"chunk": 64, "block_d": 128})


def _flash_attention_inputs(B: int, H: int, KV: int, Sq: int, Skv: int,
                            hd: int, seed: int = 2) -> InputCase:
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, H, Sq, hd)).astype(np.float32)
    k = rng.standard_normal((B, KV, Skv, hd)).astype(np.float32)
    v = rng.standard_normal((B, KV, Skv, hd)).astype(np.float32)
    return ((q, k, v), {"causal": True, "block_q": 128, "block_k": 128})


#: Budgets are ~1.25x the measured per-eqn peak at the audit shapes and
#: sit well below the banned dense materializations (see docs/ANALYSIS.md
#: for the per-kernel headroom math). Keep in sync with
#: results/ANALYSIS_kernels.json (regenerated by ``python -m
#: repro.analysis``).
NET_RERATE_SPEC = KernelSpec(
    name="net_rerate", module="repro.kernels.net_rerate",
    kernel_attr="net_rerate_kernel", ref_attr="net_rerate_ref",
    domain="sim", max_rank=2, budget_bytes=24_000,
    make_inputs=lambda: _net_rerate_inputs(256, 60, 5),
    make_small_inputs=lambda: _net_rerate_inputs(37, 23, 4),
    arg_units=("count", "bytes", "bytes_per_s", "count", "sim_seconds"),
    out_units=("bytes_per_s", "sim_seconds"),
)

EVENT_ENGINE_SPEC = KernelSpec(
    name="event_engine", module="repro.kernels.event_engine",
    kernel_attr="event_engine_kernel", ref_attr="event_engine_ref",
    domain="sim", max_rank=2, budget_bytes=20_000,
    make_inputs=lambda: _event_engine_inputs(256, 60, 5),
    make_small_inputs=lambda: _event_engine_inputs(37, 23, 4),
    multi_output=True,
    arg_units=("count", "bytes", "bytes_per_s", "sim_seconds",
               "bytes_per_s", "count", "sim_seconds"),
    out_units=("bytes", "bytes_per_s", "sim_seconds", "sim_seconds"),
)

ST_COST_SPEC = KernelSpec(
    name="st_cost", module="repro.kernels.st_cost",
    kernel_attr="st_cost_kernel", ref_attr="st_cost_ref",
    domain="sim", max_rank=2, budget_bytes=450_000,
    make_inputs=lambda: _st_cost_inputs(52, 100, 50),
    make_small_inputs=lambda: _st_cost_inputs(8, 24, 5),
    arg_units=("bytes_per_s", "count", "count", "bytes", "count",
               "score", "count"),
    out_units=("sim_seconds",),
)

STRATEGY_PLAN_SPEC = KernelSpec(
    name="strategy_plan", module="repro.kernels.strategy_plan",
    kernel_attr="strategy_plan_kernel", ref_attr="strategy_plan_ref",
    domain="sim", max_rank=2, budget_bytes=1_100_000,
    make_inputs=lambda: _strategy_plan_inputs(500, 50),
    make_small_inputs=lambda: _strategy_plan_inputs(24, 7),
    multi_output=True,
    arg_units=("bytes_per_s", "count", "count", "score", "bytes",
               "bytes"),
    out_units=("count", "count", "count", "count", "count"),
)

VALUE_SCORE_SPEC = KernelSpec(
    name="value_score", module="repro.kernels.value_score",
    kernel_attr="value_score_kernel", ref_attr="value_score_ref",
    domain="sim", max_rank=2, budget_bytes=200_000,
    make_inputs=lambda: _value_score_inputs(52, 100),
    make_small_inputs=lambda: _value_score_inputs(13, 20),
    arg_units=("score", "bytes", "count", "bytes_per_s"),
    out_units=("score",),
)

SELECTIVE_SCAN_SPEC = KernelSpec(
    name="selective_scan", module="repro.kernels.selective_scan",
    kernel_attr="selective_scan_kernel", ref_attr="selective_scan_ref",
    domain="model", max_rank=3, budget_bytes=2_200_000,
    make_inputs=lambda: _selective_scan_inputs(1, 512, 256, 16),
    multi_output=True,
    arg_units=("score", "score", "score", "score", "score",
               "score", "score"),
    out_units=("score", "score"),
)

FLASH_ATTENTION_SPEC = KernelSpec(
    name="flash_attention", module="repro.kernels.flash_attention",
    kernel_attr="flash_attention_kernel", ref_attr="flash_attention_ref",
    domain="model", max_rank=4, budget_bytes=1_700_000,
    make_inputs=lambda: _flash_attention_inputs(1, 2, 2, 256, 1024, 64),
    arg_units=("score", "score", "score"),
    out_units=("score",),
)
