"""Jitted public wrapper for the selective scan (pallas / interpret / ref)."""

from __future__ import annotations

import functools

import jax

from .kernel import selective_scan_kernel
from .ref import selective_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "backend"))
def selective_scan(x, dt, B, C, A, D, h0, *, chunk=256, block_d=512,
                   backend="auto"):
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu")
    if use_pallas:
        return selective_scan_kernel(x, dt, B, C, A, D, h0, chunk=chunk,
                                     block_d=block_d)
    if backend == "interpret":
        return selective_scan_kernel(x, dt, B, C, A, D, h0, chunk=chunk,
                                     block_d=block_d, interpret=True)
    return selective_scan_ref(x, dt, B, C, A, D, h0)
