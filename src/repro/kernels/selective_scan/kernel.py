"""Mamba1 selective-scan Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: the GPU kernel parallelizes the
recurrence across warps with shuffle-based prefix products; TPUs have no
warp shuffles, so we restructure as a *chunked VMEM-resident recurrence*:

  grid = (batch, channel_blocks, seq_chunks)   # seq axis innermost
  per step: a (chunk x bd) tile of dt/x and (chunk x N) tiles of B/C are
  streamed HBM->VMEM; the state h (bd x N) persists in VMEM scratch across
  the sequential seq_chunks axis; inside the chunk a fori_loop applies
    h = exp(dt*A) * h + (dt*x) * B;   y_t = (h @ C_t) + D*x_t
  entirely on the VPU (elementwise over a (bd, N) tile per step; bd is a
  multiple of 128 lanes).

The channel axis parallelizes across programs (channels are independent in
mamba1), which is what the MXU-free recurrence needs for occupancy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_scr, *, chunk: int, bd: int, n: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)               # (bd, N)
    dskip = d_ref[...].astype(jnp.float32)           # (bd,)

    def step(t, carry):
        h = carry
        dt = dt_ref[0, t, :].astype(jnp.float32)     # (bd,)
        xt = x_ref[0, t, :].astype(jnp.float32)      # (bd,)
        bt = b_ref[0, t, :].astype(jnp.float32)      # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)      # (N,)
        da = jnp.exp(dt[:, None] * a)                # (bd, N)
        h = da * h + (dt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + dskip * xt
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == nc - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan_kernel(x, dt, B, C, A, D, h0, *, chunk: int = 256,
                          block_d: int = 512, interpret: bool = False):
    """x, dt: (Bz, S, Di); B, C: (Bz, S, N); A: (Di, N); D: (Di,);
    h0: (Bz, Di, N). Returns (y (Bz, S, Di), h_last (Bz, Di, N))."""
    Bz, S, Di = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    block_d = min(block_d, Di)
    assert S % chunk == 0, "pad S to a chunk multiple"
    assert Di % block_d == 0
    nd = Di // block_d
    nc = S // chunk
    grid = (Bz, nd, nc)

    kernel = functools.partial(_scan_kernel, chunk=chunk, bd=block_d, n=N)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # C
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),            # A
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),                # D
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bz, S, Di), x.dtype),
            jax.ShapeDtypeStruct((Bz, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A, D, h0)
    return y, h_last
