"""Sequential pure-jnp oracle for the selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, B, C, A, D, h0):
    """x, dt: (Bz, S, Di); B, C: (Bz, S, N); A: (Di, N); D: (Di,);
    h0: (Bz, Di, N). Returns (y, h_last)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs                    # (Bz,Di),(Bz,Di),(Bz,N),(Bz,N)
        da = jnp.exp(dtt[..., None] * Af[None])     # (Bz, Di, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct) + D[None] * xt
        return h, y

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), h_last
