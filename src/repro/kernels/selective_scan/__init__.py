"""Mamba1 selective-scan kernel package (registry entry, lazy jax import).

Unlike the sim kernels, ``ops``/``kernel``/``ref`` here import jax at
module level (they are jitted device ops), so this ``__init__`` defers
them behind a module ``__getattr__`` — importing the package (as the
kernel registry does for discovery) pulls no jax.
"""

from ..spec import SELECTIVE_SCAN_SPEC as SPEC

__all__ = ["SPEC", "selective_scan", "selective_scan_kernel",
           "selective_scan_ref"]

_LAZY = {
    "selective_scan": ".ops",
    "selective_scan_kernel": ".kernel",
    "selective_scan_ref": ".ref",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
