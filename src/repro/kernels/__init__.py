"""Custom-kernel layer (Pallas TPU + float64 oracles), jax-free to import.

Each subpackage ships a ``kernel.py`` (raw pallas_call wrapper), an
``ops.py`` (public backend-dispatching entry point), a ``ref.py``
(reference/oracle), and a ``SPEC`` registry entry (:mod:`.spec`) the
jaxpr auditor discovers via :func:`.registry.registered_kernels`.
"""

from .registry import get_kernel_spec, registered_kernels
from .spec import KernelSpec

__all__ = ["KernelSpec", "get_kernel_spec", "registered_kernels"]
