"""Blocked shortest-transfer batch-costing pass (jax-free import).

``st_cost`` is the host-facing op the jitted ``shortesttransfer`` broker
calls once per dispatch batch; ``st_cost_ref`` the float64 oracle;
``st_cost_dense_ref`` the pre-blocked O(sites x files x sites)
formulation kept only for the bit-identity tests. Importing this package
pulls no jax — ``ops`` loads it lazily per call, like ``net_rerate``.
"""

from ..spec import ST_COST_SPEC as SPEC
from .ops import st_cost
from .ref import st_cost_dense_ref, st_cost_ref

__all__ = ["SPEC", "st_cost", "st_cost_ref", "st_cost_dense_ref"]
