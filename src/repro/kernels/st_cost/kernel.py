"""Pallas TPU kernel for the blocked shortest-transfer cost pass.

The jitted ``shortesttransfer`` broker costs every (job, site) pair of a
dispatch batch each time a burst arrives. The pre-blocked formulation
reduced over holders by broadcasting a ``(sites, files, sites)`` tensor —
~200 MB at the 500-site scale point — so, exactly like ``value_score``,
this kernel runs a ``fori_loop`` over the holder axis carrying a
``(files, sites)`` running max in VMEM, then a second ``fori_loop`` over
the file axis accumulating the per-job staging times into a ``(jobs,
sites)`` buffer: two VPU-shaped fused passes, no MXU, peak memory
O(sites x files + jobs x sites).

Layout: the destination-site axis rides the lanes (padded to 128)
everywhere; the file axis rides the sublanes of the ``(files, sites)``
buffers and the lanes of ``fetch_mask``/``sizes`` (padded to 128 so both
orientations agree); jobs ride the lanes of the transposed requirement
matrix and the sublanes of the output (padded to 128). Padding rows/cols
are all zero: they never win the holder max, padded files are never
required (their terms are exact zeros), and padded destination columns
cost ``inf`` but are sliced off.

Bit-identity: the holder max is order-independent and max/divide are
exact IEEE ops; the file sum runs sequentially over ascending file index
— the same order numpy reduces the major axis of a 2-D array — and a
zero term leaves a nonnegative running sum unchanged, so under
``jax.experimental.enable_x64`` interpret mode the kernel reproduces
``ref.st_cost_ref`` bit for bit (pinned by ``tests/test_kernels.py``).
Compiled TPU execution is float32 (no f64 on TPU), so on TPU the route
is approximate at the ~1e-7 relative level, like the other kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128


def _st_cost_kernel(bw_ref, fetch_ref, presence_t_ref, req_t_ref, sizes_ref,
                    rel_ref, online_ref, out_ref):
    bw = bw_ref[...]                  # (S_h, S)   [holder, dst]
    fetch = fetch_ref[...]            # (S_h, F)   0/1 fetchable holders
    presence_t = presence_t_ref[...]  # (F, S)     0/1 all holders
    req_t = req_t_ref[...]            # (F, J)     0/1 requirement masks
    n_f, n_s = presence_t.shape
    n_j = req_t.shape[1]
    dtype = bw.dtype

    # Both loops run over the *padded* axes: padded holder rows hold no
    # files (zero contrib to the max) and padded files are required by no
    # job (exact-zero terms of the sum), so results are bit-identical to
    # looping over the true counts — and compilation buckets by padded
    # shape (multiples of 128) instead of retracing per batch-union size.

    # pass 1 — best fetchable bandwidth per (file, dst): running max over
    # holder rows. Rows come off the lane axis and are stood up as columns
    # (the same (n,) -> (n, 1) idiom value_score uses).
    def holder_body(h, best):
        prow = jax.lax.dynamic_index_in_dim(fetch, h, 0,
                                            keepdims=False)      # (F,)
        brow = jax.lax.dynamic_index_in_dim(bw, h, 0,
                                            keepdims=False)      # (S,)
        contrib = jnp.where(prow[:, None] > 0.0, brow[None, :], 0.0)
        return jnp.maximum(best, contrib)

    best = jax.lax.fori_loop(0, fetch.shape[0], holder_body,
                             jnp.zeros((n_f, n_s), dtype))
    sizes_col = sizes_ref[0, :][:, None]                         # (F, 1)
    t_fs = jnp.where(best > 0.0, sizes_col / best, jnp.inf)

    # pass 2 — per-job staging time: sequential sum over ascending file
    # index of the missing files' transfer estimates.
    def file_body(f, acc):
        req_row = jax.lax.dynamic_index_in_dim(req_t, f, 0,
                                               keepdims=False)   # (J,)
        pres_row = jax.lax.dynamic_index_in_dim(presence_t, f, 0,
                                                keepdims=True)   # (1, S)
        t_row = jax.lax.dynamic_index_in_dim(t_fs, f, 0,
                                             keepdims=True)      # (1, S)
        miss = (req_row[:, None] > 0.0) & (pres_row <= 0.0)      # (J, S)
        return acc + jnp.where(miss, t_row, 0.0)

    t = jax.lax.fori_loop(0, n_f, file_body,
                          jnp.zeros((n_j, n_s), dtype))
    cost = jnp.maximum(t, rel_ref[...])                          # (1, S) bc
    out_ref[...] = jnp.where(online_ref[...] > 0.0, cost, jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _st_cost_call(bw, fetch, presence_t, req_t, sizes, rel, online, *,
                  interpret: bool):
    out_shape = (req_t.shape[1], bw.shape[1])
    return pl.pallas_call(
        _st_cost_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 7,
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct(out_shape, bw.dtype),
        interpret=interpret,
    )(bw, fetch, presence_t, req_t, sizes, rel, online)


def st_cost_kernel(bw, fetch_mask, presence, sizes, required, rel, online,
                   *, interpret: bool = False):
    """Same contract as :func:`..ref.st_cost_ref`, computed by the Pallas
    kernel. Dtypes follow ``bw`` (float32 compiled on TPU, float64 under
    x64 interpret)."""
    bw = jnp.asarray(bw)
    dtype = bw.dtype
    n_sites, n_files = jnp.asarray(presence).shape
    n_jobs = jnp.asarray(required).shape[0]
    if n_jobs == 0 or n_sites == 0:
        return jnp.zeros((n_jobs, n_sites), dtype)
    if n_files == 0:
        # nothing to stage: queue time only (the oracle's max(0, rel)
        # masked to online sites), no pallas_call over a 0-wide file axis
        cost = jnp.maximum(jnp.zeros((n_jobs, n_sites), dtype),
                           jnp.asarray(rel, dtype)[None, :])
        return jnp.where(jnp.asarray(online, dtype)[None, :] > 0.0, cost,
                         jnp.inf)
    pad_s8 = (-n_sites) % 8              # holder rows (sublanes)
    pad_s = (-n_sites) % _LANES          # dst columns (lanes)
    pad_f = (-n_files) % _LANES          # files: lanes of fetch/sizes and
    pad_j = (-n_jobs) % _LANES           #   sublanes of the (F, S) buffers
    bw_p = jnp.pad(jnp.asarray(bw, dtype), ((0, pad_s8), (0, pad_s)))
    fetch_p = jnp.pad(jnp.asarray(fetch_mask, dtype),
                      ((0, pad_s8), (0, pad_f)))
    presence_t_p = jnp.pad(jnp.asarray(presence, dtype).T,
                           ((0, pad_f), (0, pad_s)))
    req_t_p = jnp.pad(jnp.asarray(required, dtype).T,
                      ((0, pad_f), (0, pad_j)))
    sizes_p = jnp.pad(jnp.asarray(sizes, dtype), (0, pad_f)).reshape(1, -1)
    rel_p = jnp.pad(jnp.asarray(rel, dtype), (0, pad_s)).reshape(1, -1)
    online_p = jnp.pad(jnp.asarray(online, dtype), (0, pad_s)).reshape(1, -1)
    out = _st_cost_call(bw_p, fetch_p, presence_t_p, req_t_p, sizes_p,
                        rel_p, online_p, interpret=interpret)
    return out[:n_jobs, :n_sites]
