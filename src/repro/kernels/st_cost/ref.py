"""Vectorized float64 oracle for the blocked shortest-transfer cost pass.

Costs every (job, site) pair of a dispatch batch under the
``shortesttransfer`` policy (Chang et al. [6]; see
:class:`repro.core.scheduler.ShortestTransferScheduler`):

1. ``best[f, s]`` — the best point bandwidth at which site ``s`` could
   fetch file ``f``: max over fetchable holders ``h`` of ``bw[h, s]``.
   Unlike ``value_score`` self-supply is *not* excluded — a held file is
   never missing at its holder, so the diagonal never reaches a cost.
2. ``t[j, s]`` — estimated staging time: the sum over the job's required
   files missing at ``s`` of ``size / best`` (``inf`` when a missing file
   has no usable bandwidth — the sequential policy's zero-bw guard).
3. ``cost[j, s] = max(t, relative_load[s])``, ``inf`` at offline sites.

Memory is the whole point: the pre-blocked formulation materialized a
``(sites, files, sites)`` broadcast (~200 MB at the 500-site scale
point); both passes here are blocked — the max accumulates one holder
group at a time into a ``(files, sites)`` buffer and the job sum
accumulates one file at a time into a ``(jobs, sites)`` buffer — so peak
memory is O(sites x files + jobs x sites).

Bit-identity contract (pinned by ``tests/test_kernels.py``):

* the max-reduction is order-independent and divide/max are exact IEEE
  ops, so this oracle equals the dense formulation
  (:func:`st_cost_dense_ref`, kept for exactly that test) bit for bit;
* the file sum is sequential over ascending file index — numpy reduces
  the *major* axis of a 2-D array sequentially, and skipping exact-zero
  terms leaves a nonnegative running sum unchanged (``x + 0.0 == x``),
  so the per-job gathered sum below, the dense ``sum(axis=1)`` and the
  Pallas kernel's fori-loop accumulation all agree bit for bit;
* the kernel under x64 interpret mode therefore reproduces this oracle
  exactly — the same contract ``net_rerate`` / ``value_score`` pin.
"""

from __future__ import annotations

import numpy as np


def st_cost_ref(bw: np.ndarray, fetch_mask: np.ndarray,
                presence: np.ndarray, sizes: np.ndarray,
                required: np.ndarray, rel: np.ndarray,
                online: np.ndarray) -> np.ndarray:
    """Cost every (job, site) pair of one dispatch batch.

    Args:
      bw: ``(sites, sites)`` point-bandwidth matrix, ``bw[h, s]`` = bytes/s
        from holder ``h`` to site ``s``
        (:meth:`repro.core.network.NetworkEngine.point_bandwidth_matrix`).
      fetch_mask: ``(sites, files)`` bool — fetchable holders (online, or
        the durable master copy).
      presence: ``(sites, files)`` bool — all holders (a file present at
        ``s`` costs nothing there, fetchable or not).
      sizes: ``(files,)`` file sizes in bytes.
      required: ``(jobs, files)`` bool requirement masks (R_j rows).
      rel: ``(sites,)`` relative load (queued work / capacity).
      online: ``(sites,)`` bool.

    Returns ``(jobs, sites)`` float64 costs, ``inf`` at offline sites.
    """
    bw = np.asarray(bw, np.float64)
    fetch_mask = np.asarray(fetch_mask, bool)
    presence = np.asarray(presence, bool)
    sizes = np.asarray(sizes, np.float64)
    required = np.asarray(required, bool)
    rel = np.asarray(rel, np.float64)
    n_sites, n_files = presence.shape
    n_jobs = required.shape[0]
    # pass 1 — best fetchable bandwidth per (file, dst). Iterated per file
    # over its holder rows: strictly less work than the kernel's
    # fori-over-holders sweep (O(nnz x sites) vs O(sites^2 x files)) and
    # bit-identical to it, the max being order-independent.
    best = np.zeros((n_files, n_sites))
    for f in range(n_files):
        holders = np.flatnonzero(fetch_mask[:, f])
        if holders.size:
            best[f] = bw[holders].max(axis=0)
    # masked entries never read the quotient (same guard value_score uses)
    t_fs = np.where(best > 0.0, sizes[:, None] / np.where(best > 0.0, best,
                                                          1.0), np.inf)
    # pass 2 — per-job sum over its missing files, ascending file index.
    # Gathering only R_j's rows skips exact-zero terms of the full-axis
    # sequential sum, which is bit-exact (see module docstring).
    t = np.zeros((n_jobs, n_sites))
    presence_t = presence.T                       # (files, sites) view
    for j in range(n_jobs):
        idx = np.flatnonzero(required[j])
        if idx.size:
            t[j] = np.where(presence_t[idx], 0.0, t_fs[idx]).sum(axis=0)
    cost = np.maximum(t, rel[None, :])
    return np.where(np.asarray(online, bool)[None, :], cost, np.inf)


def st_cost_dense_ref(bw: np.ndarray, fetch_mask: np.ndarray,
                      presence: np.ndarray, sizes: np.ndarray,
                      required: np.ndarray, rel: np.ndarray,
                      online: np.ndarray) -> np.ndarray:
    """The pre-blocked dense formulation (materializes ``(sites, files,
    sites)`` / ``(jobs, files, sites)`` broadcasts). Exists only so the
    tests can pin the blocked pass bit-identical to what the engine used
    to compute — never call it at scale."""
    bw = np.asarray(bw, np.float64)
    fetch_mask = np.asarray(fetch_mask, bool)
    presence = np.asarray(presence, bool)
    sizes = np.asarray(sizes, np.float64)
    required = np.asarray(required, bool)
    rel = np.asarray(rel, np.float64)
    best = np.where(fetch_mask[:, :, None], bw[:, None, :], 0.0).max(axis=0)
    t_fs = np.where(best > 0.0, sizes[:, None] / np.where(best > 0.0, best,
                                                          1.0), np.inf)
    miss = required[:, :, None] & ~presence.T[None, :, :]
    t = np.where(miss, t_fs[None], 0.0).sum(axis=1)
    cost = np.maximum(t, rel[None, :])
    return np.where(np.asarray(online, bool)[None, :], cost, np.inf)
