"""Public wrapper for the shortest-transfer cost pass (pallas / interpret /
numpy).

Like ``value_score``, this op is called from host code (the jitted
``shortesttransfer`` broker, once per dispatch batch), so it takes and
returns host numpy values and picks the route per call:

  * ``"auto"``   — the compiled Pallas kernel on TPU; the float64 numpy
    oracle on CPU (no per-batch jax dispatch overhead, bit-identical to
    the oracle trivially). This is what the broker uses.
  * ``"pallas"`` — force the compiled kernel. Compiled TPU execution is
    float32 (no f64 on TPU): ~1e-7 relative drift vs the oracle, so the
    bit-identity contract covers the CPU routes only.
  * ``"interpret"`` — the kernel under the Pallas interpreter with x64
    enabled: slow, bit-identical to the oracle; used by the kernel tests.
  * ``"numpy"``  — the oracle directly.
"""

from __future__ import annotations

import numpy as np

from .ref import st_cost_ref


def st_cost(bw, fetch_mask, presence, sizes, required, rel, online, *,
            backend: str = "auto") -> np.ndarray:
    """Cost the full (jobs, sites) dispatch matrix of one batch.

    See :func:`.ref.st_cost_ref` for the argument contract. Returns a
    host float64 array regardless of backend.
    """
    if backend in ("auto", "pallas", "interpret"):
        import jax

        if backend == "pallas" or (backend == "auto"
                                   and jax.default_backend() == "tpu"):
            from .kernel import st_cost_kernel
            out = st_cost_kernel(
                np.asarray(bw, np.float32),
                np.asarray(fetch_mask, np.float32),
                np.asarray(presence, np.float32),
                np.asarray(sizes, np.float32),
                np.asarray(required, np.float32),
                np.asarray(rel, np.float32),
                np.asarray(online, np.float32))
            return np.asarray(out, np.float64)
        if backend == "interpret":
            from jax.experimental import enable_x64

            from .kernel import st_cost_kernel
            with enable_x64():
                out = st_cost_kernel(
                    np.asarray(bw, np.float64),
                    np.asarray(fetch_mask, np.float64),
                    np.asarray(presence, np.float64),
                    np.asarray(sizes, np.float64),
                    np.asarray(required, np.float64),
                    np.asarray(rel, np.float64),
                    np.asarray(online, np.float64), interpret=True)
            return np.asarray(out, np.float64)
        backend = "numpy"
    if backend != "numpy":
        raise ValueError(f"unknown st_cost backend {backend!r} "
                         "(want 'auto'|'pallas'|'interpret'|'numpy')")
    return st_cost_ref(bw, fetch_mask, presence, sizes, required, rel,
                       online)
