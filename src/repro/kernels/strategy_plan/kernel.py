"""Pallas TPU kernel for the batched replica-strategy plan pass.

One arrival burst means up to ``jobs x missing-files`` fetch decisions;
the sequential strategies make each one with a Python loop over holders
(``point_bandwidth`` per candidate — millions of calls per run at the
500-site scale point). This kernel scores the whole burst in one fused
pass: a single ``fori_loop`` over the site axis carries five ``(1,
pairs)`` running buffers in VMEM — best effective bandwidth and its
(first-occurrence) argmax for the global and the region-local candidate
sets, plus the local flag of the winning global row — and the store
verdict is one vectorized compare. Peak memory is O(sites x pairs);
the dense per-decision alternative would be a ``(pairs, sites, files)``
materialization, which is exactly what the jaxpr auditor's rank/budget
caps ban.

Layout: the pair axis rides the lanes (padded to 128) everywhere; the
site axis rides the sublanes of the ``(sites, pairs)`` inputs (padded to
8) and is walked by the loop. ``serve`` sits in SMEM (scalar read per
iteration, the ``now`` idiom of ``event_engine``). Padded site rows are
unfetchable (mask 0 -> key -1) and never win; padded pair columns are
garbage but sliced off.

Bit-identity: the running maximum updates on strict ``>`` only, so ties
keep the earliest site — exactly ``np.argmax``'s first occurrence — and
where/divide/compare are exact IEEE ops, so under
``jax.experimental.enable_x64`` interpret mode the kernel reproduces
``ref.strategy_plan_ref`` bit for bit (pinned by
``tests/test_kernels.py``). Compiled TPU execution is float32, the
tolerance tier.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128
_SUBLANES = 8


def _strategy_plan_kernel(bw_ref, fetch_ref, local_ref, free_ref, size_ref,
                          serve_ref, srcg_ref, srcl_ref, hasl_ref,
                          interg_ref, store_ref):
    bw = bw_ref[...]                       # (S, P)
    fetch = fetch_ref[...] > 0.0
    local = local_ref[...] > 0.0
    dtype = bw.dtype
    n_pairs = bw.shape[1]

    def site_body(h, carry):
        best_g, src_g, loc_g, best_l, src_l = carry    # each (1, P)
        bw_row = jax.lax.dynamic_index_in_dim(bw, h, 0, keepdims=True)
        f_row = jax.lax.dynamic_index_in_dim(fetch, h, 0, keepdims=True)
        l_row = jax.lax.dynamic_index_in_dim(local, h, 0, keepdims=True)
        eff = bw_row / (1.0 + serve_ref[0, h])
        key_g = jnp.where(f_row, eff, -1.0)
        key_l = jnp.where(f_row & l_row, eff, -1.0)
        hf = h.astype(dtype)
        upd_g = key_g > best_g             # strict: ties keep first site
        src_g = jnp.where(upd_g, hf, src_g)
        loc_g = jnp.where(upd_g, jnp.where(l_row, 1.0, 0.0), loc_g)
        best_g = jnp.where(upd_g, key_g, best_g)
        upd_l = key_l > best_l
        src_l = jnp.where(upd_l, hf, src_l)
        best_l = jnp.where(upd_l, key_l, best_l)
        return best_g, src_g, loc_g, best_l, src_l

    # init below the -1 mask value: the first site always updates, so the
    # carried argmax is always a real row index
    neg = jnp.full((1, n_pairs), -2.0, dtype)
    zero = jnp.zeros((1, n_pairs), dtype)
    best_g, src_g, loc_g, best_l, src_l = jax.lax.fori_loop(
        0, bw.shape[0], site_body, (neg, zero, zero, neg, zero))
    srcg_ref[...] = src_g
    srcl_ref[...] = src_l
    # a real local candidate scored >= 0 (bandwidth is nonnegative); the
    # all-masked column never rose above -1
    hasl_ref[...] = jnp.where(best_l >= 0.0, 1.0, 0.0)
    interg_ref[...] = 1.0 - loc_g
    store_ref[...] = jnp.where(free_ref[...] >= size_ref[...], 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _strategy_plan_call(bw, fetch, local, free, size, serve, *,
                        interpret: bool):
    n_pairs = bw.shape[1]
    dtype = bw.dtype
    row = jax.ShapeDtypeStruct((1, n_pairs), dtype)
    return pl.pallas_call(
        _strategy_plan_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 5
        + [pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 5,
        out_shape=[row] * 5,
        interpret=interpret,
    )(bw, fetch, local, free, size, serve)


def strategy_plan_kernel(bw, fetch, local, serve, free, size, *,
                         interpret: bool = False):
    """Same contract as :func:`..ref.strategy_plan_ref`, computed by the
    Pallas kernel. Dtypes follow ``bw`` (float32 compiled on TPU, float64
    under x64 interpret)."""
    bw = jnp.asarray(bw)
    dtype = bw.dtype
    n_sites, n_pairs = bw.shape
    if n_pairs == 0 or n_sites == 0:
        z = jnp.zeros((n_pairs,), dtype)
        return z, z, z, z, z
    pad_s = (-n_sites) % _SUBLANES
    pad_p = (-n_pairs) % _LANES
    bw_p = jnp.pad(bw, ((0, pad_s), (0, pad_p)))
    fetch_p = jnp.pad(jnp.asarray(fetch, dtype), ((0, pad_s), (0, pad_p)))
    local_p = jnp.pad(jnp.asarray(local, dtype), ((0, pad_s), (0, pad_p)))
    free_p = jnp.pad(jnp.asarray(free, dtype), (0, pad_p)).reshape(1, -1)
    # padded pairs get size=1 > free=0 (store 0); all columns sliced off
    size_p = jnp.pad(jnp.asarray(size, dtype), (0, pad_p),
                     constant_values=1.0).reshape(1, -1)
    serve_p = jnp.pad(jnp.asarray(serve, dtype), (0, pad_s)).reshape(1, -1)
    out = _strategy_plan_call(bw_p, fetch_p, local_p, free_p, size_p,
                              serve_p, interpret=interpret)
    return tuple(o[0, :n_pairs] for o in out)
