"""Batched replica-strategy plan pass: sources, region classification and
store verdicts for every (job, missing-file) pair of one arrival burst
(float64 oracle / Pallas TPU kernel). Jax-free to import."""

from ..spec import STRATEGY_PLAN_SPEC as SPEC
from .ops import strategy_plan
from .ref import strategy_plan_ref

__all__ = ["SPEC", "strategy_plan", "strategy_plan_ref"]
