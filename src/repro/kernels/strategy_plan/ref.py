"""Vectorized float64 oracle for the batched replica-strategy plan pass.

Scores every (job, missing-file) pair of one arrival burst at once and
returns the per-pair decisions every replication strategy starts from
(see :mod:`repro.core.replica` for the sequential policies this
vectorizes):

1. ``src_global[p]`` — the best source over *all* fetchable holders of
   pair ``p``'s file: argmax over sites of the effective bandwidth
   ``bw[s, p] / (1.0 + serve[s])``. The history-blind strategies pass
   ``serve = 0`` and the division by exactly ``1.0`` is an IEEE no-op,
   so one formula serves both the raw-bandwidth key
   (:func:`repro.core.replica._best_bandwidth_source`) and the
   serve-load-discounted key (``_AccessAwareStrategy._select_source``).
   Ties break toward the lowest site id — ``np.argmax`` returns the
   first maximum, reproducing the sequential ``max(..., key=(bw, -s))``.
2. ``src_local[p]`` / ``has_local[p]`` — the same argmax restricted to
   holders in the destination's region (HRS's region-priority rule),
   plus whether any exist.
3. ``inter_global[p]`` — whether the global pick crosses a region
   boundary (the paper's inter-communication classification), read off
   the ``local`` mask at the chosen row.
4. ``store_ok[p]`` — the no-eviction store verdict ``free >= size``,
   the comparison every sequential strategy makes before falling into
   its eviction scan.

Eviction *contents* (two-phase LRU order, retention-vs-refetch trades)
stay host-side masked reductions over the
:class:`repro.core.replica.StorageTensorView` tensors — they touch only
the few pairs whose ``store_ok`` is false.

Bit-identity contract (pinned by ``tests/test_kernels.py``): where /
divide / compare are exact IEEE ops and the argmax is a first-occurrence
running maximum, so the Pallas kernel under x64 interpret mode
reproduces this oracle bit for bit — the same contract ``net_rerate`` /
``event_engine`` / ``st_cost`` pin.
"""

from __future__ import annotations

import numpy as np


def strategy_plan_ref(bw: np.ndarray, fetch: np.ndarray, local: np.ndarray,
                      serve: np.ndarray, free: np.ndarray,
                      size: np.ndarray) -> tuple[np.ndarray, ...]:
    """Plan one burst of (job, missing-file) pairs.

    Args:
      bw: ``(sites, pairs)`` point bandwidth from each site to pair
        ``p``'s destination (columns of
        :meth:`repro.core.network.NetworkEngine.point_bandwidth_matrix`).
      fetch: ``(sites, pairs)`` 0/1 — fetchable holders of pair ``p``'s
        file (online, or the durable master copy).
      local: ``(sites, pairs)`` 0/1 — site in the destination's region.
      serve: ``(sites,)`` decayed serving load per site (all zeros for
        the history-blind strategies).
      free: ``(pairs,)`` free SE bytes at each destination.
      size: ``(pairs,)`` file size of each pair.

    Returns ``(src_global, src_local, has_local, inter_global,
    store_ok)``, each ``(pairs,)`` float64 (site ids are exact small
    integers; the flags are 0.0/1.0).
    """
    bw = np.asarray(bw, np.float64)
    fetch = np.asarray(fetch, np.float64) > 0.0
    local = np.asarray(local, np.float64) > 0.0
    serve = np.asarray(serve, np.float64)
    free = np.asarray(free, np.float64)
    size = np.asarray(size, np.float64)
    n_pairs = bw.shape[1]
    eff = bw / (1.0 + serve)[:, None]
    key_g = np.where(fetch, eff, -1.0)
    key_l = np.where(fetch & local, eff, -1.0)
    src_g = np.argmax(key_g, axis=0)                 # first max = lowest id
    src_l = np.argmax(key_l, axis=0)
    has_l = (fetch & local).any(axis=0)
    inter_g = ~local[src_g, np.arange(n_pairs)]
    store_ok = free >= size
    return (src_g.astype(np.float64), src_l.astype(np.float64),
            has_l.astype(np.float64), inter_g.astype(np.float64),
            store_ok.astype(np.float64))
