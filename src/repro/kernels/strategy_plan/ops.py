"""Public wrapper for the replica-strategy plan pass (pallas / interpret /
numpy).

Like ``st_cost``, this op is called from host code (the batched planner
classes in :mod:`repro.core.replica`, once per arrival burst and per
singleton replan), so it takes and returns host numpy values and picks
the route per call:

  * ``"auto"``   — the compiled Pallas kernel on TPU; the float64 numpy
    oracle on CPU (no per-burst jax dispatch overhead, bit-identical to
    the oracle trivially). This is what ``strategy_mode="batch"`` uses.
  * ``"pallas"`` — force the compiled kernel. Compiled TPU execution is
    float32 (no f64 on TPU): site picks can drift on near-tie effective
    bandwidths, so the bit-identity contract covers the CPU routes only.
  * ``"interpret"`` — the kernel under the Pallas interpreter with x64
    enabled: slow, bit-identical to the oracle; used by the kernel tests.
  * ``"numpy"``  — the oracle directly.
"""

from __future__ import annotations

import numpy as np

from .ref import strategy_plan_ref


def strategy_plan(bw, fetch, local, serve, free, size, *,
                  backend: str = "auto"
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                             np.ndarray, np.ndarray]:
    """Plan one burst of (job, missing-file) pairs.

    See :func:`.ref.strategy_plan_ref` for the argument contract.
    Returns host ``(src_global, src_local, has_local, inter_global,
    store_ok)`` with decision dtypes (``intp`` site ids, ``bool`` flags)
    regardless of backend.
    """
    if backend in ("auto", "pallas", "interpret"):
        import jax

        if backend == "pallas" or (backend == "auto"
                                   and jax.default_backend() == "tpu"):
            from .kernel import strategy_plan_kernel
            out = strategy_plan_kernel(
                np.asarray(bw, np.float32),
                np.asarray(fetch, np.float32),
                np.asarray(local, np.float32),
                np.asarray(serve, np.float32),
                np.asarray(free, np.float32),
                np.asarray(size, np.float32))
            return _decisions(*(np.asarray(o, np.float64) for o in out))
        if backend == "interpret":
            from jax.experimental import enable_x64

            from .kernel import strategy_plan_kernel
            with enable_x64():
                out = strategy_plan_kernel(
                    np.asarray(bw, np.float64),
                    np.asarray(fetch, np.float64),
                    np.asarray(local, np.float64),
                    np.asarray(serve, np.float64),
                    np.asarray(free, np.float64),
                    np.asarray(size, np.float64), interpret=True)
            return _decisions(*(np.asarray(o, np.float64) for o in out))
        backend = "numpy"
    if backend != "numpy":
        raise ValueError(f"unknown strategy_plan backend {backend!r} "
                         "(want 'auto'|'pallas'|'interpret'|'numpy')")
    return _decisions(*strategy_plan_ref(bw, fetch, local, serve, free,
                                         size))


def _decisions(src_g, src_l, has_l, inter_g, store_ok):
    """Float kernel outputs -> host decision dtypes."""
    return (src_g.astype(np.intp), src_l.astype(np.intp),
            has_l > 0.0, inter_g > 0.0, store_ok > 0.0)
