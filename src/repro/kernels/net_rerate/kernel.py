"""Pallas TPU kernel for the fluid-network re-rate + next-completion scan.

The DES re-rates transfers whenever link occupancy changes: every active
transfer's rate is ``min over its crossed links of bandwidth / max(1,
active)`` and the engine needs the earliest ``now + remaining / rate`` to
schedule the next NET wake-up. At 100k concurrent transfers that is a
(slots x path) gather-min plus a masked min-reduction — one VPU-shaped
pass, no MXU.

Layout: the path matrix is transposed to ``(max_links, slots)`` so the
slot axis lands on lanes, padded to a lane multiple; the (small, static)
link-level axis is unrolled in the kernel. Link shares are computed once
per call from the ``(1, links)`` bandwidth/occupancy rows and gathered per
level with ``jnp.take``. A single program sees the whole batch: even at
100k slots the operands are ~2 MB, well under VMEM.

Interpret mode runs the same kernel eagerly with jnp on CPU; under
``jax.experimental.enable_x64`` it computes in float64 and is then
bit-identical to ``ref.net_rerate_ref`` (divide/min are exact IEEE ops) —
that is the contract ``tests/test_kernels.py`` pins.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Lane width of the slot axis; the level axis is padded to the float32
# sublane minimum so the compiled layout is legal on TPU.
_LANES = 128
_SUBLANES = 8


def _rerate_scan_kernel(path_ref, rem_ref, bw_ref, act_ref, now_ref,
                        rate_ref, eta_ref, *, levels: int):
    share = bw_ref[0, :] / jnp.maximum(1.0, act_ref[0, :])     # (links,)
    rate = None
    has_link = None
    for lvl in range(levels):                                   # static unroll
        idx = path_ref[lvl, :]                                  # (slots,)
        valid = idx >= 0
        sh = jnp.where(valid, jnp.take(share, jnp.maximum(idx, 0)), jnp.inf)
        rate = sh if rate is None else jnp.minimum(rate, sh)
        has_link = valid if has_link is None else has_link | valid
    rate = jnp.where(has_link, rate, 0.0)
    rate_ref[0, :] = rate
    now = now_ref[0, 0]
    # live slots only: padding rows have rate 0 and drop out of the min
    eta = jnp.where(rate > 0.0, now + rem_ref[0, :] / rate, jnp.inf)
    eta_ref[0, 0] = jnp.min(eta)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _rerate_call(path, rem, link_bw, link_act, now, *, interpret: bool):
    levels, slots = path.shape
    dtype = rem.dtype
    kernel = functools.partial(_rerate_scan_kernel, levels=levels)
    rate, eta = pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 4
        + [pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=[pl.BlockSpec(memory_space=pltpu.VMEM),
                   pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((1, slots), dtype),
                   jax.ShapeDtypeStruct((1, 1), dtype)],
        interpret=interpret,
    )(path, rem.reshape(1, slots), link_bw.reshape(1, -1),
      link_act.reshape(1, -1), now.reshape(1, 1))
    return rate[0], eta[0, 0]


def net_rerate_kernel(path, rem, link_bw, link_act, now, *,
                      interpret: bool = False):
    """Same contract as :func:`..ref.net_rerate_ref`, computed by the
    Pallas kernel. ``path`` is ``(slots, max_links)`` (-1 padded); dtypes
    follow ``rem`` (float32 compiled on TPU, float64 under x64 interpret).
    """
    path = jnp.asarray(path, jnp.int32)
    rem = jnp.asarray(rem)
    slots, levels = path.shape
    if slots == 0:
        return jnp.zeros((0,), rem.dtype), jnp.asarray(jnp.inf, rem.dtype)
    pad_s = (-slots) % _LANES
    pad_l = (-levels) % _SUBLANES
    # transpose so slots ride the lanes; padding rows/slots are all -1 and
    # come out with rate 0, which the eta scan ignores
    path_t = jnp.pad(path.T, ((0, pad_l), (0, pad_s)), constant_values=-1)
    rem_p = jnp.pad(rem, (0, pad_s))
    nlinks = link_bw.shape[0]
    pad_k = (-nlinks) % _LANES
    # padded links get bw=1/act=1 (share 1.0); no real path row indexes them
    bw_p = jnp.pad(jnp.asarray(link_bw, rem.dtype), (0, pad_k),
                   constant_values=1.0)
    act_p = jnp.pad(jnp.asarray(link_act, rem.dtype), (0, pad_k),
                    constant_values=1.0)
    now = jnp.asarray(now, rem.dtype)
    rate, eta = _rerate_call(path_t, rem_p, bw_p, act_p, now,
                             interpret=interpret)
    return rate[:slots], eta
