"""Public wrapper for the net re-rate (pallas / interpret / numpy ref).

Unlike the model kernels this op is called from the discrete-event loop
(host code, once per link-occupancy change), so the wrapper returns host
numpy values and picks the backend per call:

  * ``"auto"``   — the compiled Pallas kernel on TPU; the float64 numpy
    oracle on CPU (no per-event jax dispatch overhead, bit-identical to
    the incremental engine backend). This is what ``net="pallas"`` uses.
  * ``"pallas"`` — force the compiled kernel. Compiled TPU execution is
    float32 (no f64 on TPU): ~1e-7 relative rate drift vs the oracle, so
    the engine's bit-identity contract covers the CPU routes only.
  * ``"interpret"`` — the kernel under the Pallas interpreter with x64
    enabled: slow, but bit-identical to the oracle; used by the kernel
    tests and the ``net="pallas-interpret"`` engine flag.
  * ``"numpy"``  — the oracle directly.
"""

from __future__ import annotations

import numpy as np

from .ref import net_rerate_ref


def net_rerate(path, rem, link_bw, link_act, now, *, backend: str = "auto"
               ) -> tuple[np.ndarray, float]:
    """Re-rate transfer slots and scan for the next completion.

    See :func:`.ref.net_rerate_ref` for the argument contract. Returns a
    host ``(rate, eta)`` pair regardless of backend.
    """
    if backend in ("auto", "pallas", "interpret"):
        import jax

        if backend == "pallas" or (backend == "auto"
                                   and jax.default_backend() == "tpu"):
            from .kernel import net_rerate_kernel
            rate, eta = net_rerate_kernel(
                np.asarray(path, np.int32), np.asarray(rem, np.float32),
                np.asarray(link_bw, np.float32),
                np.asarray(link_act, np.float32), np.float32(now))
            return np.asarray(rate, np.float64), float(eta)
        if backend == "interpret":
            from jax.experimental import enable_x64

            from .kernel import net_rerate_kernel
            with enable_x64():
                rate, eta = net_rerate_kernel(
                    np.asarray(path, np.int32), np.asarray(rem, np.float64),
                    np.asarray(link_bw, np.float64),
                    np.asarray(link_act, np.float64), np.float64(now),
                    interpret=True)
            return np.asarray(rate, np.float64), float(eta)
        backend = "numpy"
    if backend != "numpy":
        raise ValueError(f"unknown net_rerate backend {backend!r} "
                         "(want 'auto'|'pallas'|'interpret'|'numpy')")
    return net_rerate_ref(path, rem, link_bw, link_act, now)
