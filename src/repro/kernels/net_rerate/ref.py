"""Vectorized float64 oracle for the fluid-network re-rate.

One full recompute of the fair-share fluid model over a batch of transfer
slots: each slot's rate is the min over its link path of
``bandwidth / max(1, active)``, and the next completion time is the min of
``now + remaining / rate`` over live slots. The per-element operations
(divide, min) are exact IEEE ops, so this full recompute is bit-identical
to the incremental per-link re-rating the numpy engine backend does — both
are the same pure function of link occupancy.

This is also the CPU fast path behind ``net="pallas"``: the Pallas kernel
(``kernel.py``) computes exactly this and is validated against it in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import numpy as np


def net_rerate_ref(path: np.ndarray, rem: np.ndarray, link_bw: np.ndarray,
                   link_act: np.ndarray, now: float
                   ) -> tuple[np.ndarray, float]:
    """Re-rate a batch of transfer slots.

    Args:
      path: ``(slots, max_links)`` int link-index matrix, ``-1``-padded.
        Row i lists every link transfer i crosses (source NIC first, then
        uplinks top-down).
      rem: ``(slots,)`` remaining bytes per transfer.
      link_bw: ``(links,)`` aggregate bandwidth per link.
      link_act: ``(links,)`` concurrent-transfer count per link (float).
      now: current simulation time.

    Returns ``(rate, eta)``: per-slot rates (min fair share over the row's
    links; 0.0 for all-padding rows) and the earliest completion time
    (``inf`` when no slot has a positive rate).
    """
    path = np.asarray(path)
    rem = np.asarray(rem, dtype=np.float64)
    if path.shape[0] == 0:
        return np.zeros(0), float("inf")
    valid = path >= 0
    safe = np.where(valid, path, 0)
    # per-link share once (O(links)), then one gather — same divisions the
    # incremental backend does per slot, so still bit-identical
    share_links = link_bw / np.maximum(1.0, link_act)
    share = np.where(valid, share_links[safe], np.inf)
    rate = share.min(axis=1)
    rate = np.where(valid.any(axis=1), rate, 0.0)
    live = rate > 0.0
    if live.any():
        eta = float(np.min(now + rem[live] / rate[live]))
    else:
        eta = float("inf")
    return rate, eta
