from ..spec import NET_RERATE_SPEC as SPEC
from .ops import net_rerate
from .ref import net_rerate_ref

__all__ = ["SPEC", "net_rerate", "net_rerate_ref"]
