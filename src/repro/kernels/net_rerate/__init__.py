from .ops import net_rerate
from .ref import net_rerate_ref

__all__ = ["net_rerate", "net_rerate_ref"]
