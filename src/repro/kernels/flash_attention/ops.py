"""Jitted public wrapper: picks the Pallas kernel (TPU) or the jnp oracle.

On this CPU container the Pallas TPU kernel runs in interpret mode for
validation only; model code routes through repro.models.attention, which
calls into here when ``use_pallas`` is on (real TPU).
"""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_kernel
from .ref import flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset", "kv_len",
                     "block_q", "block_k", "backend"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_offset=0, kv_len=None, block_q=128, block_k=128,
                    backend="auto"):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu")
    if use_pallas:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, kv_len=kv_len, block_q=block_q,
            block_k=block_k)
    if backend == "interpret":
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, kv_len=kv_len, block_q=block_q,
            block_k=block_k, interpret=True)
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               kv_len=kv_len)
