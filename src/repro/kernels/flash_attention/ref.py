"""Pure-jnp oracle for the flash attention kernel (materialized scores)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        q_offset=0, kv_len=None):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd). Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * (hd ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
