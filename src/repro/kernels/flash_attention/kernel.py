"""Flash attention Pallas TPU kernel.

Grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv-block axis is
innermost (sequentially executed on TPU), so the online-softmax running
state (m, l, acc) lives in VMEM scratch that persists across kv steps of
one (b, h, qi) program family. BlockSpecs stream one (block_q x head_dim)
Q tile and one (block_k x head_dim) K/V tile HBM->VMEM per step; GQA is
handled in the K/V index_map (kv head = q head // group) so grouped K/V
tiles are fetched once per group without materializing a repeat.

Tiles are (128 x 128)-aligned for the MXU; the causal/window masks are
built from broadcasted iotas on the VPU. Softcap (gemma2) is a tanh on the
logits tile. Tiles fully masked by causal/window bounds are skipped with
@pl.when, eliding their MXU work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_k: int,
                  causal: bool, window: int | None, softcap: float | None,
                  q_offset: int, kv_len: int | None):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: tiles fully masked by causal/window bounds
    run = jnp.bool_(True)
    if causal:
        run &= kj * block_k <= q_offset + (qi + 1) * block_q - 1
    if window is not None:
        run &= (kj + 1) * block_k - 1 > q_offset + qi * block_q - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) \
            * (q.shape[-1] ** -0.5)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        if kv_len is not None:
            mask &= k_pos < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=None,
                           softcap=None, q_offset=0, kv_len=None,
                           block_q=128, block_k=128, interpret=False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd); H = G * KV.
    Returns (B, H, Sq, hd). hd should be a multiple of 128 on real TPUs
    (any hd works in interpret mode)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Skv + pk) // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=Skv,
        causal=causal, window=window, softcap=softcap, q_offset=q_offset,
        kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
