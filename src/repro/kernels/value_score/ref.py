"""Vectorized float64 oracle for the replica value-scoring pass.

Scores the full ``(sites, files)`` value matrix of the replication economy
(:mod:`repro.core.economy`) in one pass:

1. ``bestbw[s, f]`` — the best point bandwidth at which site ``s`` could
   fetch file ``f`` right now: max over holders ``h`` of ``bw[h, s]``,
   with **self-supply excluded** (``h == s`` never counts, so a file the
   site already holds scores its re-fetch-if-dropped cost — that is what
   makes the same matrix usable for both acquisition and retention value).
2. ``value[s, f]`` — under ``mode="cost"`` (the OptorSim-style economic
   valuation) ``demand * size / bestbw``: predicted future accesses times
   the transfer seconds each would cost without a local replica. Under
   ``mode="plain"`` (pure popularity prediction) just ``demand`` masked to
   pairs with a live source.

Pairs with no external holder score 0 in both modes (nothing to buy).

Max/divide are exact IEEE ops and the max-reduction is order-independent,
so the Pallas kernel (``kernel.py``) run under x64 interpret mode is
bit-identical to this oracle — the same contract ``net_rerate`` pins, here
checked by ``tests/test_kernels.py`` and reachable end-to-end via the
``econ="pallas-interpret"`` engine flag.
"""

from __future__ import annotations

import numpy as np

MODES = ("cost", "plain")


def value_score_ref(demand: np.ndarray, sizes: np.ndarray,
                    presence: np.ndarray, bw: np.ndarray, *,
                    mode: str = "cost") -> np.ndarray:
    """Score every (site, file) pair.

    Args:
      demand: ``(sites, files)`` predicted future accesses (decayed counts,
        already region-pooled by the caller).
      sizes: ``(files,)`` file sizes in bytes.
      presence: ``(sites, files)`` bool — which sites are fetchable holders.
      bw: ``(sites, sites)`` point-bandwidth matrix, ``bw[h, s]`` = bytes/s
        from holder ``h`` to site ``s``
        (:meth:`repro.core.network.NetworkEngine.point_bandwidth_matrix`).
      mode: ``"cost"`` (economic: demand x transfer cost, in predicted
        seconds saved) or ``"plain"`` (popularity: demand masked to pairs
        with a live source).

    Returns ``(sites, files)`` float64 values.
    """
    if mode not in MODES:
        raise ValueError(f"unknown value_score mode {mode!r} "
                         f"(want one of {MODES})")
    demand = np.asarray(demand, np.float64)
    sizes = np.asarray(sizes, np.float64)
    presence = np.asarray(presence, bool)
    bw = np.asarray(bw, np.float64)
    n_sites, n_files = demand.shape
    # best external source per (s, f): max over holders h != s of bw[h, s].
    # Accumulated one holder row at a time — O(sites) passes over an
    # (sites, files) buffer instead of materializing (sites, files, sites).
    best = np.zeros((n_sites, n_files))
    for h in range(n_sites):
        if not presence[h].any():
            continue
        contrib = np.where(presence[h][None, :], bw[h][:, None], 0.0)
        contrib[h, :] = 0.0                      # self-supply excluded
        np.maximum(best, contrib, out=best)
    if mode == "plain":
        return np.where(best > 0.0, demand, 0.0)
    # masked entries never read the quotient, so a safe denominator keeps
    # the kept entries bit-identical while avoiding 0 * inf warnings
    cost = sizes[None, :] / np.where(best > 0.0, best, 1.0)
    return np.where(best > 0.0, demand * cost, 0.0)
