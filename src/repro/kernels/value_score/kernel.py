"""Pallas TPU kernel for the replica value-scoring pass.

The replication economy re-scores every (site, file) pair each time its
periodic DES event fires: ``bestbw[s, f] = max over holders h != s of
bw[h, s]`` followed by ``value = demand * size / bestbw`` (see ``ref.py``
for the exact contract). Naively that reduction materializes a
``(sites, files, sites)`` tensor — ~200 MB at the 500-site scale point —
so the kernel instead runs a ``fori_loop`` over the holder axis carrying
an ``(sites, files)`` running max in VMEM: one VPU-shaped fused pass, no
MXU, peak memory O(sites x files).

Layout: the file axis rides the lanes (padded to 128), the site axis the
sublanes (padded to 8). The bandwidth matrix is ``(sites, sites)`` with
the destination axis on lanes. Padding rows of ``presence`` are all zero
and padded ``bw`` entries are 0, so they never win the max; padded file
columns score 0 and are sliced off.

Interpret mode runs the same kernel eagerly on CPU; under
``jax.experimental.enable_x64`` it computes in float64 and is then
bit-identical to ``ref.value_score_ref`` (max/divide are exact IEEE ops;
the max-reduction is order-independent) — the contract pinned by
``tests/test_kernels.py`` and the ``econ="pallas-interpret"`` engine flag.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128
_SUBLANES = 8


def _value_score_kernel(demand_ref, sizes_ref, presence_ref, bw_ref,
                        out_ref, *, plain: bool):
    demand = demand_ref[...]                       # (S, F)
    presence = presence_ref[...]                   # (S, F) 0/1
    bw = bw_ref[...]                               # (S, S) [holder, dst]
    n_sites = demand.shape[0]
    # dst-site index per output row, used to mask self-supply (h == s)
    row_id = jax.lax.broadcasted_iota(jnp.int32, demand.shape, 0)

    def body(h, best):
        prow = jax.lax.dynamic_index_in_dim(presence, h, 0,
                                            keepdims=True)      # (1, F)
        # bw's dst axis is lane-padded wider than the output's sublane-
        # padded site axis; keep the first n_sites entries
        brow = jax.lax.dynamic_index_in_dim(bw, h, 0,
                                            keepdims=False)[:n_sites]
        contrib = jnp.where((prow > 0.0) & (row_id != h),
                            brow[:, None], 0.0)
        return jnp.maximum(best, contrib)

    best = jax.lax.fori_loop(0, n_sites, body, jnp.zeros_like(demand))
    if plain:
        out_ref[...] = jnp.where(best > 0.0, demand, 0.0)
    else:
        cost = sizes_ref[0, :][None, :] / best     # inf where best == 0 ...
        out_ref[...] = jnp.where(best > 0.0, demand * cost, 0.0)


@functools.partial(jax.jit, static_argnames=("plain", "interpret"))
def _value_score_call(demand, sizes, presence, bw, *, plain: bool,
                      interpret: bool):
    kernel = functools.partial(_value_score_kernel, plain=plain)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 4,
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct(demand.shape, demand.dtype),
        interpret=interpret,
    )(demand, sizes, presence, bw)


def value_score_kernel(demand, sizes, presence, bw, *, mode: str = "cost",
                       interpret: bool = False):
    """Same contract as :func:`..ref.value_score_ref`, computed by the
    Pallas kernel. Dtypes follow ``demand`` (float32 compiled on TPU,
    float64 under x64 interpret)."""
    demand = jnp.asarray(demand)
    dtype = demand.dtype
    n_sites, n_files = demand.shape
    if n_sites == 0 or n_files == 0:
        return jnp.zeros((n_sites, n_files), dtype)
    pad_s = (-n_sites) % _SUBLANES
    pad_f = (-n_files) % _LANES
    pad_d = (-n_sites) % _LANES          # dst axis of bw rides the lanes
    demand_p = jnp.pad(demand, ((0, pad_s), (0, pad_f)))
    sizes_p = jnp.pad(jnp.asarray(sizes, dtype), (0, pad_f)).reshape(1, -1)
    presence_p = jnp.pad(jnp.asarray(presence, dtype),
                         ((0, pad_s), (0, pad_f)))
    bw_p = jnp.pad(jnp.asarray(bw, dtype), ((0, pad_s), (0, pad_d)))
    out = _value_score_call(demand_p, sizes_p, presence_p, bw_p,
                            plain=(mode == "plain"), interpret=interpret)
    return out[:n_sites, :n_files]
