from ..spec import VALUE_SCORE_SPEC as SPEC
from .ops import value_score
from .ref import value_score_ref

__all__ = ["SPEC", "value_score", "value_score_ref"]
