from .ops import value_score
from .ref import value_score_ref

__all__ = ["value_score", "value_score_ref"]
