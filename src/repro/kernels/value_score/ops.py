"""Public wrapper for the value-scoring pass (pallas / interpret / numpy).

Like ``net_rerate``, this op is called from host code (the economy's
periodic DES event), so it returns host numpy values and picks the route
per call:

  * ``"auto"``   — the compiled Pallas kernel on TPU; the float64 numpy
    oracle on CPU (no per-event jax dispatch overhead, bit-identical to
    the oracle trivially). This is what ``econ="pallas"`` uses.
  * ``"pallas"`` — force the compiled kernel. Compiled TPU execution is
    float32 (no f64 on TPU): ~1e-7 relative drift vs the oracle, so the
    bit-identity contract covers the CPU routes only.
  * ``"interpret"`` — the kernel under the Pallas interpreter with x64
    enabled: slow, bit-identical to the oracle; used by the kernel tests
    and the ``econ="pallas-interpret"`` engine flag.
  * ``"numpy"``  — the oracle directly.
"""

from __future__ import annotations

import numpy as np

from .ref import MODES, value_score_ref


def value_score(demand, sizes, presence, bw, *, mode: str = "cost",
                backend: str = "auto") -> np.ndarray:
    """Score the full (sites, files) replica value matrix.

    See :func:`.ref.value_score_ref` for the argument contract. Returns a
    host float64 array regardless of backend.
    """
    if mode not in MODES:
        raise ValueError(f"unknown value_score mode {mode!r} "
                         f"(want one of {MODES})")
    if backend in ("auto", "pallas", "interpret"):
        import jax

        if backend == "pallas" or (backend == "auto"
                                   and jax.default_backend() == "tpu"):
            from .kernel import value_score_kernel
            out = value_score_kernel(
                np.asarray(demand, np.float32), np.asarray(sizes, np.float32),
                np.asarray(presence, np.float32), np.asarray(bw, np.float32),
                mode=mode)
            return np.asarray(out, np.float64)
        if backend == "interpret":
            from jax.experimental import enable_x64

            from .kernel import value_score_kernel
            with enable_x64():
                out = value_score_kernel(
                    np.asarray(demand, np.float64),
                    np.asarray(sizes, np.float64),
                    np.asarray(presence, np.float64),
                    np.asarray(bw, np.float64), mode=mode, interpret=True)
            return np.asarray(out, np.float64)
        backend = "numpy"
    if backend != "numpy":
        raise ValueError(f"unknown value_score backend {backend!r} "
                         "(want 'auto'|'pallas'|'interpret'|'numpy')")
    return value_score_ref(demand, sizes, presence, bw, mode=mode)
