from ..spec import EVENT_ENGINE_SPEC as SPEC
from .ops import event_engine
from .ref import event_engine_core, event_engine_ref

__all__ = ["SPEC", "event_engine", "event_engine_core", "event_engine_ref"]
