"""Vectorized float64 oracle for the batched event-engine flush.

One fused pass over *all* transfer slots, run once per drained event
instant by the ``net="device"`` engine backend instead of once per event:

1. reconstruct each live slot's remaining bytes from its cached
   ``(rate, eta)`` pair — ``rem = rate * (eta - now)`` — so the engine
   never integrates ``rem`` on the host between flushes;
2. re-rate every slot: min over its link path of
   ``bandwidth / max(1, active)`` (identical to the incremental numpy
   backend and to :mod:`repro.kernels.net_rerate`);
3. recompute every slot's completion eta and reduce to the earliest one,
   which becomes the next NET wake-up.

Step 1 is the deliberate fidelity break: the numpy oracle engine advances
``rem -= rate * dt`` stepwise, while this pass reconstructs it as
``rate * (eta - now)``. Both describe the same fluid trajectory but round
differently, so the device engine is *not* bit-identical to the numpy
engine — it is pinned by the tolerance-golden contract
(``tests/golden_tolerance.json``) instead. Within the device route itself
every operation here is an exact IEEE op, so the Pallas kernel
(``kernel.py``) under x64 interpret is bit-identical to this oracle —
that contract the jaxpr auditor enforces.
"""

from __future__ import annotations

import numpy as np


def event_engine_ref(path: np.ndarray, rem: np.ndarray, rate: np.ndarray,
                     eta: np.ndarray, link_bw: np.ndarray,
                     link_act: np.ndarray, now: float
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
    """Fused reconstruct + re-rate + next-completion pass.

    Args:
      path: ``(slots, max_links)`` int link-index matrix, ``-1``-padded;
        all ``-1`` rows are released/unused slots.
      rem: ``(slots,)`` remaining bytes *as of the previous flush* (used
        verbatim for slots whose cached rate is 0, i.e. freshly allocated
        or released slots).
      rate: ``(slots,)`` rates set by the previous flush.
      eta: ``(slots,)`` completion times set by the previous flush
        (``inf`` where rate is 0).
      link_bw: ``(links,)`` aggregate bandwidth per link.
      link_act: ``(links,)`` concurrent-transfer count per link (float).
      now: current simulation time (the flush instant).

    Returns ``(rem_now, rate_new, eta_new, eta_min)``: reconstructed
    remaining bytes, new per-slot rates (0.0 for all-padding rows), new
    per-slot completion times (``inf`` for dead slots) and their min
    (``inf`` when no slot is live).
    """
    path = np.asarray(path)
    rem = np.asarray(rem, dtype=np.float64)
    rate = np.asarray(rate, dtype=np.float64)
    eta = np.asarray(eta, dtype=np.float64)
    return event_engine_core(path, rem, rate, eta, link_bw, link_act, now)


def event_engine_core(path: np.ndarray, rem: np.ndarray, rate: np.ndarray,
                      eta: np.ndarray, link_bw: np.ndarray,
                      link_act: np.ndarray, now: float
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
    """:func:`event_engine_ref` minus the input coercion — for callers
    that already hold float64 ndarrays (the engine's flush loop calls
    this hundreds of thousands of times per run).

    The share gather uses an ``inf`` sentinel appended to the share
    vector: the path matrix's ``-1`` padding legally indexes the last
    element, so no validity mask or ``(slots, links)`` where-temporary
    is ever built, and the per-slot min runs as one ``np.minimum`` pass
    per link column instead of a slow small-axis reduction. Same IEEE
    ops on the same values as the masked formulation — bit-identical
    outputs (the Pallas kernel equivalence test pins this)."""
    if path.shape[0] == 0:
        return np.zeros(0), np.zeros(0), np.zeros(0), float("inf")
    shares = np.empty(link_bw.shape[0] + 1)
    np.divide(link_bw, np.maximum(1.0, link_act), out=shares[:-1])
    shares[-1] = np.inf          # the -1 padding's landing cell
    rate_new = shares[path[:, 0]]
    for d in range(1, path.shape[1]):
        np.minimum(rate_new, shares[path[:, d]], out=rate_new)
    # all-padding rows reduced to the bare sentinel: dead, rate 0
    np.copyto(rate_new, 0.0, where=~np.isfinite(rate_new))
    # reconstruct remaining bytes from the cached (rate, eta) pair; slots
    # without a cached rate (fresh allocs, released rows) keep stored rem.
    # eta is masked before the multiply so inf etas on dead slots never
    # produce 0*inf NaNs in the untaken branch.
    carried = rate > 0.0
    eta_c = np.where(carried, eta, 0.0)
    rem_now = np.maximum(np.where(carried, rate * (eta_c - now), rem), 0.0)
    live = rate_new > 0.0
    eta_new = np.where(live, now + rem_now / np.where(live, rate_new, 1.0),
                       np.inf)
    return rem_now, rate_new, eta_new, float(eta_new.min())
