"""Public wrapper for the event-engine flush (pallas / interpret / numpy).

Like :mod:`repro.kernels.net_rerate`, this op is called from the
discrete-event loop (host code, once per drained event instant), so the
wrapper returns host numpy values and picks the backend per call:

  * ``"auto"``   — the compiled Pallas kernel on TPU; the float64 numpy
    oracle on CPU (no per-instant jax dispatch overhead). This is what
    ``net="device"`` uses.
  * ``"pallas"`` — force the compiled kernel. Compiled TPU execution is
    float32 (no f64 on TPU): extra ~1e-7 relative drift on top of the
    reconstruction drift the tolerance goldens already bound.
  * ``"interpret"`` — the kernel under the Pallas interpreter with x64
    enabled: slow, but bit-identical to the oracle; used by the kernel
    tests and the ``net="device-interpret"`` engine flag.
  * ``"numpy"``  — the oracle directly.
"""

from __future__ import annotations

import numpy as np

from .ref import event_engine_ref


def event_engine(path, rem, rate, eta, link_bw, link_act, now, *,
                 backend: str = "auto"
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
    """Run one fused flush pass over all transfer slots.

    See :func:`.ref.event_engine_ref` for the argument contract. Returns
    a host ``(rem_now, rate_new, eta_new, eta_min)`` tuple regardless of
    backend.
    """
    if backend in ("auto", "pallas", "interpret"):
        import jax

        if backend == "pallas" or (backend == "auto"
                                   and jax.default_backend() == "tpu"):
            from .kernel import event_engine_kernel
            out = event_engine_kernel(
                np.asarray(path, np.int32), np.asarray(rem, np.float32),
                np.asarray(rate, np.float32), np.asarray(eta, np.float32),
                np.asarray(link_bw, np.float32),
                np.asarray(link_act, np.float32), np.float32(now))
            rem_now, rate_new, eta_new, eta_min = out
            return (np.asarray(rem_now, np.float64),
                    np.asarray(rate_new, np.float64),
                    np.asarray(eta_new, np.float64), float(eta_min))
        if backend == "interpret":
            from jax.experimental import enable_x64

            from .kernel import event_engine_kernel
            with enable_x64():
                out = event_engine_kernel(
                    np.asarray(path, np.int32), np.asarray(rem, np.float64),
                    np.asarray(rate, np.float64), np.asarray(eta, np.float64),
                    np.asarray(link_bw, np.float64),
                    np.asarray(link_act, np.float64), np.float64(now),
                    interpret=True)
            rem_now, rate_new, eta_new, eta_min = out
            return (np.asarray(rem_now, np.float64),
                    np.asarray(rate_new, np.float64),
                    np.asarray(eta_new, np.float64), float(eta_min))
        backend = "numpy"
    if backend != "numpy":
        raise ValueError(f"unknown event_engine backend {backend!r} "
                         "(want 'auto'|'pallas'|'interpret'|'numpy')")
    return event_engine_ref(path, rem, rate, eta, link_bw, link_act, now)
