"""Pallas TPU kernel for the batched event-engine flush.

The ``net="device"`` engine backend defers every link-occupancy change
within one event instant and then runs this single fused pass: remaining
bytes are reconstructed from the cached ``(rate, eta)`` pair, every slot
is re-rated (gather-min of per-link fair shares along its path, as in
:mod:`repro.kernels.net_rerate`), and a running-min reduction over the new
etas yields the next NET wake-up — one device call per drained instant
instead of one per event.

Layout matches ``net_rerate``: the path matrix is transposed to
``(max_links, slots)`` so the slot axis rides the lanes (padded to a lane
multiple) and the small static level axis is unrolled in the kernel; the
slot-state rows (rem/rate/eta) are ``(1, slots)`` VMEM rows, link
bandwidth/occupancy are ``(1, links)`` rows, ``now`` sits in SMEM. One
program sees the whole batch — even 100k slots is a few MB of VMEM.

Interpret mode under ``jax.experimental.enable_x64`` computes in float64
and is bit-identical to ``ref.event_engine_ref`` (where/multiply/divide/
min are exact IEEE ops) — the contract the jaxpr auditor and
``tests/test_kernels.py`` pin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Lane width of the slot axis; the level axis is padded to the float32
# sublane minimum so the compiled layout is legal on TPU.
_LANES = 128
_SUBLANES = 8


def _event_flush_kernel(path_ref, rem_ref, rate_ref, eta_ref, bw_ref,
                        act_ref, now_ref, rem_out, rate_out, eta_out,
                        eta_min_ref, *, levels: int):
    share = bw_ref[0, :] / jnp.maximum(1.0, act_ref[0, :])     # (links,)
    rate_new = None
    has_link = None
    for lvl in range(levels):                                   # static unroll
        idx = path_ref[lvl, :]                                  # (slots,)
        valid = idx >= 0
        sh = jnp.where(valid, jnp.take(share, jnp.maximum(idx, 0)), jnp.inf)
        rate_new = sh if rate_new is None else jnp.minimum(rate_new, sh)
        has_link = valid if has_link is None else has_link | valid
    rate_new = jnp.where(has_link, rate_new, 0.0)
    now = now_ref[0, 0]
    rate_old = rate_ref[0, :]
    carried = rate_old > 0.0
    # mask dead slots' inf etas before the multiply (no 0*inf NaNs)
    eta_c = jnp.where(carried, eta_ref[0, :], 0.0)
    rem_now = jnp.maximum(
        jnp.where(carried, rate_old * (eta_c - now), rem_ref[0, :]), 0.0)
    live = rate_new > 0.0
    eta_new = jnp.where(live, now + rem_now / jnp.where(live, rate_new, 1.0),
                        jnp.inf)
    rem_out[0, :] = rem_now
    rate_out[0, :] = rate_new
    eta_out[0, :] = eta_new
    eta_min_ref[0, 0] = jnp.min(eta_new)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _flush_call(path, rem, rate, eta, link_bw, link_act, now, *,
                interpret: bool):
    levels, slots = path.shape
    dtype = rem.dtype
    kernel = functools.partial(_event_flush_kernel, levels=levels)
    rem_now, rate_new, eta_new, eta_min = pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 6
        + [pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 3
        + [pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((1, slots), dtype),
                   jax.ShapeDtypeStruct((1, slots), dtype),
                   jax.ShapeDtypeStruct((1, slots), dtype),
                   jax.ShapeDtypeStruct((1, 1), dtype)],
        interpret=interpret,
    )(path, rem.reshape(1, slots), rate.reshape(1, slots),
      eta.reshape(1, slots), link_bw.reshape(1, -1),
      link_act.reshape(1, -1), now.reshape(1, 1))
    return rem_now[0], rate_new[0], eta_new[0], eta_min[0, 0]


def event_engine_kernel(path, rem, rate, eta, link_bw, link_act, now, *,
                        interpret: bool = False):
    """Same contract as :func:`..ref.event_engine_ref`, computed by the
    Pallas kernel. ``path`` is ``(slots, max_links)`` (-1 padded); dtypes
    follow ``rem`` (float32 compiled on TPU, float64 under x64 interpret).
    """
    path = jnp.asarray(path, jnp.int32)
    rem = jnp.asarray(rem)
    slots, levels = path.shape
    if slots == 0:
        z = jnp.zeros((0,), rem.dtype)
        return z, z, z, jnp.asarray(jnp.inf, rem.dtype)
    pad_s = (-slots) % _LANES
    pad_l = (-levels) % _SUBLANES
    # transpose so slots ride the lanes; padded slots are all -1 path rows
    # with zeroed state — they re-rate to 0 and an inf eta, dropping out
    # of the min
    path_t = jnp.pad(path.T, ((0, pad_l), (0, pad_s)), constant_values=-1)
    rem_p = jnp.pad(jnp.asarray(rem), (0, pad_s))
    rate_p = jnp.pad(jnp.asarray(rate, rem.dtype), (0, pad_s))
    eta_p = jnp.pad(jnp.asarray(eta, rem.dtype), (0, pad_s))
    nlinks = link_bw.shape[0]
    pad_k = (-nlinks) % _LANES
    # padded links get bw=1/act=1 (share 1.0); no real path row indexes them
    bw_p = jnp.pad(jnp.asarray(link_bw, rem.dtype), (0, pad_k),
                   constant_values=1.0)
    act_p = jnp.pad(jnp.asarray(link_act, rem.dtype), (0, pad_k),
                    constant_values=1.0)
    now = jnp.asarray(now, rem.dtype)
    rem_now, rate_new, eta_new, eta_min = _flush_call(
        path_t, rem_p, rate_p, eta_p, bw_p, act_p, now, interpret=interpret)
    return rem_now[:slots], rate_new[:slots], eta_new[:slots], eta_min
