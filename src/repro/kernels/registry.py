"""Auto-discovery of kernel packages (jax-free).

Every subpackage of :mod:`repro.kernels` that exports a module-level
``SPEC: KernelSpec`` is a registered kernel. The jaxpr auditor
(:mod:`repro.analysis.jaxpr_audit`) iterates :func:`registered_kernels`
so a new kernel package is audited the moment it exists — no test or
auditor edit required.

Discovery imports only the package ``__init__`` modules, which are all
jax-free by contract (the model packages defer their jax-importing
``ops``/``kernel`` modules behind a module ``__getattr__``).
"""

from __future__ import annotations

import importlib
import pkgutil

from .spec import KernelSpec


def registered_kernels() -> dict[str, KernelSpec]:
    """Return ``{name: spec}`` for every kernel package, sorted by name."""
    import repro.kernels as root

    specs: dict[str, KernelSpec] = {}
    for info in pkgutil.iter_modules(root.__path__):
        if not info.ispkg:
            continue
        mod = importlib.import_module(f"{root.__name__}.{info.name}")
        spec = getattr(mod, "SPEC", None)
        if spec is None:
            continue
        if not isinstance(spec, KernelSpec):
            raise TypeError(f"{mod.__name__}.SPEC is not a KernelSpec")
        if spec.name != info.name:
            raise ValueError(f"{mod.__name__}.SPEC.name {spec.name!r} "
                             f"does not match its package name")
        specs[spec.name] = spec
    return dict(sorted(specs.items()))


def get_kernel_spec(name: str) -> KernelSpec:
    """Look up one registered kernel spec by name."""
    specs = registered_kernels()
    if name not in specs:
        raise KeyError(f"unknown kernel {name!r} "
                       f"(registered: {sorted(specs)})")
    return specs[name]
