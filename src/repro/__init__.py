"""repro — hierarchical data-grid scheduling + HRS replication (Abdi et
al., 2010) built as a multi-pod JAX training/inference framework."""

__version__ = "1.0.0"
