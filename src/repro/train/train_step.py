"""Training step: loss -> grads (microbatched) -> AdamW update.

Gradient accumulation runs as a ``lax.scan`` over microbatches so peak
activation memory is one microbatch with per-group remat; gradients
accumulate in f32 with the parameter sharding (ZeRO). The MoE auxiliary
load-balancing loss is folded in for MoE architectures.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from .optimizer import OptimizerConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    remat: bool = True
    moe_aux_weight: float = 0.01
    opt: OptimizerConfig = OptimizerConfig()


def _split_micro(batch: dict[str, Any], n: int, mesh=None,
                 dp_axes: tuple = ("data",)) -> dict[str, Any]:
    """(B, ...) -> (n, B/n, ...) for gradient accumulation.

    The reshape splits the sharded batch axis, and XLA cannot keep the
    sharding on the new minor axis by itself — without an explicit
    constraint every microbatch ends up REPLICATED across the data axis
    (n x the per-device memory and compute). Pin P(None, dp, ...)."""

    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        out = x.reshape(n, b // n, *x.shape[1:])
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            per = b // n
            dp = dp_axes if per % _dp_size(mesh, dp_axes) == 0 else None
            spec = P(None, dp, *([None] * (out.ndim - 2)))
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, spec))
        return out

    return {k: r(v) for k, v in batch.items()}


def _dp_size(mesh, dp_axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in dp_axes:
        out *= sizes.get(a, 1)
    return out


def micro_loss(cfg: ArchConfig, tcfg: TrainConfig, params, micro):
    loss, metrics = M.loss_fn(cfg, params, micro, remat=tcfg.remat)
    if cfg.moe is not None:
        from repro.models.moe import moe_aux_loss
        # one representative router probe on the embedded input keeps the
        # aux term cheap; the router params of every layer still receive
        # balancing pressure through the shared embedding statistics.
        x = M._embed_tokens(cfg, params, micro)
        aux = 0.0
        tree = params["groups"]
        if "b0" in tree and tree["b0"] is not None and "moe" in tree["b0"]:
            probe = jax.tree.map(lambda w: w[0], tree["b0"]["moe"])
            aux = moe_aux_loss(probe, x, top_k=cfg.moe.top_k)
        loss = loss + tcfg.moe_aux_weight * aux
        metrics = dict(metrics, moe_aux=aux)
    return loss, metrics


def grad_fn(cfg: ArchConfig, tcfg: TrainConfig, params, batch, mesh=None,
            dp_axes: tuple = ("data",), grad_shardings=None):
    """Microbatched value_and_grad. Returns (mean_loss, metrics, grads).

    ``grad_shardings`` (a params-shaped tree of NamedShardings) pins each
    microbatch's gradients to the ZeRO parameter sharding INSIDE the
    accumulation loop: the cross-data-axis reduction then lowers to a
    reduce-scatter of the shard each device owns instead of an all-reduce
    of the full gradient (1/dp of the wire bytes per microbatch)."""
    vg = jax.value_and_grad(
        lambda p, mb: micro_loss(cfg, tcfg, p, mb), has_aux=True)
    n = tcfg.n_microbatches

    def pin(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    if n == 1:
        (loss, metrics), grads = vg(params, batch)
        return loss, metrics, pin(grads)

    micros = _split_micro(batch, n, mesh=mesh, dp_axes=dp_axes)
    zero = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def body(acc, micro):
        g_acc, l_acc = acc
        (loss, _metrics), g = vg(params, micro)
        g = pin(g)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (pin(g_acc), l_acc + loss), None

    (g_acc, l_sum), _ = jax.lax.scan(body, (zero, 0.0), micros)
    grads = jax.tree.map(lambda g: g / n, g_acc)
    loss = l_sum / n
    return loss, {"loss": loss}, grads


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, mesh=None,
                    dp_axes: tuple = ("data",), grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    jit-compatible; shardings are applied by the caller at the jit boundary.
    ``mesh`` (optional) pins the microbatch sharding and ``grad_shardings``
    the ZeRO gradient sharding — required on real meshes, no-ops on a
    single device.
    """

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grad_fn(cfg, tcfg, params, batch, mesh=mesh,
                                       dp_axes=dp_axes,
                                       grad_shardings=grad_shardings)
        params, opt_state, _, stats = adamw_update(
            tcfg.opt, grads, params, opt_state)
        return params, opt_state, {**metrics, **stats, "loss": loss}

    return train_step


def init_train_state(cfg: ArchConfig, key):
    params = M.init_params(cfg, key)
    return params, init_opt_state(params)
