"""AdamW with f32 master weights + cosine schedule, pure JAX.

State layout (all pytrees mirroring params):
  master: f32 copy of params (the source of truth)
  m, v:   f32 first/second moments
  step:   scalar int32

With ZeRO sharding, master/m/v inherit the parameter sharding, so optimizer
memory is 12 bytes/param spread over the whole mesh.

``compress`` hooks gradient compression (int8 quantization with error
feedback) in front of the update — the cross-pod all-reduce then moves 1/4
of the bytes; the error-feedback accumulator keeps the update unbiased over
time (beyond-paper distributed-optimization trick, default off).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False      # int8 + error feedback


def cosine_lr(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


def init_compress_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(g, err):
    """Gradient + carried error -> (int8 payload, scale, new error)."""
    t = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, t - deq


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: OptimizerConfig, grads, params, state,
                 compress_state=None):
    """Returns (new_params, new_state, new_compress_state, stats)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.compress_grads and compress_state is not None:
        pairs = jax.tree.map(quantize_int8, grads, compress_state)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        compress_state = jax.tree.map(lambda p: p[1], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g * clip, grads)

    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    new_state = {"master": master, "m": m, "v": v, "step": step}
    stats = {"grad_norm": gn, "lr": lr}
    return new_params, new_state, compress_state, stats
