"""Fault injection, for both the grid DES and the training runtime.

Two halves:

* **Grid side** — :class:`ChurnSpec` + :func:`churn_schedule` generate
  deterministic site failure/recovery (and slowdown) event lists for the
  discrete-event simulator (``repro.core.simulator``). The scenario engine
  (``repro.core.scenarios``) drives this to build site-churn regimes.
* **Runtime side** — :class:`TrainingSupervisor` wraps a step function with
  checkpoint/restart, deterministic failure injection (``FailurePlan``),
  straggler detection, and elastic re-meshing. On real hardware the failure
  signal comes from the cluster manager; here the plan injects it so
  tests/examples can prove the recovery path end to end.
"""

from __future__ import annotations

import dataclasses
import random as _random
import time
from typing import Any, Callable

from repro.checkpoint.ckpt import (latest_step, restore_checkpoint,
                                   save_checkpoint)


# --------------------------------------------------------------------------
# grid-side injections (consumed by repro.core.run_experiment)
# --------------------------------------------------------------------------
# ChurnSpec itself lives in repro.core.scenarios (it is a ScenarioSpec field
# and that module must stay importable without jax); re-exported here.
from repro.core.scenarios import ChurnSpec  # noqa: E402


def churn_schedule(spec: ChurnSpec, n_sites: int,
                   seed: int = 0) -> list[tuple[int, float, float]]:
    """Expand a :class:`ChurnSpec` into ``(site, at, duration)`` tuples for
    :func:`repro.core.run_experiment`'s ``failures`` argument.

    Failure times are evenly spaced over the window with a small jittered
    offset; sites are drawn without replacement until the pool is exhausted
    (then with replacement), so short schedules never hit one site twice.
    """
    if spec.n_failures <= 0:
        return []
    rng = _random.Random(seed ^ 0x5EED)
    start, end = spec.window
    span = max(0.0, end - start)
    pool = list(range(n_sites))
    rng.shuffle(pool)
    out = []
    for i in range(spec.n_failures):
        site = pool[i] if i < len(pool) else rng.randrange(n_sites)
        frac = (i + rng.random()) / spec.n_failures
        at = start + frac * span
        duration = rng.expovariate(1.0 / spec.mean_downtime_s)
        out.append((site, at, max(1.0, duration)))
    return out


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """fail_at_steps: steps at which a simulated node failure kills the run
    (state is lost, restart restores the latest checkpoint).
    slow_steps: steps that take ``straggle_factor`` x longer (straggler)."""

    fail_at_steps: tuple[int, ...] = ()
    slow_steps: tuple[int, ...] = ()
    straggle_factor: float = 5.0


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class SupervisorStats:
    restarts: int = 0
    steps_run: int = 0
    steps_wasted: int = 0
    stragglers_mitigated: int = 0


class TrainingSupervisor:
    """Checkpoint/restart + straggler mitigation around a pure step fn.

    step_fn(state, step_idx) -> (state, metrics). ``state`` must be a
    checkpointable pytree (params + opt state). Straggler mitigation here is
    deadline-based re-issue: a step exceeding ``deadline x median`` is
    re-executed (deterministic step functions make the re-issue free of
    divergence — the backup result wins, as in the DES's speculative twins).
    """

    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 10, keep_last: int = 3,
                 plan: FailurePlan = FailurePlan(),
                 deadline: float = 4.0) -> None:
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.plan = plan
        self.deadline = deadline
        self.stats = SupervisorStats()
        self._durations: list[float] = []

    def _maybe_checkpoint(self, state, step: int) -> None:
        if step % self.ckpt_every == 0:
            save_checkpoint(state, self.ckpt_dir, step)

    def run(self, state, n_steps: int, *, start_step: int = 0):
        """Run to n_steps with recovery; returns (state, history)."""
        history: list[dict[str, Any]] = []
        step = start_step
        failed_already: set[int] = set()
        while step < n_steps:
            try:
                if step in self.plan.fail_at_steps and step not in failed_already:
                    failed_already.add(step)
                    raise SimulatedFailure(f"node failure at step {step}")
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                if step in self.plan.slow_steps:
                    dt *= self.plan.straggle_factor     # simulated straggler
                med = sorted(self._durations)[len(self._durations) // 2] \
                    if self._durations else dt
                if self._durations and dt > self.deadline * med:
                    # re-issue the step (speculative backup wins)
                    state, metrics = self.step_fn(state, step)
                    self.stats.stragglers_mitigated += 1
                self._durations.append(dt)
                history.append({"step": step, **{k: float(v)
                                                 for k, v in metrics.items()}})
                self.stats.steps_run += 1
                step += 1
                self._maybe_checkpoint(state, step)
            except SimulatedFailure:
                self.stats.restarts += 1
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise RuntimeError("failure before first checkpoint")
                state, _ = restore_checkpoint(self.ckpt_dir, last, like=state)
                self.stats.steps_wasted += step - last
                step = last
        return state, history
