"""Tests for the dataflow framework + unit/dimension checker (SL020-25).

Covers: FlowAnalysis propagation semantics (env scoping, class
attribute pre-pass, fixpoint mode), one positive + one negative (and a
suppression) case per unit rule, the SL001 port-parity pin (the
determinism linter now rides on the framework and must keep flagging /
keep the corpus clean), the unit-broken fixture module, kernel unit
signatures, and the acceptance pin: the shipped dimension-carrying
modules unit-check clean with zero suppressions.
"""

import ast
import textwrap
from pathlib import Path

from repro.analysis import RULES
from repro.analysis.dataflow import FlowAnalysis
from repro.analysis.jaxpr_audit import check_unit_signature
from repro.analysis.simlint import lint_source
from repro.analysis.units import (DIMENSIONS, UNIT_SCOPE, lint_units,
                                  run_units, unit_scoped)

REPO_ROOT = Path(__file__).resolve().parents[1]
FIXTURE = REPO_ROOT / "tests" / "fixtures" / "unit_broken.py"


def unit_rules(source: str, path: str = "repro/core/x.py") -> list[str]:
    return [f.rule for f in lint_units(textwrap.dedent(source), path)]


# -- dataflow framework -----------------------------------------------------

class _TagFlow(FlowAnalysis):
    """Toy client: annotation `int` means label 'tag'; calls to tag()
    produce 'tag'; flags every Name read that evaluates to 'tag'."""

    def ann_label(self, ann):
        return "tag" if isinstance(ann, ast.Name) and ann.id == "int" \
            else None

    def expr_label(self, node):
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            return self.attr_env.get(node.attr)
        if isinstance(node, ast.Call) and self.func_name(node.func) == "tag":
            return "tag"
        return None

    def visit_Expr(self, node):
        if self.expr_label(node.value) == "tag":
            self.flag("TAG", node, "tagged value used")
        self.generic_visit(node)


def tag_lines(source: str, *, fixpoint: bool = False) -> list[int]:
    src = textwrap.dedent(source)
    flow = _TagFlow("x.py", src)
    flow.fixpoint = fixpoint
    return [f.line for f in flow.run(ast.parse(src))]


def test_dataflow_assign_propagation_and_rebinding():
    assert tag_lines("""
        x = tag()
        x
        x = 0
        x
    """) == [3]                       # rebinding to unknown drops the label


def test_dataflow_function_scope_and_annotation_seeding():
    assert tag_lines("""
        def f(a: int, b):
            a
            b
        def g(b):
            b
    """) == [3]                       # only the annotated param labels


def test_dataflow_closure_sees_enclosing_bindings():
    assert tag_lines("""
        def outer():
            y = tag()
            def inner():
                y
    """) == [5]


def test_dataflow_class_attr_prepass():
    assert tag_lines("""
        class C:
            def use(self):
                self.z
            def set(self):
                self.z = tag()
    """) == [4]                       # pre-pass sees later assignment


def test_dataflow_fixpoint_reaches_loop_carried_labels():
    src = """
        def f():
            x = 0
            for _ in range(3):
                x
                x = tag()
    """
    assert tag_lines(src) == []                  # single pass misses it
    assert tag_lines(src, fixpoint=True) == [5]  # fixpoint finds it


def test_dataflow_fixpoint_reports_each_finding_once():
    lines = tag_lines("""
        def f():
            x = tag()
            x
    """, fixpoint=True)
    assert lines == [4]               # warm-up passes stay muted


# -- SL001 port parity ------------------------------------------------------

def test_sl001_still_fires_after_framework_port():
    findings = lint_source(textwrap.dedent("""
        class C:
            def __init__(self):
                self.pending: set[int] = set()
            def drain(self):
                return [x for x in self.pending]
    """), "repro/core/x.py")
    assert [f.rule for f in findings] == ["SL001"]


def test_sl001_order_free_consumers_still_clean_after_port():
    assert lint_source(textwrap.dedent("""
        def f(s: set[int]):
            return sorted(s), any(x > 0 for x in s), len(s)
    """), "repro/core/x.py") == []


def test_simlint_corpus_parity_on_shipped_tree():
    """The ported linter keeps the shipped corpus clean file-for-file
    (the pre-port corpus had zero findings; so must the port)."""
    from repro.analysis import run_analysis
    new, baselined, inline = run_analysis()
    assert new == [] and baselined == []


# -- unit rules: positive / negative / suppression ---------------------------

def test_sl020_cross_dimension_add():
    assert "SL020" in unit_rules("""
        def f(size, now):
            return size + now
    """)


def test_sl020_unknown_operand_is_forgiving():
    assert unit_rules("""
        def f(now):
            return now + 5.0
    """) == []


def test_sl020_augassign_mismatch():
    assert "SL020" in unit_rules("""
        class C:
            def f(self, now):
                self.total_wan_bytes += now
    """)


def test_sl021_cross_dimension_compare():
    assert "SL021" in unit_rules("""
        def f(size, bandwidth):
            return size > bandwidth
    """)


def test_sl021_ratio_compare_is_clean():
    assert unit_rules("""
        def f(size, bandwidth, now, deadline):
            return size / bandwidth > deadline - now
    """) == []                        # bytes/bw -> seconds vs seconds


def test_sl022_bytes_divided_by_mbps():
    assert "SL022" in unit_rules("""
        def f(n_bytes, link_mbps):
            return n_bytes / link_mbps
    """)


def test_sl022_kwarg_binding_without_conversion():
    assert "SL022" in unit_rules("""
        def f(make, spec):
            return make(wan_bandwidth=spec.lan_mbps)
    """)


def test_sl022_converted_mbps_is_clean():
    assert unit_rules("""
        from repro.core.quantities import MBPS_TO_BYTES_PER_S
        def f(make, spec):
            return make(wan_bandwidth=spec.lan_mbps * MBPS_TO_BYTES_PER_S)
    """) == []


def test_sl023_sim_wall_mixing():
    rules = unit_rules("""
        def f(now, elapsed_us):
            return now - elapsed_us
    """)
    assert "SL023" in rules and "SL020" not in rules


def test_sl024_raw_conversion_literal():
    assert "SL024" in unit_rules("""
        def f(n_bytes):
            return n_bytes / 1e9
    """)


def test_sl024_exempt_in_quantities_and_named_constant_clean():
    src = """
        from repro.core.quantities import GB
        def f(n_bytes):
            return n_bytes / GB
    """
    assert unit_rules(src) == []
    assert unit_rules("""
        def f(n_bytes):
            return n_bytes / 1e9
    """, path="repro/core/quantities.py") == []


def test_sl025_declared_dimension_contradiction():
    assert "SL025" in unit_rules("""
        class C:
            def f(self, n_bytes):
                self.makespan = n_bytes
    """)


def test_sl025_registry_outranks_buggy_inference():
    """The buggy assignment itself must not relabel the declared attr."""
    rules = unit_rules("""
        class C:
            def f(self, n_bytes):
                self.makespan = n_bytes
                return self.makespan + n_bytes
    """)
    assert "SL025" in rules and "SL020" in rules


def test_unit_rules_inline_suppression():
    from repro.analysis.findings import (inline_suppressions,
                                         is_inline_suppressed)
    src = textwrap.dedent("""
        def f(size, now):
            return size + now  # simlint: disable=SL020
    """)
    findings = lint_units(src, "repro/core/x.py")
    supp = inline_suppressions(src)
    assert findings and all(is_inline_suppressed(f, supp) for f in findings)


def test_unit_algebra_labels_engine_idioms():
    """The canonical engine lines type-check: rem -= rate*dt,
    eta = now + rem/rate, share = bw/active."""
    assert unit_rules("""
        class Net:
            def advance(self, now, dt):
                self.rem -= self.rate * dt
                eta = now + self.rem / self.rate
                share = self.link_bw / self.n_active
                return eta, share
    """) == []


# -- fixture + shipped tree --------------------------------------------------

def test_unit_broken_fixture_yields_three_distinct_rules():
    findings = lint_units(FIXTURE.read_text(), "tests/fixtures/unit_broken.py")
    rules = {f.rule for f in findings}
    assert len(rules) >= 3, rules
    assert rules <= {"SL020", "SL021", "SL022", "SL023", "SL024", "SL025"}
    # every seeded bug class is caught
    assert {"SL020", "SL021", "SL022", "SL023", "SL024", "SL025"} <= rules


def test_shipped_tree_unit_checks_clean():
    """Acceptance pin: zero findings, zero suppressions on the scoped
    modules, and the report covers the whole scope."""
    findings, n_inline, report = run_units()
    assert findings == [], "\n".join(f.render() for f in findings)
    assert n_inline == 0
    assert len(report["files"]) == len(UNIT_SCOPE)
    assert report["n_findings"] == 0


def test_unit_scope_files_exist():
    for scope in UNIT_SCOPE:
        assert (REPO_ROOT / "src" / scope).is_file(), scope
        assert unit_scoped(str(REPO_ROOT / "src" / scope))


def test_units_catches_seeded_engine_mutations():
    """End-to-end sensitivity: breaking real engine lines is flagged."""
    net = (REPO_ROOT / "src/repro/core/network.py").read_text()
    broken = net.replace("np.maximum(self.rem - self.rate * dt",
                         "np.maximum(self.rem - self.rate + dt")
    assert broken != net
    assert any(f.rule == "SL020"
               for f in lint_units(broken, "repro/core/network.py"))

    scen = (REPO_ROOT / "src/repro/core/scenarios.py").read_text()
    broken = scen.replace("lan_bandwidth=spec.lan_mbps * mbps",
                          "lan_bandwidth=spec.lan_mbps")
    assert broken != scen
    assert any(f.rule == "SL022"
               for f in lint_units(broken, "repro/core/scenarios.py"))


def test_unit_rules_registered_in_catalog():
    assert {"SL020", "SL021", "SL022", "SL023", "SL024", "SL025"} \
        <= set(RULES)


def test_list_rules_groups_by_family():
    from repro.analysis import RULE_FAMILIES
    grouped = [r for _, rules in RULE_FAMILIES for r in rules]
    assert sorted(grouped) == sorted(set(grouped))      # no dupes
    assert set(grouped) == set(RULES)                   # nothing dropped


# -- kernel unit signatures (jax-free) --------------------------------------

def test_all_kernel_specs_declare_unit_signatures():
    from repro.kernels import registered_kernels
    specs = registered_kernels()
    assert len(specs) == 7
    for name, spec in specs.items():
        args, _ = spec.make_inputs()
        assert check_unit_signature(spec, len(args)), name
        assert set(spec.arg_units) <= DIMENSIONS, name
        assert set(spec.out_units) <= DIMENSIONS, name


def test_sim_kernel_signatures_pinned():
    """The physical signatures of the DES kernels are load-bearing
    documentation — pin them."""
    from repro.kernels import get_kernel_spec
    net = get_kernel_spec("net_rerate")
    assert net.arg_units == ("count", "bytes", "bytes_per_s", "count",
                             "sim_seconds")
    assert net.out_units == ("bytes_per_s", "sim_seconds")
    st = get_kernel_spec("st_cost")
    assert st.out_units == ("sim_seconds",)
    ev = get_kernel_spec("event_engine")
    assert ev.out_units == ("bytes", "bytes_per_s", "sim_seconds",
                            "sim_seconds")


def test_check_unit_signature_rejects_incomplete():
    import dataclasses
    from repro.kernels import get_kernel_spec
    spec = get_kernel_spec("value_score")
    args, _ = spec.make_inputs()
    assert not check_unit_signature(
        dataclasses.replace(spec, arg_units=spec.arg_units[:-1]), len(args))
    assert not check_unit_signature(
        dataclasses.replace(spec, out_units=()), len(args))
    assert not check_unit_signature(
        dataclasses.replace(spec, out_units=("furlongs",)), len(args))
