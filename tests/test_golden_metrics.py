"""Golden-metrics regression for the event engine.

``golden_metrics.json`` pins every fig4/fig5 cell (paper Table-1 grid) as
produced by the pre-refactor engine. The rebuilt hot paths (NetworkEngine
slot arrays with per-link path contention, incremental re-rating,
deque/tombstone queues, bisect LRU) are required to be *bit-identical* —
any drift here means the refactor changed simulation semantics, not just
speed. The contract extends across network backends: two-level grids must
reproduce the same floats under ``net="numpy"`` and ``net="pallas"`` (the
vectorized op path on CPU; one cell also runs the Pallas interpreter under
``-m slow``), and ``golden_deep.json`` pins one deep-tree cell so the
mid-tier path-contention semantics are regression-locked too.

Tier-1 checks a 6-cell subset; the full 18-cell grid runs under ``-m slow``.
"""

import json
import os

import pytest

from repro.core import GridConfig, run_experiment
from repro.launch.experiments import run_spec

_HERE = os.path.dirname(__file__)
GOLDEN = json.load(open(os.path.join(_HERE, "golden_metrics.json")))["metrics"]
GOLDEN_DEEP = json.load(open(os.path.join(_HERE, "golden_deep.json")))

FAST_CELLS = ["fig4/hrs/100", "fig4/bhr/100", "fig4/lru/100",
              "fig4/hrs/300", "fig4/bhr/300", "fig4/lru/300"]


def _check(key: str, net: str = "numpy") -> None:
    _, strategy, n = key.split("/")
    n = int(n)
    cfg = GridConfig(n_jobs=n) if key.startswith("fig5") else GridConfig()
    r = run_experiment(cfg, strategy=strategy, n_jobs=n, net=net)
    g = GOLDEN[key]
    assert r.avg_job_time == g["avg_job_time"], key
    assert r.avg_inter_comms == g["avg_inter_comms"], key
    assert r.total_wan_gb == g["total_wan_gb"], key
    assert r.makespan == g["makespan"], key
    assert r.completed_jobs == n, key


@pytest.mark.parametrize("key", FAST_CELLS)
def test_golden_fig4_subset(key):
    _check(key)


@pytest.mark.parametrize("key", FAST_CELLS[:3])
def test_golden_pallas_backend(key):
    """Bit-identity under net='pallas' (the vectorized full re-rate path;
    routes through the kernel op wrapper)."""
    _check(key, net="pallas")


def test_golden_deep_tree_cell():
    """The deep-tree pin: deep_contended at 300 jobs under the per-link
    path model. Drift here means mid-tier contention semantics moved."""
    from repro.core import SCENARIOS
    g = GOLDEN_DEEP["metrics"]
    r = run_spec(SCENARIOS[GOLDEN_DEEP["scenario"]],
                 n_jobs=GOLDEN_DEEP["n_jobs"])
    assert r.avg_job_time == g["avg_job_time"]
    assert r.avg_inter_comms == g["avg_inter_comms"]
    assert r.total_wan_gb == g["total_wan_gb"]
    assert r.makespan == g["makespan"]
    assert r.completed_jobs == g["completed_jobs"]


@pytest.mark.slow
@pytest.mark.parametrize("key", sorted(set(GOLDEN) - set(FAST_CELLS)))
def test_golden_full_grid(key):
    _check(key)


@pytest.mark.slow
def test_golden_pallas_interpret_cell():
    """One cell through the actual Pallas interpreter (x64): the kernel —
    not just its numpy oracle — reproduces the golden floats."""
    _check("fig4/hrs/100", net="pallas-interpret")
