"""Golden-metrics regression for the event engine.

``golden_metrics.json`` pins every fig4/fig5 cell (paper Table-1 grid) as
produced by the pre-refactor engine. The rebuilt hot paths (vectorized
fair-share network, incremental re-rating, deque/tombstone queues, bisect
LRU) are required to be *bit-identical* — any drift here means the refactor
changed simulation semantics, not just speed.

Tier-1 checks a 6-cell subset; the full 18-cell grid runs under ``-m slow``.
"""

import json
import os

import pytest

from repro.core import GridConfig, run_experiment

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_metrics.json")))["metrics"]

FAST_CELLS = ["fig4/hrs/100", "fig4/bhr/100", "fig4/lru/100",
              "fig4/hrs/300", "fig4/bhr/300", "fig4/lru/300"]


def _check(key: str) -> None:
    _, strategy, n = key.split("/")
    n = int(n)
    cfg = GridConfig(n_jobs=n) if key.startswith("fig5") else GridConfig()
    r = run_experiment(cfg, strategy=strategy, n_jobs=n)
    g = GOLDEN[key]
    assert r.avg_job_time == g["avg_job_time"], key
    assert r.avg_inter_comms == g["avg_inter_comms"], key
    assert r.total_wan_gb == g["total_wan_gb"], key
    assert r.makespan == g["makespan"], key
    assert r.completed_jobs == n, key


@pytest.mark.parametrize("key", FAST_CELLS)
def test_golden_fig4_subset(key):
    _check(key)


@pytest.mark.slow
@pytest.mark.parametrize("key", sorted(set(GOLDEN) - set(FAST_CELLS)))
def test_golden_full_grid(key):
    _check(key)
