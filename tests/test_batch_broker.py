"""Batch-dispatch broker (``broker="jax"``): SUBMIT events arriving within
``batch_window`` of each other are placed by one jitted vectorized argmax
(`jaxsched.select_sites_batch`) over a shared catalog/load snapshot."""

import pytest

from repro.core import GridConfig, run_experiment


def test_batch_broker_completes_and_is_deterministic():
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    a = run_experiment(cfg, strategy="hrs", n_jobs=80,
                       broker="jax", arrival_burst=10)
    b = run_experiment(cfg, strategy="hrs", n_jobs=80,
                       broker="jax", arrival_burst=10)
    assert a.completed_jobs == a.n_jobs == 80
    assert a.avg_job_time == b.avg_job_time
    assert a.avg_inter_comms == b.avg_inter_comms


def test_batch_broker_singleton_batches_match_event_broker():
    """With one job per batch the jax broker falls back to the sequential
    python dispatch path, so results must equal the default broker's."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    ev = run_experiment(cfg, strategy="hrs", n_jobs=40, broker="event")
    jx = run_experiment(cfg, strategy="hrs", n_jobs=40, broker="jax")
    assert ev.avg_job_time == jx.avg_job_time
    assert ev.avg_inter_comms == jx.avg_inter_comms


def test_batch_window_holds_then_flushes():
    """batch_window > 0 delays dispatch (never schedules a job before its
    own arrival): every record's start is at/after its submit time and all
    jobs still complete."""
    from repro.core import (GridSimulator, build_catalog, build_topology,
                            generate_jobs)
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy="hrs", broker="jax",
                        batch_window=300.0)
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    for j, job in enumerate(generate_jobs(cfg, 30)):
        sim.submit_job(job, at=j * 60.0)
    res = sim.run()
    assert len(res.records) == 30
    for r in res.records:
        assert r.finish_time >= r.submit_time
        assert r.job_time > 0


def test_unknown_broker_rejected():
    with pytest.raises(ValueError):
        run_experiment(GridConfig(n_regions=2, sites_per_region=2),
                       n_jobs=1, broker="nope")


def _snapshot_world():
    from repro.core import build_catalog, build_topology
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    return cfg, topo, cat


def test_jax_leastloaded_matches_sequential_policy():
    """Site-for-site: over one shared snapshot the jitted argmin equals
    the sequential ``(relative_load, site_id)`` min, including load ties
    and offline-site exclusion."""
    from repro.core import generate_jobs
    from repro.core.jaxsched import JaxLeastLoadedBroker
    from repro.core.scheduler import LeastLoadedScheduler
    cfg, topo, cat = _snapshot_world()
    topo.sites[3].queued_work = 5e9
    topo.sites[1].queued_work = 1e9
    topo.sites[0].online = False         # lowest id must be skippable
    seq = LeastLoadedScheduler(cat, topo)
    broker = JaxLeastLoadedBroker(cat, topo)
    jobs = generate_jobs(cfg, 16)
    want = [seq.select_site(j) for j in jobs]        # no placements between
    got = broker.select_batch([j.required for j in jobs])
    assert got == want


def test_jax_random_matches_sequential_policy():
    """Site-for-site: the broker's host-PRNG index draw consumes the same
    ``_randbelow`` stream as ``Random.choice``, so an equally-seeded
    sequential RandomScheduler makes identical picks."""
    import random as _random

    from repro.core import generate_jobs
    from repro.core.jaxsched import JaxRandomBroker
    from repro.core.scheduler import RandomScheduler
    cfg, topo, cat = _snapshot_world()
    topo.sites[5].online = False
    seq = RandomScheduler(cat, topo, seed=5)
    broker = JaxRandomBroker(cat, topo, _random.Random(5))
    jobs = generate_jobs(cfg, 32)
    want = [seq.select_site(j) for j in jobs]
    got = broker.select_batch([j.required for j in jobs])
    assert got == want
    assert all(topo.sites[s].online for s in got)


@pytest.mark.parametrize("scheduler", ["leastloaded", "random"])
def test_jax_broker_full_run_matches_event_broker(scheduler):
    """End-to-end: for these policies the batched dispatch consumes state
    exactly as the sequential one does (leastloaded: bursts land on the
    shared-snapshot argmin; random: one shared-PRNG draw per job), so a
    singleton-batch run must equal the event broker bit-for-bit."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    ev = run_experiment(cfg, scheduler=scheduler, strategy="hrs", n_jobs=40,
                        broker="event")
    jx = run_experiment(cfg, scheduler=scheduler, strategy="hrs", n_jobs=40,
                        broker="jax")
    assert ev.avg_job_time == jx.avg_job_time
    assert ev.avg_inter_comms == jx.avg_inter_comms
    assert jx.completed_jobs == 40


def test_jax_broker_burst_runs_complete():
    for scheduler in ("leastloaded", "random"):
        r = run_experiment(GridConfig(n_regions=2, sites_per_region=4),
                           scheduler=scheduler, strategy="hrs", n_jobs=60,
                           broker="jax", arrival_burst=10)
        assert r.completed_jobs == 60


@pytest.mark.slow
def test_batch_broker_2k_job_smoke():
    """2k jobs in bursts of 50 through the jitted batch dispatcher."""
    r = run_experiment(GridConfig(), strategy="hrs", n_jobs=2000,
                       broker="jax", arrival_burst=50)
    assert r.completed_jobs == r.n_jobs == 2000
    assert r.avg_job_time > 0
    assert r.makespan > 0
