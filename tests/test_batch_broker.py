"""Batch-dispatch broker (``broker="jax"``): SUBMIT events arriving within
``batch_window`` of each other are placed by one jitted vectorized argmax
(`jaxsched.select_sites_batch`) over a shared catalog/load snapshot."""

import pytest

from repro.core import GridConfig, run_experiment


def test_batch_broker_completes_and_is_deterministic():
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    a = run_experiment(cfg, strategy="hrs", n_jobs=80,
                       broker="jax", arrival_burst=10)
    b = run_experiment(cfg, strategy="hrs", n_jobs=80,
                       broker="jax", arrival_burst=10)
    assert a.completed_jobs == a.n_jobs == 80
    assert a.avg_job_time == b.avg_job_time
    assert a.avg_inter_comms == b.avg_inter_comms


def test_batch_broker_singleton_batches_match_event_broker():
    """With one job per batch the jax broker falls back to the sequential
    python dispatch path, so results must equal the default broker's."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    ev = run_experiment(cfg, strategy="hrs", n_jobs=40, broker="event")
    jx = run_experiment(cfg, strategy="hrs", n_jobs=40, broker="jax")
    assert ev.avg_job_time == jx.avg_job_time
    assert ev.avg_inter_comms == jx.avg_inter_comms


def test_batch_window_holds_then_flushes():
    """batch_window > 0 delays dispatch (never schedules a job before its
    own arrival): every record's start is at/after its submit time and all
    jobs still complete."""
    from repro.core import (GridSimulator, build_catalog, build_topology,
                            generate_jobs)
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy="hrs", broker="jax",
                        batch_window=300.0)
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    for j, job in enumerate(generate_jobs(cfg, 30)):
        sim.submit_job(job, at=j * 60.0)
    res = sim.run()
    assert len(res.records) == 30
    for r in res.records:
        assert r.finish_time >= r.submit_time
        assert r.job_time > 0


def test_unknown_broker_rejected():
    with pytest.raises(ValueError):
        run_experiment(GridConfig(n_regions=2, sites_per_region=2),
                       n_jobs=1, broker="nope")


def _snapshot_world():
    from repro.core import build_catalog, build_topology
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    return cfg, topo, cat


def test_jax_leastloaded_matches_sequential_policy():
    """Site-for-site: over one shared snapshot the jitted argmin equals
    the sequential ``(relative_load, site_id)`` min, including load ties
    and offline-site exclusion."""
    from repro.core import generate_jobs
    from repro.core.jaxsched import JaxLeastLoadedBroker
    from repro.core.scheduler import LeastLoadedScheduler
    cfg, topo, cat = _snapshot_world()
    topo.sites[3].queued_work = 5e9
    topo.sites[1].queued_work = 1e9
    topo.sites[0].online = False         # lowest id must be skippable
    seq = LeastLoadedScheduler(cat, topo)
    broker = JaxLeastLoadedBroker(cat, topo)
    jobs = generate_jobs(cfg, 16)
    want = [seq.select_site(j) for j in jobs]        # no placements between
    got = broker.select_batch([j.required for j in jobs])
    assert got == want


def test_jax_random_matches_sequential_policy():
    """Site-for-site: the broker's host-PRNG index draw consumes the same
    ``_randbelow`` stream as ``Random.choice``, so an equally-seeded
    sequential RandomScheduler makes identical picks."""
    import random as _random

    from repro.core import generate_jobs
    from repro.core.jaxsched import JaxRandomBroker
    from repro.core.scheduler import RandomScheduler
    cfg, topo, cat = _snapshot_world()
    topo.sites[5].online = False
    seq = RandomScheduler(cat, topo, seed=5)
    broker = JaxRandomBroker(cat, topo, _random.Random(5))
    jobs = generate_jobs(cfg, 32)
    want = [seq.select_site(j) for j in jobs]
    got = broker.select_batch([j.required for j in jobs])
    assert got == want
    assert all(topo.sites[s].online for s in got)


@pytest.mark.parametrize("scheduler", ["leastloaded", "random"])
def test_jax_broker_full_run_matches_event_broker(scheduler):
    """End-to-end: for these policies the batched dispatch consumes state
    exactly as the sequential one does (leastloaded: bursts land on the
    shared-snapshot argmin; random: one shared-PRNG draw per job), so a
    singleton-batch run must equal the event broker bit-for-bit."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    ev = run_experiment(cfg, scheduler=scheduler, strategy="hrs", n_jobs=40,
                        broker="event")
    jx = run_experiment(cfg, scheduler=scheduler, strategy="hrs", n_jobs=40,
                        broker="jax")
    assert ev.avg_job_time == jx.avg_job_time
    assert ev.avg_inter_comms == jx.avg_inter_comms
    assert jx.completed_jobs == 40


def test_jax_broker_burst_runs_complete():
    for scheduler in ("leastloaded", "random"):
        r = run_experiment(GridConfig(n_regions=2, sites_per_region=4),
                           scheduler=scheduler, strategy="hrs", n_jobs=60,
                           broker="jax", arrival_burst=10)
        assert r.completed_jobs == 60


def test_jax_random_churn_to_zero_matches_sequential():
    """With every site offline the sequential policy's ``Random.choice``
    raises IndexError *without* consuming a PRNG draw; the broker must do
    exactly the same, so a shared stream stays aligned across a caught
    churn-to-zero window and picks coincide site-for-site afterwards."""
    import random as _random

    from repro.core import generate_jobs
    from repro.core.jaxsched import JaxRandomBroker
    from repro.core.scheduler import RandomScheduler
    cfg, topo, cat = _snapshot_world()
    seq = RandomScheduler(cat, topo, seed=11)
    broker = JaxRandomBroker(cat, topo, seq.rng)   # one shared stream
    jobs = generate_jobs(cfg, 8)
    for s in topo.sites:
        s.online = False
    state = seq.rng.getstate()
    with pytest.raises(IndexError):
        seq.select_site(jobs[0])
    with pytest.raises(IndexError):
        broker.select_batch([j.required for j in jobs])
    assert seq.rng.getstate() == state            # no draw consumed
    for s in topo.sites[:3]:
        s.online = True                            # partial recovery
    twin = RandomScheduler(cat, topo, seed=11)     # fresh aligned stream
    want = [twin.select_site(j) for j in jobs]
    assert broker.select_batch([j.required for j in jobs]) == want


def test_jax_brokers_all_offline_raise_like_sequential():
    """Batch dispatch against an all-offline snapshot must not silently
    land on site 0 (the old argmin-over-inf bug): every deterministic jax
    broker raises the same ValueError its sequential policy does."""
    from repro.core import GridSimulator, build_catalog, build_topology
    from repro.core.scheduler import Job, make_scheduler
    cfg = GridConfig(n_regions=2, sites_per_region=3)
    for scheduler in ("dataaware", "leastloaded", "shortesttransfer"):
        topo = build_topology(cfg)
        cat = build_catalog(cfg, topo)
        sim = GridSimulator(topo, cat, scheduler=scheduler, strategy="hrs",
                            broker="jax")
        for s in topo.sites:
            s.online = False
        job_files = [["lfn0000", "lfn0001"]] * 4
        with pytest.raises(ValueError):
            make_scheduler(scheduler, cat, topo).select_site(
                Job(0, 0, job_files[0], 1.0))
        with pytest.raises(ValueError):
            sim._jax_broker.select_batch(job_files)


def test_late_registered_files_visible_to_batch_dispatch():
    """Regression (stale-snapshot bug): lfns/sizes/presence were frozen at
    broker construction, so files registered afterwards were invisible to
    batch dispatch. The lazy re-sync must pick them up."""
    from repro.core.jaxsched import JaxScheduler
    _, topo, cat = _snapshot_world()
    broker = JaxScheduler(cat, topo)
    cat.register_file("zzz-new", 7e9, master_site=6)
    # the new file's only copy is at site 6, which must now win the
    # dataaware argmax for a job that requires nothing else
    assert broker.select_batch([["zzz-new"]] * 3 + [["lfn0000"]])[:3] == [6] * 3
    assert broker._sizes_np[broker.lfn_index["zzz-new"]] == 7e9


def test_catalog_listeners_are_weak():
    """A broker that goes out of scope is collected, not notified forever:
    the catalog holds listeners by weak reference only."""
    import gc

    from repro.core.jaxsched import JaxScheduler
    _, topo, cat = _snapshot_world()
    broker = JaxScheduler(cat, topo)
    broker.presence_np()
    ref = cat._listeners[-1]
    del broker
    gc.collect()
    assert ref() is None
    cat.add_replica("lfn0000", 3)       # dead listener must not blow up
    keeper = JaxScheduler(cat, topo)    # registering prunes dead refs
    assert all(r() is not None for r in cat._listeners)
    assert cat._listeners[-1]() is keeper


def test_presence_bitmap_tracks_catalog_incrementally():
    """The listener-maintained bitmap equals a fresh catalog scan after a
    full simulated run of replications, evictions and churn-driven
    replica losses (site_churn at small scale)."""
    import numpy as np

    from repro.core import GridSimulator, build_catalog, build_topology, \
        generate_jobs
    cfg = GridConfig(n_regions=2, sites_per_region=4,
                     storage_capacity=3e9)           # force evictions
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy="hrs", broker="jax")
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    for j, job in enumerate(generate_jobs(cfg, 60)):
        sim.submit_job(job, at=(j // 5) * 60.0)
    sim.inject_failure(3, 500.0, 2000.0)
    sim.run()
    broker = sim._jax_broker
    got = broker.presence_np()
    want = np.zeros_like(got)
    for j, lfn in enumerate(broker.lfns):
        for h in cat.holders(lfn):
            want[h, j] = True
    assert np.array_equal(got, want)


@pytest.mark.slow
def test_batch_broker_2k_job_smoke():
    """2k jobs in bursts of 50 through the jitted batch dispatcher."""
    r = run_experiment(GridConfig(), strategy="hrs", n_jobs=2000,
                       broker="jax", arrival_burst=50)
    assert r.completed_jobs == r.n_jobs == 2000
    assert r.avg_job_time > 0
    assert r.makespan > 0
