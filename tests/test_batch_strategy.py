"""Batched replica-strategy engine (``strategy_mode="batch"``): one
``plan_batch`` pass per arrival burst through the ``strategy_plan`` kernel
must produce the same FetchPlans the sequential strategies build one
``plan_fetch`` at a time."""

import copy
import dataclasses
import gc

import numpy as np
import pytest

from repro.core import (AccessHistory, GridConfig, GridSimulator,
                        GridTopology, NetworkEngine, ReplicaCatalog,
                        ScenarioSpec, StorageState, StorageTensorView,
                        STRATEGIES, build_catalog, build_topology,
                        generate_jobs, get_scenario, make_strategy,
                        run_experiment)
from repro.launch.experiments import run_spec

GB = 1e9
ALL_STRATEGIES = sorted(STRATEGIES)


def _random_world(rng):
    """A small grid with random replicas, pins, offline sites and decayed
    access history — every state axis the planners read."""
    topo = GridTopology(int(rng.integers(2, 4)), int(rng.integers(2, 5)),
                        lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=4 * GB, seed=int(rng.integers(100)))
    cat = ReplicaCatalog()
    stor = StorageState(cat, topo)
    n_files = int(rng.integers(4, 11))
    for i in range(n_files):
        m = int(rng.integers(topo.n_sites))
        cat.register_file(f"f{i}", float(rng.uniform(0.3, 1.2)) * GB, m)
        stor.bootstrap(m, f"f{i}")
    now = 1.0
    for _ in range(2 * topo.n_sites):           # scatter extra replicas
        lfn = f"f{int(rng.integers(n_files))}"
        s = int(rng.integers(topo.n_sites))
        if not stor.holds(s, lfn) and \
                topo.sites[s].free_storage >= cat.size(lfn):
            stor.add(s, lfn, now)
            now += 1.0
    for _ in range(3):                          # in-use (pinned) files
        s = int(rng.integers(topo.n_sites))
        contents = stor.site_contents(s)
        if contents:
            stor.pin(s, contents[int(rng.integers(len(contents)))])
    for s in topo.sites[1:]:                    # churn (site 0 stays up)
        if rng.random() < 0.15:
            s.online = False
    access = AccessHistory(cat, topo)
    for _ in range(30):                         # decayed popularity + loads
        now += float(rng.uniform(0.0, 400.0))
        lfn = f"f{int(rng.integers(n_files))}"
        site = int(rng.integers(topo.n_sites))
        access.record_access(site, lfn, now)
        src = int(rng.integers(topo.n_sites))
        access.record_fetch(src, site, lfn, cat.size(lfn),
                            bool(rng.integers(2)), now)
    return topo, cat, stor, access


def _as_tuple(plan):
    return (plan.lfn, plan.src, plan.dst, plan.store, plan.evictions,
            plan.inter_region, plan.remote_access)


def _probe_plans_match(seed):
    """On one random world: every strategy's ``plan_batch`` equals the
    sequential twin's ``plan_fetch``, plan for plan — source pick, store
    verdict, eviction list, inter-region flag."""
    rng = np.random.default_rng(seed)
    topo, cat, stor, access = _random_world(rng)
    net = NetworkEngine(topo)
    pairs = [(lfn, d) for lfn in sorted(cat.files)
             for d in range(topo.n_sites)
             if topo.sites[d].online and not stor.holds(d, lfn)]
    for name in ALL_STRATEGIES:
        seq = make_strategy(name, cat, topo, stor, access)
        bat = make_strategy(name, cat, topo, stor, access,
                            mode="batch", network=net)
        got = bat.plan_batch(pairs)
        for pair, plan in zip(pairs, got):
            want = seq.plan_fetch(*pair)
            assert _as_tuple(plan) == _as_tuple(want), (name, pair)


@pytest.mark.parametrize("seed", range(12))
def test_batched_plans_match_sequential_seeded(seed):
    """Fixed-seed slice of the property probe — runs everywhere, with or
    without hypothesis."""
    _probe_plans_match(seed)


def test_batched_plans_match_sequential_property():
    """Hypothesis-driven probe over arbitrary world seeds."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(seed=st.integers(0, 2**32 - 1))
    def probe(seed):
        _probe_plans_match(seed)

    probe()


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_batched_run_matches_sequential(strategy):
    """End-to-end: singleton bursts take the sequential path bit-for-bit,
    so a whole run must produce identical metrics under either mode."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    seq = run_experiment(cfg, strategy=strategy, n_jobs=120)
    bat = run_experiment(cfg, strategy=strategy, n_jobs=120,
                         strategy_mode="batch")
    assert bat.completed_jobs == seq.completed_jobs == 120
    assert bat.avg_job_time == seq.avg_job_time
    assert bat.avg_inter_comms == seq.avg_inter_comms
    assert bat.total_wan_gb == seq.total_wan_gb
    assert bat.makespan == seq.makespan


def test_batched_burst_completes_and_is_deterministic():
    """Multi-job bursts share one planning snapshot (the jax-broker
    tolerance convention) — results stay deterministic and every job
    completes through the revalidate-or-replan guard."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    a = run_experiment(cfg, strategy="hrs", n_jobs=100, broker="jax",
                       arrival_burst=10, strategy_mode="batch")
    b = run_experiment(cfg, strategy="hrs", n_jobs=100, broker="jax",
                       arrival_burst=10, strategy_mode="batch")
    assert a.completed_jobs == a.n_jobs == 100
    assert a.avg_job_time == b.avg_job_time
    assert a.avg_inter_comms == b.avg_inter_comms


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_paper_baseline_batched_smoke(strategy):
    """Every registered strategy runs the paper_baseline scenario in batch
    mode through the config-driven launch path."""
    spec = dataclasses.replace(get_scenario("paper_baseline"),
                               strategy=strategy, strategy_mode="batch")
    r = run_spec(spec, n_jobs=50)
    assert r.completed_jobs == 50


def test_view_tracks_storage_through_churn():
    """The listener-maintained StorageTensorView equals a fresh rebuild
    after a full batched run with evictions and churn-driven losses."""
    cfg = GridConfig(n_regions=2, sites_per_region=4,
                     storage_capacity=3e9)           # force evictions
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy="hrs", strategy_mode="batch",
                        broker="jax")
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    for j, job in enumerate(generate_jobs(cfg, 60)):
        sim.submit_job(job, at=(j // 5) * 60.0)
    sim.inject_failure(3, 500.0, 2000.0)
    sim.run()
    view = sim.strategy.view
    view.sync()
    fresh = StorageTensorView(cat, topo, sim.storage)
    for attr in ("cat_present", "region_counts", "st_present", "st_atime",
                 "st_seq", "st_pins", "sizes", "masters"):
        assert np.array_equal(getattr(view, attr), getattr(fresh, attr)), attr


def test_storage_listeners_are_weak():
    """A view that goes out of scope is collected, not notified forever:
    StorageState holds listeners by weak reference only (and a deepcopy —
    the sanitizer's twin path — drops them entirely)."""
    rng = np.random.default_rng(7)
    topo, cat, stor, _ = _random_world(rng)
    view = StorageTensorView(cat, topo, stor)
    ref = stor._listeners[-1]
    del view
    gc.collect()
    assert ref() is None
    lfn = stor.site_contents(0)[0] if stor.site_contents(0) else None
    if lfn is not None:
        stor.touch(0, lfn, 9999.0)      # dead listener must not blow up
    keeper = StorageTensorView(cat, topo, stor)
    assert stor._listeners[-1]() is keeper
    assert copy.deepcopy(stor)._listeners == []


def test_batch_mode_rejects_strategy_instance():
    cfg = GridConfig(n_regions=2, sites_per_region=2)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    stor = StorageState(cat, topo)
    inst = make_strategy("hrs", cat, topo, stor)
    with pytest.raises(ValueError, match="registry name"):
        GridSimulator(topo, cat, strategy=inst, strategy_mode="batch")


def test_sanitize_incompatible_with_batch_mode():
    cfg = GridConfig(n_regions=2, sites_per_region=2)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    with pytest.raises(ValueError, match="sanitize"):
        GridSimulator(topo, cat, strategy="hrs", strategy_mode="batch",
                      sanitize=True)


def test_batch_strategy_requires_network():
    cfg = GridConfig(n_regions=2, sites_per_region=2)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    stor = StorageState(cat, topo)
    with pytest.raises(ValueError, match="network"):
        make_strategy("hrs", cat, topo, stor, mode="batch")
    with pytest.raises(ValueError, match="strategy_mode"):
        make_strategy("hrs", cat, topo, stor, mode="bogus")
    with pytest.raises(ValueError, match="strategy_mode"):
        dataclasses.replace(get_scenario("paper_baseline"),
                            strategy_mode="bogus")
