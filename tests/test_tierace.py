"""Tie-race sanitizer + determinism-under-reordering tests.

Three layers, matching :mod:`repro.analysis.tierace`:

* static — every heappush in the engine carries the ``(time, seq)`` key;
* dynamic — the engine's ``sanitize=True`` twin-replay mode detects
  same-timestamp groups whose handler order changes observable state
  (and stays silent when the group commutes);
* property — shuffling same-timestamp *insertion* order leaves the
  batched (jax-broker) experiment results bit-identical when the batch
  decision is snapshot-pure and placements are disjoint.
"""

import random
from pathlib import Path

import pytest

from repro.analysis.tierace import (canonical_records, sanitize_smoke,
                                    static_tie_key_findings)
from repro.core.scheduler import Job
from repro.core.simulator import GridSimulator
from repro.core.workload import GridConfig, build_catalog, build_topology

SRC_CORE = Path(__file__).resolve().parents[1] / "src" / "repro" / "core"


def make_sim(*, scheduler="dataaware", strategy="hrs", sanitize=False,
             broker="event", net="numpy", seed=0):
    cfg = GridConfig(seed=seed)
    topology = build_topology(cfg)
    catalog = build_catalog(cfg, topology)
    sim = GridSimulator(topology, catalog, scheduler=scheduler,
                        strategy=strategy, seed=seed, sanitize=sanitize,
                        broker=broker, net=net)
    for info in catalog.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    return cfg, sim


def pinned_jobs(n):
    """Jobs whose single required file is mastered at n distinct sites
    (build_catalog pins lfn i at site (i*7) % n_sites): dataaware
    placement is a unique argmax, independent of decision order. The
    stride-4 file indices land on same-capacity (1 GFLOP/s) sites, so
    equal-length jobs also *finish* at one shared instant."""
    return [Job(job_id=j, job_type=0, required=[f"lfn{4 * j:04d}"],
                length=60e9) for j in range(n)]


# -- static: every event insertion carries the (time, seq) key --------------

def test_engine_heappushes_carry_seq_key():
    findings = static_tie_key_findings(sorted(SRC_CORE.glob("*.py")))
    assert findings == [], "\n".join(f.render() for f in findings)


# -- dynamic: sanitize mode flags racy ties, passes commuting ones ----------

def test_sequential_scheduler_submit_ties_race():
    """leastloaded reads mutable queued-work between same-instant
    placements — reordering a burst must be detected as a race."""
    rep = sanitize_smoke(n_jobs=16, scheduler="leastloaded")
    assert rep["ties_seen"] > 0
    assert rep["tie_races"], "expected order-dependent SUBMIT burst"
    assert any("SUBMIT" in r["kinds"] for r in rep["tie_races"])


def test_disjoint_placements_commute():
    """Same-instant SUBMITs whose data pins distinct sites commute: the
    twin replay finds ties but no observable divergence. The equal-length
    jobs also finish at one shared instant across distinct sites,
    exercising the CPU_DONE tie group."""
    _, sim = make_sim(sanitize=True)
    for job in pinned_jobs(4):
        sim.submit_job(job, at=0.0)
    sim.run()
    assert sim.ties_seen >= 2    # the SUBMIT burst + the CPU_DONE group
    assert sim.tie_races == [], sim.tie_races[:1]


def test_batched_drain_ties_commute_on_device_engine():
    """Twin-replay over a same-instant burst on the batched ``device``
    engine: the whole burst resolves through one fused flush whose
    per-slot math is permutation-invariant (the dirty-neighborhood
    gather/scatter and the eta min commute), so reordering the burst must
    find ties but no observable divergence — even though the engine never
    re-rates between the reordered handlers.

    Unlike :func:`pinned_jobs`, each job here needs a *second* file
    mastered in another region, so every placement starts a WAN fetch
    (single-file jobs run where their data lives and the network never
    engages) — and jobs 0 and 2 pull across the same pair of region
    uplinks, so the burst's transfers genuinely share links inside one
    fused flush."""
    _, sim = make_sim(sanitize=True, net="device")
    for j in range(4):
        sim.submit_job(Job(job_id=j, job_type=0,
                           required=[f"lfn{4 * j:04d}", f"lfn{4 * j + 2:04d}"],
                           length=60e9), at=0.0)
    sim.run()
    assert sim.network.batched
    assert sim.network.stats["flush_passes"] > 0
    assert sim.network.stats["rerate_slots"] == 0   # all work was fused
    assert sim.ties_seen >= 2    # the SUBMIT burst + the CPU_DONE group
    assert sim.tie_races == [], sim.tie_races[:1]


def test_sanitize_mode_is_observation_only():
    """sanitize=True must not perturb the primary timeline: records are
    identical to a plain run of the same scenario."""
    from repro.core.workload import generate_jobs

    results = []
    for sanitize in (False, True):
        cfg, sim = make_sim(sanitize=sanitize)
        for j, job in enumerate(generate_jobs(cfg, 16)):
            sim.submit_job(job, at=(j // 8) * cfg.interarrival * 8)
        results.append(sim.run())
    assert canonical_records(results[0]) == canonical_records(results[1])
    # stronger: even the record *order* matches
    assert results[0].records == results[1].records


def test_sanitize_requires_event_broker():
    with pytest.raises(ValueError, match="sanitize"):
        make_sim(sanitize=True, broker="jax")


def test_smoke_report_shape():
    rep = sanitize_smoke(n_jobs=8)
    assert set(rep) == {"ties_seen", "tie_races"}
    for race in rep["tie_races"]:
        assert set(race) == {"time", "kinds", "detail"}


# -- property: determinism under shuffled same-timestamp insertion ----------

@pytest.mark.parametrize("shuffle_seed", [1, 2, 3])
def test_batched_dispatch_invariant_to_insertion_order(shuffle_seed):
    """jax-broker batch decisions are snapshot-pure per job, so a burst
    submitted in any order must produce bit-identical results when the
    placements are disjoint."""
    pytest.importorskip("jax")

    def run(order):
        _, sim = make_sim(broker="jax")
        for j in order:
            sim.submit_job(j, at=0.0)
        return canonical_records(sim.run())

    jobs = pinned_jobs(8)
    baseline = run(jobs)
    shuffled = jobs[:]
    random.Random(shuffle_seed).shuffle(shuffled)
    assert run(shuffled) == baseline
