"""Per-arch smoke tests: REDUCED config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement). The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, arch_ids, cells, get_config
from repro.models import model as M
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.enc_dec:
        return {
            "frames": jnp.ones((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": tokens[:, : S // 8],
            "labels": tokens[:, : S // 8],
        }
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.ones((B, cfg.vision_tokens, cfg.d_model),
                                          jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_enc=S, max_dec=S // 8)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = M.train_logits(cfg, params, batch)
    exp_s = S // 8 if cfg.enc_dec else S
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", arch_ids())
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    tcfg = TrainConfig(n_microbatches=2,
                       opt=OptimizerConfig(warmup_steps=1, total_steps=10))
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_enc=S, max_dec=S // 8)
    from repro.train.optimizer import init_opt_state
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, params2))
    assert any(moved)


@pytest.mark.parametrize("arch", arch_ids())
def test_exact_published_config_fields(arch):
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    # layer pattern covers all layers
    assert cfg.n_groups * len(cfg.layer_pattern) \
        + len(cfg.remainder_pattern) == cfg.n_layers


def test_moe_configs():
    g = get_config("granite-moe-1b-a400m")
    assert g.moe.num_experts == 32 and g.moe.top_k == 8
    a = get_config("arctic-480b")
    assert a.moe.num_experts == 128 and a.moe.top_k == 2 and a.moe.dense_residual


def test_long_500k_only_subquadratic():
    for arch in arch_ids():
        has_long = "long_500k" in cells(arch)
        assert has_long == (arch in ("zamba2-7b", "falcon-mamba-7b"))


def test_param_counts_order_of_magnitude():
    from repro.models.model import count_params_analytic
    approx = {
        "qwen2-72b": 72e9, "gemma2-27b": 27e9, "granite-3-8b": 8e9,
        "falcon-mamba-7b": 7e9, "zamba2-7b": 7e9, "arctic-480b": 480e9,
        "gemma3-1b": 1e9, "granite-moe-1b-a400m": 1.3e9,
        "internvl2-2b": 1.9e9, "whisper-large-v3": 1.5e9,
    }
    for arch, target in approx.items():
        n = count_params_analytic(get_config(arch))
        assert 0.5 * target < n < 1.8 * target, (arch, n, target)
