"""Optimizer + train-step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.train.optimizer import (OptimizerConfig, adamw_update, cosine_lr,
                                   init_compress_state, init_opt_state,
                                   quantize_int8)
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def test_adamw_matches_manual_scalar():
    cfg = OptimizerConfig(peak_lr=0.1, min_lr=0.1, warmup_steps=0,
                          total_steps=10, weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([1.0], jnp.float32)}
    state = init_opt_state(params)
    g = {"w": jnp.asarray([0.5], jnp.float32)}
    new_params, state, _, stats = adamw_update(cfg, g, params, state)
    # manual: m=0.05, v=0.0125*... b1=0.9,b2=0.95
    m = 0.1 * 0.5
    v = 0.05 * 0.25
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    expect = 1.0 - 0.1 * mh / (np.sqrt(vh) + cfg.eps)
    np.testing.assert_allclose(np.asarray(new_params["w"]), [expect],
                               rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                          total_steps=100)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[-1] <= lrs[1]
    assert abs(lrs[-1] - 1e-4) < 1e-5         # decays to min


def test_grad_clip_applied():
    cfg = OptimizerConfig(grad_clip=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = init_opt_state(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, _, stats = adamw_update(cfg, g, params, state)
    assert float(stats["grad_norm"]) == 200.0


def test_int8_compression_error_feedback_unbiased():
    """Sum of dequantized updates converges to the true sum (error feedback
    carries the residual)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        deq, err = quantize_int8(g, err)
        total = total + deq
    np.testing.assert_allclose(np.asarray(total) / 50, np.asarray(g),
                               atol=float(jnp.max(jnp.abs(g))) / 100)


def test_loss_decreases_overfit():
    cfg = get_config("granite-3-8b").reduced()
    tcfg = TrainConfig(n_microbatches=2,
                       opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=5,
                                           total_steps=100))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    first = last = None
    for _ in range(25):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < 0.75 * first


def test_microbatching_matches_full_batch_grads():
    """n_micro=2 gradient == n_micro=1 gradient (linearity)."""
    from repro.train.train_step import grad_fn
    cfg = get_config("gemma3-1b").reduced()
    params, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    t1 = TrainConfig(n_microbatches=1)
    t2 = TrainConfig(n_microbatches=2)
    _, _, g1 = jax.jit(lambda p, b: grad_fn(cfg, t1, p, b))(params, batch)
    _, _, g2 = jax.jit(lambda p, b: grad_fn(cfg, t2, p, b))(params, batch)
    flat1 = jax.tree.leaves(g1)
    flat2 = jax.tree.leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-3, rtol=3e-2)
