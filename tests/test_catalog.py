import pytest

from repro.core import GridTopology, ReplicaCatalog


def make_topo():
    return GridTopology(2, 3, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=10e9)


def test_register_and_query():
    cat = ReplicaCatalog()
    cat.register_file("f1", 500e6, master_site=0)
    assert cat.holders("f1") == {0}
    assert cat.size("f1") == 500e6
    assert cat.is_master("f1", 0)
    cat.add_replica("f1", 3)
    assert cat.holders("f1") == {0, 3}
    assert cat.n_copies("f1") == 2


def test_duplicate_registration_rejected():
    cat = ReplicaCatalog()
    cat.register_file("f1", 1.0, 0)
    with pytest.raises(ValueError):
        cat.register_file("f1", 2.0, 1)


def test_master_copy_protected():
    cat = ReplicaCatalog()
    cat.register_file("f1", 1.0, 0)
    cat.add_replica("f1", 1)
    with pytest.raises(ValueError):
        cat.remove_replica("f1", 0)
    cat.remove_replica("f1", 1)
    assert cat.holders("f1") == {0}


def test_bytes_at_site_eq1():
    """Paper eq. (1): S_s sums only the required files present at s."""
    cat = ReplicaCatalog()
    for i, size in enumerate([100.0, 200.0, 400.0]):
        cat.register_file(f"f{i}", size, master_site=0)
    cat.add_replica("f1", 2)
    assert cat.bytes_at_site(["f0", "f1", "f2"], 0) == 700.0
    assert cat.bytes_at_site(["f1"], 2) == 200.0
    assert cat.bytes_at_site(["f0", "f2"], 2) == 0.0


def test_duplicated_in_region():
    topo = make_topo()
    cat = ReplicaCatalog()
    cat.register_file("f1", 1.0, 0)       # region 0
    cat.add_replica("f1", 4)              # region 1
    assert not cat.duplicated_in_region("f1", 4, topo)   # only holder there
    cat.add_replica("f1", 5)              # region 1, second copy
    assert cat.duplicated_in_region("f1", 4, topo)
