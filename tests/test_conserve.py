"""Runtime conservation auditor tests (repro.analysis.conserve).

Ledger closure on the paper baseline and the OptorSim-scale grid, on
both the numpy and the batched on-device (interpret) network engines,
plus the economy regime where the prefetch ledger is live. These are
the dynamic counterparts of the static SL011/SL013 coherence rules:
the books must balance after a real run, not just mutate through the
right APIs.
"""

import json

import pytest

from repro.analysis.conserve import (REL_TOL, _close, conservation_audit,
                                     run_conservation_smoke)

CORE_INVARIANTS = {"I1_byte_ledger", "I3_site_occupancy",
                   "I4_aggregate_replicas", "I5_drained",
                   "I6_prefetch_ledger"}


def assert_all_ok(report):
    bad = {n: c for n, c in report["checks"].items() if not c["ok"]}
    assert report["ok"] and not bad, bad


def test_paper_baseline_ledgers_close_numpy():
    report = conservation_audit("paper_baseline", n_jobs=40, net="numpy")
    assert_all_ok(report)
    assert CORE_INVARIANTS <= set(report["checks"])
    # failure-free run: the strict counters are checked too
    assert report["failure_free"]
    assert "I2_inter_comms" in report["checks"]
    assert "I7_completion" in report["checks"]
    # the run moved real bytes — the closure is not vacuous
    assert report["checks"]["I1_byte_ledger"]["lhs"] > 0


def test_paper_baseline_ledgers_close_device_engine():
    pytest.importorskip("jax")
    report = conservation_audit("paper_baseline", n_jobs=40,
                                net="device-interpret")
    assert_all_ok(report)
    assert report["checks"]["I1_byte_ledger"]["lhs"] > 0


def test_grid_500_smoke_ledgers_close():
    pytest.importorskip("jax")          # grid_500 dispatches broker="jax"
    report = conservation_audit("grid_500", n_jobs=40, net="numpy")
    assert_all_ok(report)
    assert report["n_jobs"] == 40


def test_economy_prefetch_ledger_closes_and_is_live():
    report = conservation_audit("economy_starved", n_jobs=60, net="numpy")
    assert_all_ok(report)
    debits, counted, started = report["checks"]["I6_prefetch_ledger"]["lhs"]
    proposed = report["checks"]["I6_prefetch_ledger"]["rhs"]
    assert debits == counted == started > 0      # ledger actually exercised
    assert started <= proposed


def test_smoke_runner_covers_baseline_and_economy():
    reports = run_conservation_smoke(n_jobs=40)
    scenarios = [r["scenario"] for r in reports]
    assert scenarios == ["paper_baseline", "economy_starved"]
    for report in reports:
        assert_all_ok(report)
        json.dumps(report)                       # CI artifact: JSON-ready


def test_close_tolerance_is_tight():
    assert _close(353_500_000_000.0, 353_500_000_000.0)
    assert not _close(353_500_000_000.0, 353_500_000_001.0 * (1 + 1e-6))
    assert not _close(1.0, 2.0)
    assert REL_TOL <= 1e-9
