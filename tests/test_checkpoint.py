"""Checkpoint: roundtrip, elastic re-shard, HRS restore sources."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (choose_restore_sources, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.core import GridTopology


def tree_eq(a, b):
    return all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))


def make_state(key):
    ks = jax.random.split(key, 4)
    return {
        "params": {
            "embed": jax.random.normal(ks[0], (64, 16)).astype(jnp.bfloat16),
            "layers": {"w": jax.random.normal(ks[1], (4, 16, 32))},
        },
        "opt": {
            "m": jax.random.normal(ks[2], (4, 16, 32)),
            "step": jnp.int32(7),
        },
        "rest": [],
    }


def test_roundtrip_exact(tmp_path):
    state = make_state(jax.random.PRNGKey(0))
    save_checkpoint(state, str(tmp_path), 3, n_shards=4)
    out, m = restore_checkpoint(str(tmp_path), 3, like=state)
    assert tree_eq(state, out)
    assert m.step == 3
    assert latest_step(str(tmp_path)) == 3


def test_elastic_reshard_different_shard_counts(tmp_path):
    """8-shard save restores bit-exactly regardless of reader topology."""
    state = make_state(jax.random.PRNGKey(1))
    save_checkpoint(state, str(tmp_path / "a"), 1, n_shards=8)
    save_checkpoint(state, str(tmp_path / "b"), 1, n_shards=2)
    out_a, _ = restore_checkpoint(str(tmp_path / "a"), 1, like=state)
    out_b, _ = restore_checkpoint(str(tmp_path / "b"), 1, like=state)
    assert tree_eq(out_a, out_b)
    assert tree_eq(out_a, state)


def test_bfloat16_preserved(tmp_path):
    state = {"w": jnp.asarray([1.5, -2.25], jnp.bfloat16)}
    save_checkpoint(state, str(tmp_path), 0)
    out, _ = restore_checkpoint(str(tmp_path), 0, like=state)
    assert out["w"].dtype == jnp.bfloat16
    assert tree_eq(state, out)


def test_hrs_restore_sources_prefer_region(tmp_path):
    topo = GridTopology(2, 4, lan_bandwidth=50e9, wan_bandwidth=3e9,
                        storage_capacity=1e12)
    state = make_state(jax.random.PRNGKey(2))
    m = save_checkpoint(state, str(tmp_path), 5, n_shards=4,
                        replicate_to=[1, 6])
    # dst in region 1 (sites 4..7): must pick 6 (intra-region), never 1
    srcs = choose_restore_sources(m, topo, dst_site=5)
    assert set(srcs.values()) == {6}
    # dst in region 0: picks 1
    srcs0 = choose_restore_sources(m, topo, dst_site=2)
    assert set(srcs0.values()) == {1}


def test_latest_step_none_for_empty(tmp_path):
    assert latest_step(str(tmp_path / "nothing")) is None
