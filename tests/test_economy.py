"""Replication economy: value models, the auction, ECON-event integration,
and backend equivalence of the vectorized scorer."""

import dataclasses

import numpy as np
import pytest

from repro.core import (AccessHistory, GridConfig, GridSimulator,
                        NetworkEngine, ReplicaCatalog, ReplicationOptimizer,
                        StorageState, VALUE_MODELS, build_catalog,
                        build_topology, generate_jobs, run_experiment)


def _world(n_files=6, file_size=1e6, storage=None):
    cfg = GridConfig(n_regions=2, sites_per_region=3,
                     **({"storage_capacity": storage} if storage else {}))
    topo = build_topology(cfg)
    cat = ReplicaCatalog()
    for i in range(n_files):
        cat.register_file(f"lfn{i:04d}", file_size, i % topo.n_sites)
    storage_state = StorageState(cat, topo)
    for info in cat.files.values():
        storage_state.bootstrap(info.master_site, info.lfn)
    access = AccessHistory(cat, topo, half_life_s=3600.0)
    net = NetworkEngine(topo)
    return topo, cat, storage_state, access, net


def _optimizer(model="popularity", **kw):
    topo, cat, store, access, net = _world(**{k: v for k, v in kw.items()
                                              if k in ("n_files", "file_size",
                                                       "storage")})
    opt = ReplicationOptimizer(cat, topo, store, access, net, model=model)
    return topo, cat, store, access, opt


def test_optimizer_stages_hot_file_to_demanding_site():
    topo, cat, store, access, opt = _optimizer()
    # site 1 keeps asking for lfn0000 (mastered at site 0): clear demand
    for t in range(20):
        access.record_access(1, "lfn0000", now=60.0 * t)
    props = opt.step(now=1200.0)
    assert props, "hot demand with free space must produce a proposal"
    by_dst = {(p.dst, p.lfn) for p in props}
    assert (1, "lfn0000") in by_dst
    for p in props:
        assert cat.has_replica(p.lfn, p.src) and p.src != p.dst
        assert p.value > 0 and not p.evictions   # plenty of free space


def test_optimizer_quiet_history_proposes_nothing():
    topo, cat, store, access, opt = _optimizer()
    assert opt.step(now=900.0) == []


def _full_site_world():
    """Site 1's SE (2 GB) holds its master lfn0001 (unevictable) plus a
    replica of lfn0002 (evictable) — staging anything means evicting the
    replica."""
    topo, cat, store, access, opt = _optimizer(file_size=1e9, storage=2e9)
    store.add(1, "lfn0002", now=0.0)      # registers the replica too
    return topo, cat, store, access, opt


def test_optimizer_never_trades_at_a_net_loss():
    topo, cat, store, access, opt = _full_site_world()
    # the resident replica is hot, the candidate is lukewarm: evicting
    # the resident would be a net loss, so no proposal targets site 1
    for t in range(3):
        access.record_access(1, "lfn0000", now=60.0 * t)
    for t in range(50):
        access.record_access(1, "lfn0002", now=60.0 * t)
    assert all(p.dst != 1 for p in opt.step(now=600.0))


def test_optimizer_evicts_cold_replica_for_hot_file():
    topo, cat, store, access, opt = _full_site_world()
    for t in range(50):
        access.record_access(1, "lfn0000", now=60.0 * t)
    props = [p for p in opt.step(now=600.0) if p.dst == 1]
    assert props and props[0].lfn == "lfn0000"
    assert props[0].evictions == ["lfn0002"]
    assert props[0].evicted_value < props[0].value


def test_value_models_registry():
    assert set(VALUE_MODELS) == {"economic", "popularity"}
    for name, cls in VALUE_MODELS.items():
        assert cls.name == name
        assert cls.mode in ("cost", "plain")


def test_unknown_model_and_backend_rejected():
    topo, cat, store, access, net = _world()
    with pytest.raises(ValueError, match="value model"):
        ReplicationOptimizer(cat, topo, store, access, net, model="nope")
    with pytest.raises(ValueError, match="econ backend"):
        ReplicationOptimizer(cat, topo, store, access, net, backend="cuda")
    with pytest.raises(ValueError, match="econ backend"):
        run_experiment(GridConfig(n_regions=2, sites_per_region=2),
                       n_jobs=1, econ="cuda")


def test_econ_event_fires_and_run_terminates():
    """The periodic ECON event stages replicas mid-run and the DES still
    drains: forcing the optimizer on for plain HRS exercises the
    event path without an access-aware strategy."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy="hrs", econ_interval=600.0)
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    for j, job in enumerate(generate_jobs(cfg, 60)):
        sim.submit_job(job, at=j * 60.0)
    res = sim.run()
    assert len(res.records) == 60
    assert sim._econ is not None and sim._econ.rounds > 0
    assert sim.access.prefetches > 0


def test_reactive_strategies_schedule_no_econ_events():
    cfg = GridConfig(n_regions=2, sites_per_region=2)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy="hrs")
    assert sim._econ is None


def test_econ_backend_numpy_vs_pallas_interpret_end_to_end():
    """econ='pallas-interpret' runs every optimizer round's scoring pass
    through the Pallas interpreter under x64 — decisions, and therefore
    the whole simulation, must be bit-identical to the numpy scorer."""
    cfg = GridConfig(n_regions=2, sites_per_region=3)
    kw = dict(strategy="economic", n_jobs=40, econ_interval=1200.0)
    a = run_experiment(cfg, econ="numpy", **kw)
    b = run_experiment(cfg, econ="pallas-interpret", **kw)
    assert a.avg_job_time == b.avg_job_time
    assert a.avg_inter_comms == b.avg_inter_comms
    assert a.total_wan_gb == b.total_wan_gb
    assert a.makespan == b.makespan


@pytest.mark.parametrize("strategy", ["economic", "predictive"])
def test_access_aware_strategies_complete_under_pressure(strategy):
    """Starved SEs (2 GB against 6 GB working sets): the trade logic must
    still complete every job, streaming what it refuses to store."""
    cfg = GridConfig(n_regions=2, sites_per_region=4,
                     storage_capacity=2e9)
    r = run_experiment(cfg, strategy=strategy, n_jobs=60)
    assert r.completed_jobs == r.n_jobs == 60
    assert r.avg_job_time > 0
