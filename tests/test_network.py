"""Path-contention NetworkEngine: uplink-path topology queries on deep
trees, the hand-computed min-over-path contention fixture, backend
equivalence (numpy vs pallas), and the legacy topmost-model divergence."""

import dataclasses
import types

import pytest

from repro.core import GridConfig, GridTopology, NetworkEngine, run_experiment
from repro.core.network import BACKENDS

GB = 1e9


def _topo(fanouts, uplinks, lan=100.0, path_model="full"):
    return GridTopology(0, 0, lan_bandwidth=lan, wan_bandwidth=uplinks[0],
                        storage_capacity=10 * GB, tier_fanouts=fanouts,
                        uplink_bandwidths=uplinks, path_model=path_model)


# -- uplink_index / uplink_path / links_for on deep trees -------------------
class TestUplinkPath4Tier:
    """(2, 4, 7): 2 clusters x 4 groups x 7 sites. wan_links layout:
    level-1 cluster uplinks are ids 0-1, level-2 group uplinks ids 2-9."""

    def setup_method(self):
        self.topo = _topo((2, 4, 7), (10.0, 100.0))

    def test_same_group(self):
        assert self.topo.uplink_path(0, 3) == ()
        assert self.topo.uplink_index(0, 3) == -1
        assert [l.name for l in self.topo.links_for(0, 3)] == ["nic0"]
        assert self.topo.link_ids_for(0, 3) == (0,)

    def test_sibling_subtree(self):
        # site 0 (group 0) -> site 7 (group 1), same cluster: one crossed
        # uplink, the source group's — full and topmost models agree
        assert self.topo.uplink_path(0, 7) == (2,)
        assert self.topo.uplink_index(0, 7) == 2
        assert self.topo.link_ids_for(0, 7) == (0, 56 + 2)

    def test_cross_region(self):
        # site 0 -> site 28 (cluster 1): crosses the cluster-0 uplink AND
        # the group-0 uplink below it, topmost first
        assert self.topo.uplink_path(0, 28) == (0, 2)
        assert self.topo.uplink_index(0, 28) == 0          # topmost only
        assert self.topo.link_ids_for(0, 28) == (0, 56 + 0, 56 + 2)
        # reverse direction uses the *source-side* (cluster 1) links
        assert self.topo.uplink_path(28, 0) == (1, 2 + 4)

    def test_topmost_model_truncates(self):
        legacy = _topo((2, 4, 7), (10.0, 100.0), path_model="topmost")
        assert legacy.uplink_path(0, 28) == (0,)
        assert legacy.uplink_path(0, 7) == (2,)            # unchanged
        assert legacy.link_ids_for(0, 28) == (0, 56 + 0)


class TestUplinkPath5Tier:
    """(2, 3, 3, 3): 54 sites; wan_links: level-1 ids 0-1, level-2 ids 2-7,
    level-3 ids 8-25."""

    def setup_method(self):
        self.topo = _topo((2, 3, 3, 3), (10.0, 50.0, 200.0))

    def test_same_site_group(self):
        assert self.topo.uplink_path(0, 2) == ()
        assert self.topo.link_ids_for(0, 2) == (0,)

    def test_sibling_subtree_mid(self):
        # site 0 -> site 4: same level-2 node, different leaf groups
        assert self.topo.ancestors(0) == (0, 0, 0)
        assert self.topo.ancestors(4) == (0, 0, 1)
        assert self.topo.uplink_path(0, 4) == (8,)
        assert self.topo.uplink_index(0, 4) == 8

    def test_cross_region_full_depth(self):
        # site 0 -> site 53: diverges at the root, crosses all three
        # source-side uplinks top-down
        assert self.topo.ancestors(53) == (1, 5, 17)
        assert self.topo.uplink_path(0, 53) == (0, 2, 8)
        assert self.topo.uplink_index(0, 53) == 0
        assert self.topo.link_ids_for(0, 53) == (0, 54, 54 + 2, 54 + 8)

    def test_point_bandwidth_sees_thin_mid_tier(self):
        # make the lower tier the bottleneck: 100 over 1 top-down
        topo = _topo((2, 2, 2), (100.0, 1.0))
        # site 0 -> site 7 crosses level-1 (100) and a thin level-2 (1)
        assert topo.point_bandwidth(0, 7) == 1.0
        legacy = _topo((2, 2, 2), (100.0, 1.0), path_model="topmost")
        assert legacy.point_bandwidth(0, 7) == pytest.approx(100.0)


def test_path_model_validation():
    with pytest.raises(ValueError, match="path_model"):
        _topo((2, 2), (10.0,), path_model="bogus")


# -- the 3-transfer mid-tier contention fixture -----------------------------
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_three_transfer_min_over_path(backend):
    """Hand-computed fair shares on a (2,2,2) tree: NIC 100 B/s, cluster
    uplinks 50, group uplinks 10 (ids: cluster c -> 8+c, group g -> 8+2+g).

      t1: 0 -> 6  crosses nic0, cluster-0 (50), group-0 (10)
      t2: 1 -> 2  crosses nic1, group-0 (10)
      t3: 0 -> 1  crosses nic0 only

    Occupancy: nic0={t1,t3}, nic1={t2}, cluster0={t1}, group0={t1,t2}, so
      t1 = min(100/2, 50/1, 10/2) = 5      (mid-tier bound through-traffic)
      t2 = min(100/1, 10/2)       = 5
      t3 = 100/2                  = 50
    The legacy topmost model would rate t1 = min(100/2, 50/1) = 50."""
    topo = _topo((2, 2, 2), (50.0, 10.0))
    net = NetworkEngine(topo, backend=backend)
    slots = {}
    for name, (src, dst) in {"t1": (0, 6), "t2": (1, 2), "t3": (0, 1)}.items():
        tr = types.SimpleNamespace(slot=-1)
        net.alloc(tr, 1e6, topo.link_ids_for(src, dst))
        net.rerate(topo.link_ids_for(src, dst), 0.0)
        slots[name] = tr.slot
    assert net.rate[slots["t1"]] == pytest.approx(5.0)
    assert net.rate[slots["t2"]] == pytest.approx(5.0)
    assert net.rate[slots["t3"]] == pytest.approx(50.0)
    # eta scan: smallest rem/rate wins
    assert net.rerate((), 0.0) == pytest.approx(1e6 / 50.0)

    legacy = _topo((2, 2, 2), (50.0, 10.0), path_model="topmost")
    lnet = NetworkEngine(legacy, backend=backend)
    tr = types.SimpleNamespace(slot=-1)
    lnet.alloc(tr, 1e6, legacy.link_ids_for(0, 6))
    lnet.rerate(legacy.link_ids_for(0, 6), 0.0)
    assert lnet.rate[tr.slot] == pytest.approx(50.0)


def test_three_transfer_batched_flush_matches_numpy():
    """The batched ``device`` engine defers re-rates (rerate() only marks
    dirty links) and resolves the whole instant in one fused flush; the
    flushed rates must equal the hand-computed incremental fixture above
    and the returned wake-up must be the global earliest completion."""
    topo = _topo((2, 2, 2), (50.0, 10.0))
    net = NetworkEngine(topo, backend="device")
    assert net.batched
    slots = {}
    for name, (src, dst) in {"t1": (0, 6), "t2": (1, 2), "t3": (0, 1)}.items():
        tr = types.SimpleNamespace(slot=-1)
        net.alloc(tr, 1e6, topo.link_ids_for(src, dst))
        assert net.rerate(topo.link_ids_for(src, dst), 0.0) is None
        slots[name] = tr.slot
    assert net.dirty
    eta = net.flush(0.0)
    assert not net.dirty
    assert net.rate[slots["t1"]] == pytest.approx(5.0)
    assert net.rate[slots["t2"]] == pytest.approx(5.0)
    assert net.rate[slots["t3"]] == pytest.approx(50.0)
    # the flush returns the next completion: t3 at 1e6 / 50 B/s
    assert eta == pytest.approx(1e6 / 50.0)
    assert net.rem_now(0.0)[slots["t1"]] == pytest.approx(1e6)


def _burst_stats(backend: str, n_backlog: int) -> tuple[dict, "object"]:
    """Load one uplink path with ``n_backlog`` in-flight transfers, then
    replay an identical 16-event same-instant burst on that path and
    return the engine's work counters for the burst alone."""
    topo = _topo((2, 2, 2), (50.0, 10.0))
    net = NetworkEngine(topo, backend=backend)
    links = topo.link_ids_for(0, 6)
    for _ in range(n_backlog):
        tr = types.SimpleNamespace(slot=-1)
        net.alloc(tr, 1e9, links)
        net.rerate(links, 0.0)
    if net.batched:
        net.flush(0.0)
    net.stats = {k: 0 for k in net.stats}
    for _ in range(16):
        tr = types.SimpleNamespace(slot=-1)
        net.alloc(tr, 1e6, links)
        net.rerate(links, 1.0)
    if net.batched:
        net.flush(1.0)
    return net.stats, net


def test_device_per_event_work_independent_of_backlog():
    """Saturated-backlog regression (counter-based, no timing): the numpy
    engine re-rates the changed-link union on *every* event, so its
    per-event work grows with the in-flight count; the batched device
    engine does zero per-event re-rate work (rerate only marks dirty)
    and pays one fused pass over the dirty neighborhood per instant,
    however many events the instant carries."""
    small_np, _ = _burst_stats("numpy", 8)
    big_np, _ = _burst_stats("numpy", 512)
    small_dev, _ = _burst_stats("device", 8)
    big_dev, net_dev = _burst_stats("device", 512)

    # numpy: 16 union re-rates, each touching the whole shared backlog
    assert big_np["rerate_slots"] >= 16 * 512
    assert big_np["rerate_slots"] > 4 * small_np["rerate_slots"]

    # device: no per-event slot work at all — backlog size is invisible
    # until the instant's single flush
    assert small_dev["rerate_slots"] == big_dev["rerate_slots"] == 0
    assert big_dev["flush_passes"] == 1
    assert big_dev["flush_slots"] <= 512 + 16      # one pass, not 16

    # and the fused pass lands on the same floats the incremental
    # engine integrates to (both are f64 min-over-path fair shares)
    _, net_np = _burst_stats("numpy", 512)
    import numpy as np
    assert np.array_equal(net_dev.rate[:528], net_np.rate[:528])


def test_engine_counters_surface_through_results():
    """The per-engine work counters asserted above must also be readable
    from a finished run — SimResult/ExperimentResult carry
    ``NetworkEngine.stats`` so the saturated-backlog regression can be
    re-checked on real workloads without reaching into the engine."""
    cfg = GridConfig(n_regions=2, sites_per_region=3)
    inc = run_experiment(cfg, n_jobs=80)                 # incremental numpy
    dev = run_experiment(cfg, n_jobs=80, net="device")   # batched device
    assert set(inc.net_stats) == {"rerate_calls", "rerate_slots",
                                  "flush_passes", "flush_slots"}
    # incremental engine: per-event union re-rates, never a fused flush
    assert inc.net_stats["rerate_slots"] > 0
    assert inc.net_stats["flush_passes"] == 0
    # batched engine: zero per-event slot work, all work in flush passes
    assert dev.net_stats["rerate_slots"] == 0
    assert dev.net_stats["flush_passes"] > 0
    assert dev.net_stats["flush_slots"] > 0
    # both engines saw the same event stream
    assert dev.net_stats["rerate_calls"] == inc.net_stats["rerate_calls"]


def test_engine_release_and_regrow():
    topo = _topo((2, 2), (10.0,))
    net = NetworkEngine(topo)
    trs = []
    for i in range(100):           # force a capacity doubling past 64
        tr = types.SimpleNamespace(slot=-1)
        net.alloc(tr, 1e6, topo.link_ids_for(0, 3))
        trs.append(tr)
    assert net.cap >= 128 and net.n_active == 100
    assert net.link_act[0] == 100.0
    changed = net.release(trs[0])
    assert changed == topo.link_ids_for(0, 3)
    assert net.n_active == 99 and net.link_act[0] == 99.0
    assert trs[0].slot == -1


def test_unknown_backend_rejected():
    topo = _topo((2, 2), (10.0,))
    with pytest.raises(ValueError, match="backend"):
        NetworkEngine(topo, backend="fortran")
    with pytest.raises(ValueError, match="net engine"):
        run_experiment(GridConfig(n_regions=2, sites_per_region=2), n_jobs=1,
                       net="fortran")
    assert "numpy" in BACKENDS and "pallas" in BACKENDS


def test_topmost_refuses_full_path_topology():
    """net='topmost' must not silently mutate a topology built with the
    full path model — a direct GridSimulator gets a loud error instead."""
    from repro.core import GridSimulator, build_catalog, build_topology
    cfg = GridConfig(n_regions=2, sites_per_region=2)
    topo = build_topology(cfg)                      # path_model="full"
    cat = build_catalog(cfg, topo)
    with pytest.raises(ValueError, match="path_model='topmost'"):
        GridSimulator(topo, cat, net="topmost")
    assert topo.path_model == "full"                # untouched
    legacy = build_topology(cfg, path_model="topmost")
    GridSimulator(legacy, build_catalog(cfg, legacy), net="topmost")


# -- backend equivalence and fidelity divergence ----------------------------
def test_two_level_backends_bit_identical():
    """On two-level grids all engine flags (numpy / pallas / topmost) must
    produce the same floats — the path is {NIC, region uplink} under every
    model."""
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    base = run_experiment(cfg, strategy="hrs", n_jobs=60, net="numpy")
    for net in ("pallas", "topmost"):
        r = run_experiment(cfg, strategy="hrs", n_jobs=60, net=net)
        assert r.avg_job_time == base.avg_job_time, net
        assert r.avg_inter_comms == base.avg_inter_comms, net
        assert r.total_wan_gb == base.total_wan_gb, net
        assert r.makespan == base.makespan, net


def test_deep_tree_backends_bit_identical():
    """numpy incremental vs pallas full-recompute agree bit-for-bit on a
    deep tree too (same pure function of link occupancy)."""
    mbps = 1e6 / 8
    cfg = GridConfig(tier_fanouts=(3, 3, 6),
                     uplink_bandwidths=(100 * mbps, 10 * mbps))
    a = run_experiment(cfg, strategy="hrs", n_jobs=60, net="numpy")
    b = run_experiment(cfg, strategy="hrs", n_jobs=60, net="pallas")
    assert a.avg_job_time == b.avg_job_time
    assert a.avg_inter_comms == b.avg_inter_comms
    assert a.makespan == b.makespan


def test_deep_tree_full_path_diverges_from_topmost():
    """The fidelity change is real: on a fat-top/thin-mid tree the
    per-link path model must not reproduce the legacy topmost numbers."""
    mbps = 1e6 / 8
    cfg = GridConfig(tier_fanouts=(3, 3, 6),
                     uplink_bandwidths=(100 * mbps, 10 * mbps))
    full = run_experiment(cfg, strategy="hrs", n_jobs=60, net="numpy")
    legacy = run_experiment(cfg, strategy="hrs", n_jobs=60, net="topmost")
    assert full.avg_job_time != legacy.avg_job_time


@pytest.mark.parametrize("fanouts,uplinks,path_model", [
    ((4, 13), (10.0,), "full"),
    ((2, 4, 7), (10.0, 100.0), "full"),
    ((2, 3, 3, 3), (10.0, 50.0, 200.0), "full"),
    ((2, 3, 3, 3), (10.0, 50.0, 200.0), "topmost"),
])
def test_pair_link_matrix_matches_link_ids_for(fanouts, uplinks, path_model):
    """The vectorized (sites, sites, depth) tensor equals the per-pair
    link_ids_for rows: NIC first, same crossed-uplink id set (hole
    positions within a row carry no meaning — consumers mask on >= 0)."""
    topo = _topo(fanouts, uplinks, path_model=path_model)
    mat = topo.pair_link_matrix()
    assert mat.shape == (topo.n_sites, topo.n_sites, topo.depth)
    for h in range(topo.n_sites):
        for s in range(topo.n_sites):
            row = mat[h, s]
            assert row[0] == h                           # source NIC
            assert sorted(int(x) for x in row if x >= 0) == \
                sorted(topo.link_ids_for(h, s))


def test_point_bandwidth_matrix_is_the_shared_snapshot():
    """One cached path tensor serves both consumers: the jitted
    shortest-transfer broker and the replication economy read the same
    NetworkEngine.point_bandwidth_matrix, and its cell values equal the
    scalar point_bandwidth query."""
    import numpy as np

    from repro.core import GridSimulator, build_catalog, build_topology
    cfg = GridConfig(n_regions=2, sites_per_region=3)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, scheduler="shortesttransfer",
                        strategy="hrs", broker="jax")
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    assert sim.network._pair_paths is None          # lazy until first use
    sim._jax_broker.select_batch([["lfn0000"], ["lfn0001"]])
    cached = sim.network._pair_paths
    assert cached is not None                       # broker went through it
    B = sim.network.point_bandwidth_matrix()
    assert sim.network._pair_paths is cached        # built exactly once
    for h, s in ((0, 0), (0, 5), (4, 1), (5, 2)):
        assert B[h, s] == sim.network.point_bandwidth(h, s)
    assert np.array_equal(cached, topo.pair_link_matrix())


# -- the vectorized shortest-transfer broker --------------------------------
def test_jax_shortest_transfer_matches_python():
    """Batch decisions over a frozen snapshot must equal the sequential
    python policy site-for-site (durable masters + zero-bw guard incl.)."""
    from repro.core import (GridSimulator, build_catalog, build_topology,
                            generate_jobs)
    from repro.core.scheduler import make_scheduler
    cfg = GridConfig(n_regions=3, sites_per_region=5)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, scheduler="shortesttransfer",
                        strategy="hrs", broker="jax")
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    jobs = generate_jobs(cfg, 48)
    want = [make_scheduler("shortesttransfer", cat, topo).select_site(j)
            for j in jobs]
    got = sim._jax_broker.select_batch([j.required for j in jobs])
    assert got == want


def test_jax_shortest_transfer_broker_end_to_end():
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    a = run_experiment(cfg, scheduler="shortesttransfer", strategy="hrs",
                       n_jobs=60, broker="jax", arrival_burst=10)
    b = run_experiment(cfg, scheduler="shortesttransfer", strategy="hrs",
                       n_jobs=60, broker="jax", arrival_burst=10)
    assert a.completed_jobs == a.n_jobs == 60
    assert a.avg_job_time == b.avg_job_time       # deterministic


def test_jax_broker_covers_every_registered_policy():
    """The broker gap is closed: every SCHEDULERS entry dispatches under
    broker='jax' (dataaware/shortesttransfer since PR 3, leastloaded and
    random via the argmin/PRNG-gather brokers)."""
    from repro.core import SCHEDULERS
    for scheduler in sorted(SCHEDULERS):
        r = run_experiment(GridConfig(n_regions=2, sites_per_region=2),
                           scheduler=scheduler, n_jobs=8, broker="jax",
                           arrival_burst=4)
        assert r.completed_jobs == 8, scheduler


def test_bulk_shortest_scenario_smoke():
    from repro.core import SCENARIOS
    from repro.launch.experiments import run_spec
    spec = dataclasses.replace(SCENARIOS["bulk_shortest"])
    r = run_spec(spec, n_jobs=50)
    assert r.completed_jobs == 50
