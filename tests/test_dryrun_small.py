"""Launch-stack integration: lower + compile on a small fake-device mesh.

Runs in a SUBPROCESS because the device count is locked at first jax init —
the main pytest process must keep seeing one CPU device.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.launch.dryrun import lower_cell
from repro.launch.hloanalysis import analyze
from repro.launch.mesh import make_debug_mesh

mesh = make_debug_mesh(4, 2)            # (data=4, model=2)
import repro.configs.base as B
import dataclasses
# shrink shapes so the compile stays quick on CPU
B.SHAPES = {
    "train_4k": B.ShapeConfig("train_4k", 256, 8, "train"),
    "decode_32k": B.ShapeConfig("decode_32k", 512, 8, "decode"),
    "prefill_32k": B.ShapeConfig("prefill_32k", 256, 8, "prefill"),
    "long_500k": B.ShapeConfig("long_500k", 1024, 1, "decode"),
}
# reduced-size models, published family structure
_orig = B.get_config
def patched(arch):
    return _orig(arch).reduced()
B.get_config = patched
import repro.launch.dryrun as D
D.get_config = patched
D.SHAPES = B.SHAPES

out = {}
for arch, shape in [("granite-3-8b", "train_4k"),
                    ("gemma3-1b", "decode_32k"),
                    ("falcon-mamba-7b", "prefill_32k"),
                    ("granite-moe-1b-a400m", "train_4k")]:
    lowered, meta = lower_cell(arch, shape, mesh)
    compiled = lowered.compile()
    st = analyze(compiled.as_text(), pod_boundary=4)
    out[f"{arch}:{shape}"] = {
        "flops": st.matmul_flops,
        "mem": compiled.memory_analysis().temp_size_in_bytes,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_lower_compile_all_modes():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(out) == 4
    for cell, stats in out.items():
        assert stats["flops"] > 0, cell
