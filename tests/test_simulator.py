"""Discrete-event simulator behaviour (paper §4 semantics)."""

import pytest

from repro.core import (GridConfig, GridSimulator, Job, ReplicaCatalog,
                        build_catalog, build_topology, generate_jobs,
                        run_experiment)
from repro.core.topology import GridTopology

GB = 1e9


def mini_sim(strategy="hrs", scheduler="dataaware", caps=None):
    topo = GridTopology(2, 2, lan_bandwidth=100e6, wan_bandwidth=1e6,
                        storage_capacity=100 * GB,
                        compute_capacities=caps or [1e9] * 4)
    cat = ReplicaCatalog()
    sim = GridSimulator(topo, cat, scheduler=scheduler, strategy=strategy)
    return topo, cat, sim


def test_job_time_transfer_plus_processing():
    """One job, one missing 100 MB file over LAN at 100 MB/s + 10s CPU."""
    topo, cat, sim = mini_sim()
    cat.register_file("f", 100e6, master_site=1)    # site 1, same region as 0
    sim.storage.bootstrap(1, "f")
    cat.register_file("local", 100e6, master_site=0)
    sim.storage.bootstrap(0, "local")
    job = Job(0, 0, ["local", "f"], length=10e9)    # 10s at 1 GFLOPs
    sim.submit_job(job, at=0.0)
    res = sim.run()
    assert len(res.records) == 1
    r = res.records[0]
    # schedule at site 0 (holds 100 MB 'local'); fetch f (1s) then 10s CPU
    assert r.site == 0
    assert r.finish_time == pytest.approx(11.0, rel=1e-3)
    assert r.inter_comms == 0


def test_inter_region_transfer_counted():
    topo, cat, sim = mini_sim()
    cat.register_file("f", 1e6, master_site=2)       # other region
    sim.storage.bootstrap(2, "f")
    cat.register_file("anchor", 2e6, master_site=0)
    sim.storage.bootstrap(0, "anchor")
    job = Job(0, 0, ["anchor", "f"], length=1e9)
    sim.submit_job(job, at=0.0)
    res = sim.run()
    assert res.records[0].inter_comms == 1
    assert res.total_wan_bytes == 1e6


def test_fair_share_two_transfers():
    """Two jobs pulling different files from the same source NIC share it."""
    topo, cat, sim = mini_sim()
    for i in range(2):
        cat.register_file(f"f{i}", 100e6, master_site=1)
        sim.storage.bootstrap(1, f"f{i}")
    # anchors force the two jobs onto different destinations (0 and 2? no —
    # same region sites: 0 and 1). Use anchors at sites 0 and 3.
    cat.register_file("a0", 300e6, master_site=0)
    sim.storage.bootstrap(0, "a0")
    job0 = Job(0, 0, ["a0", "f0"], length=1e6)
    job1 = Job(1, 0, ["f1"], length=1e6)             # scheduled at site 1 (holder)
    # second puller from site 1's NIC: job at site 3 needing f1? keep simple:
    sim.submit_job(job0, at=0.0)
    res = sim.run()
    # single transfer at full NIC share: 1s for 100 MB at 100 MB/s
    assert res.records[0].finish_time == pytest.approx(1.0 + 0.001, rel=1e-2)


def test_queueing_fifo_single_server():
    topo, cat, sim = mini_sim()
    cat.register_file("f", 1e6, master_site=0)
    sim.storage.bootstrap(0, "f")
    for j in range(3):
        sim.submit_job(Job(j, 0, ["f"], length=10e9), at=0.0)
    res = sim.run()
    finishes = sorted(r.finish_time for r in res.records)
    assert finishes == pytest.approx([10.0, 20.0, 30.0], rel=1e-3)


def test_failure_resubmits_jobs():
    topo, cat, sim = mini_sim()
    cat.register_file("f", 1e6, master_site=0)
    sim.storage.bootstrap(0, "f")
    sim.submit_job(Job(0, 0, ["f"], length=100e9), at=0.0)   # 100s of work
    sim.inject_failure(0, at=5.0, duration=50.0)
    res = sim.run()
    assert len(res.records) == 1
    r = res.records[0]
    assert r.resubmits == 1
    assert r.site != 0 or r.finish_time > 55.0    # rescheduled elsewhere/after
    assert r.finish_time > 100.0                  # lost progress + refetch


def test_speculative_backup_beats_straggler():
    topo, cat, sim_plain = mini_sim()
    cat.register_file("f", 1e6, master_site=0)
    sim_plain.storage.bootstrap(0, "f")
    sim_plain.submit_job(Job(0, 0, ["f"], length=10e9), at=0.0)
    sim_plain.inject_slowdown(0, at=1.0, duration=1e6, factor=0.01)
    plain = sim_plain.run().records[0].finish_time

    topo2, cat2, sim_spec = mini_sim()
    sim_spec.speculative_backups = True
    cat2.register_file("f", 1e6, master_site=0)
    sim_spec.storage.bootstrap(0, "f")
    sim_spec.submit_job(Job(0, 0, ["f"], length=10e9), at=0.0)
    sim_spec.inject_slowdown(0, at=1.0, duration=1e6, factor=0.01)
    spec = sim_spec.run().records[0].finish_time
    assert spec < plain / 5          # backup on a healthy site wins


def test_paper_orderings_hold():
    """HRS <= BHR <= LRU on job time AND inter-comms (paper Figs 4-6)."""
    res = {}
    for s in ("hrs", "bhr", "lru"):
        res[s] = run_experiment(GridConfig(), strategy=s, n_jobs=150)
    assert res["hrs"].avg_job_time <= res["bhr"].avg_job_time
    assert res["bhr"].avg_job_time <= res["lru"].avg_job_time
    assert res["hrs"].avg_inter_comms <= res["bhr"].avg_inter_comms
    assert res["bhr"].avg_inter_comms <= res["lru"].avg_inter_comms


def test_all_jobs_complete_and_storage_bounded():
    cfg = GridConfig()
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, scheduler="dataaware", strategy="hrs")
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    jobs = generate_jobs(cfg, 100)
    for i, j in enumerate(jobs):
        sim.submit_job(j, at=i * cfg.interarrival)
    res = sim.run()
    assert len(res.records) == 100
    for s in topo.sites:
        assert s.used_storage <= s.storage_capacity + 1e-6
        assert s.queued_work == pytest.approx(0.0, abs=1e-6)
