"""End-to-end behaviour tests for the paper's system.

1. The headline reproduction: HRS + data-aware scheduling beats BHR and LRU
   on average job time and inter-region communications (paper Figs 4-6).
2. Grid-integrated training with failure injection recovers and converges.
3. Serving: greedy generation through the engine matches the teacher-forced
   argmax path of the same model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import GridConfig, run_experiment


def test_paper_headline_reproduction():
    # 300+ jobs: the strategies only diverge once SEs come under sustained
    # pressure (DESIGN.md §8) — shorter runs sit in the warm-up regime
    res = {s: run_experiment(GridConfig(), strategy=s, n_jobs=300)
           for s in ("hrs", "bhr", "lru")}
    # orderings (Figs 4-6)
    assert res["hrs"].avg_job_time < res["bhr"].avg_job_time
    assert res["bhr"].avg_job_time < res["lru"].avg_job_time
    assert res["hrs"].avg_inter_comms < res["lru"].avg_inter_comms
    # magnitude: paper reports "about 12%" HRS over BHR; we accept a broad
    # band since the paper under-specifies the workload (DESIGN.md §8)
    gain = (res["bhr"].avg_job_time - res["hrs"].avg_job_time) \
        / res["bhr"].avg_job_time
    assert 0.05 < gain < 0.60


def test_scheduler_matters_with_fixed_replication():
    data_aware = run_experiment(GridConfig(), scheduler="dataaware",
                                strategy="hrs", n_jobs=150)
    rand = run_experiment(GridConfig(), scheduler="random",
                          strategy="hrs", n_jobs=150)
    assert data_aware.avg_job_time < rand.avg_job_time


def test_training_with_failures_recovers(tmp_path):
    from repro.core import GridTopology
    from repro.data.pipeline import (DataConfig, GridDataLoader,
                                     SyntheticShardedDataset)
    from repro.fault.failures import FailurePlan, TrainingSupervisor
    from repro.grid.datagrid import DataGridService
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)

    cfg = get_config("gemma3-1b").reduced()
    topo = GridTopology(2, 4, lan_bandwidth=50e9, wan_bandwidth=3e9,
                        storage_capacity=64e9)
    grid = DataGridService(topo)
    ds = SyntheticShardedDataset(DataConfig(vocab=cfg.vocab, seq_len=32,
                                            global_batch=4, n_shards=8))
    loader = GridDataLoader(ds, grid)
    tcfg = TrainConfig(n_microbatches=1,
                       opt=OptimizerConfig(peak_lr=2e-3, warmup_steps=2,
                                           total_steps=60))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    jstep = jax.jit(make_train_step(cfg, tcfg))

    def step_fn(state, i):
        p, o = state
        batch, _ = loader.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = jstep(p, o, batch)
        return (p, o), {"loss": m["loss"]}

    sup = TrainingSupervisor(step_fn, str(tmp_path), ckpt_every=4,
                             plan=FailurePlan(fail_at_steps=(6,)))
    state, hist = sup.run((params, opt), 24)
    assert sup.stats.restarts == 1
    assert len(hist) >= 24
    losses = [h["loss"] for h in hist]
    # learnable affine-recurrence data: loss must fall below the uniform
    # floor despite the mid-run failure + restore
    assert min(losses[-6:]) < losses[0] - 0.15


def test_serving_engine_matches_teacher_forced():
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    cfg = get_config("granite-3-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=48)
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab))
    out = eng.generate(prompt, n_new=4)
    assert out.shape == (2, 4)
    # oracle: greedy continuation via repeated full forward
    cur = prompt
    for t in range(4):
        logits = M.train_logits(cfg, params, {"tokens": jnp.asarray(cur)})
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))[:, None]
        assert (nxt[:, 0] == out[:, t]).all(), f"mismatch at step {t}"
        cur = np.concatenate([cur, nxt], axis=1)
