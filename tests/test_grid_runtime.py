"""DataGridService + pipeline + serving router integration."""

import numpy as np

from repro.core import GridTopology
from repro.data.pipeline import (DataConfig, GridDataLoader,
                                 SyntheticShardedDataset)
from repro.grid.datagrid import DataGridService
from repro.grid.placement import mesh_to_topology
from repro.serve.engine import GridRouter, Request


def make_grid():
    topo = GridTopology(2, 4, lan_bandwidth=50e9, wan_bandwidth=3e9,
                        storage_capacity=64e9)
    return DataGridService(topo)


def test_place_job_prefers_data_locality():
    grid = make_grid()
    grid.register("a", 4e9, master_site=3)
    grid.register("b", 1e9, master_site=6)
    site, stats = grid.place_job(["a", "b"])
    assert site == 3                      # most required bytes
    assert len(stats) == 1 and stats[0].lfn == "b"


def test_scheduler_sends_job_to_data_not_data_to_job():
    """With a free choice the broker sends work WHERE THE DATA IS — zero
    transfers for a sole-replica artifact (the paper's core effect)."""
    grid = make_grid()
    grid.register("hot", 2e9, master_site=7)
    site, stats = grid.place_job(["hot"])
    assert site == 7 and stats == []
    assert grid.inter_comm_count() == 0


def test_hrs_replication_cuts_wan_traffic_on_reuse():
    """Consumers pinned in the other region: HRS crosses the WAN once,
    then serves every later consumer intra-region."""
    grid = make_grid()
    grid.register("hot", 2e9, master_site=7)      # region 1
    for dst in (0, 1, 2):                          # region-0 consumers
        grid.ensure_local(["hot"], dst)
    assert grid.inter_comm_count() == 1            # only the first fetch
    assert grid.wan_bytes() == 2e9
    assert grid.lan_bytes() == 4e9                 # two intra-region copies


def test_loader_deterministic_and_local():
    topo = GridTopology(2, 4, lan_bandwidth=50e9, wan_bandwidth=3e9,
                        storage_capacity=512e9)
    grid = DataGridService(topo)
    ds = SyntheticShardedDataset(DataConfig(vocab=100, seq_len=16,
                                            global_batch=4, n_shards=8))
    loader = GridDataLoader(ds, grid)
    b1, s1 = loader.next_batch()
    loader2 = GridDataLoader(SyntheticShardedDataset(
        DataConfig(vocab=100, seq_len=16, global_batch=4, n_shards=8)),
        DataGridService(GridTopology(2, 4, lan_bandwidth=50e9,
                                     wan_bandwidth=3e9,
                                     storage_capacity=512e9)))
    b2, s2 = loader2.next_batch()
    assert (b1["tokens"] == b2["tokens"]).all()
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_mesh_to_topology_two_pods():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)
            flat = range(512)
    import numpy as np
    topo = mesh_to_topology(FakeMesh, chips_per_host=8)
    assert topo.n_regions == 2
    assert topo.sites_per_region == 32     # 256 chips / 8 per host
    assert topo.wan_links[0].bandwidth < topo.nic_links[0].bandwidth


def test_router_sends_requests_to_prefix_holder():
    grid = make_grid()
    router = GridRouter(grid, n_engines=grid.topology.n_sites)
    router.register_prefix("sys-prompt-kv", 1e9, master_site=2)
    reqs = [Request(i, np.zeros(8, np.int32), prefix_id="sys-prompt-kv")
            for i in range(4)]
    sites = [router.route(r) for r in reqs]
    assert sites[0] == 2                   # prefix lives at 2
    # queue-load tie-breaks spread subsequent identical requests
    assert len(set(sites)) >= 1
    for s, r in zip(sites, reqs):
        router.complete(s, r)
