"""HRS / BHR / LRU decision behaviour (paper §3.3)."""

import pytest

from repro.core import (GridTopology, ReplicaCatalog, StorageState,
                        make_strategy)

GB = 1e9


def build(storage=10 * GB):
    topo = GridTopology(2, 3, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=storage)
    cat = ReplicaCatalog()
    st = StorageState(cat, topo)
    return topo, cat, st


def add_file(cat, st, lfn, size, master, replicas=()):
    cat.register_file(lfn, size, master)
    st.bootstrap(master, lfn)
    for r in replicas:
        st.add(r, lfn, now=0.0)


def test_hrs_prefers_local_region():
    topo, cat, st = build()
    # replica in region 0 (site 1) and region 1 (site 4); dst = site 0
    add_file(cat, st, "f", 1 * GB, master=4, replicas=[1])
    hrs = make_strategy("hrs", cat, topo, st)
    plan = hrs.plan_fetch("f", 0)
    assert plan.src == 1 and not plan.inter_region and plan.store


def test_hrs_intra_region_no_space_uses_temp_buffer():
    topo, cat, st = build(storage=1 * GB)
    add_file(cat, st, "full", 1 * GB, master=0)       # dst SE is full
    add_file(cat, st, "f", 1 * GB, master=1)          # same region
    hrs = make_strategy("hrs", cat, topo, st)
    plan = hrs.plan_fetch("f", 0)
    assert not plan.store and plan.evictions == [] and not plan.inter_region


def test_hrs_two_phase_eviction_prefers_region_duplicates():
    topo, cat, st = build(storage=2 * GB)
    # dst site 0 holds two evictable replicas: "dup" (duplicated at site 1,
    # same region) and "solo" (sole copy in region; master elsewhere)
    add_file(cat, st, "dup", 1 * GB, master=1, replicas=[0])
    add_file(cat, st, "solo", 1 * GB, master=5, replicas=[0])
    st.touch(0, "dup", 5.0)     # dup is MORE recently used than solo
    st.touch(0, "solo", 1.0)
    # file only available in the other region
    add_file(cat, st, "f", 2 * GB, master=4)
    hrs = make_strategy("hrs", cat, topo, st)
    plan = hrs.plan_fetch("f", 0)
    assert plan.inter_region and plan.store
    # phase 1 evicts the in-region duplicate first despite its recent use
    assert plan.evictions[0] == "dup"
    assert plan.evictions == ["dup", "solo"]


def test_hrs_never_evicts_master_or_pinned():
    topo, cat, st = build(storage=2 * GB)
    add_file(cat, st, "m", 1 * GB, master=0)            # master at dst
    add_file(cat, st, "p", 1 * GB, master=1, replicas=[0])
    st.pin(0, "p")
    add_file(cat, st, "f", 1 * GB, master=4)
    hrs = make_strategy("hrs", cat, topo, st)
    plan = hrs.plan_fetch("f", 0)
    # nothing evictable -> temp-buffer fallback
    assert not plan.store and plan.evictions == []


def test_bhr_remote_access_within_region():
    topo, cat, st = build(storage=1 * GB)
    add_file(cat, st, "full", 1 * GB, master=0)
    add_file(cat, st, "f", 1 * GB, master=2)            # same region as 0
    bhr = make_strategy("bhr", cat, topo, st)
    plan = bhr.plan_fetch("f", 0)
    assert plan.remote_access and not plan.store and not plan.inter_region


def test_lru_evicts_least_recently_used():
    topo, cat, st = build(storage=2 * GB)
    add_file(cat, st, "a", 1 * GB, master=1, replicas=[0])
    add_file(cat, st, "b", 1 * GB, master=2, replicas=[0])
    st.touch(0, "a", 1.0)
    st.touch(0, "b", 9.0)
    add_file(cat, st, "f", 1 * GB, master=4)
    lru = make_strategy("lru", cat, topo, st)
    plan = lru.plan_fetch("f", 0)
    assert plan.store and plan.evictions == ["a"]


def test_single_phase_ablation_ignores_region_duplication():
    """The ablation strategy evicts strictly by LRU, so the in-region
    duplicate is NOT prioritized (contrast with the two-phase test above)."""
    topo, cat, st = build(storage=2 * GB)
    add_file(cat, st, "dup", 1 * GB, master=1, replicas=[0])
    add_file(cat, st, "solo", 1 * GB, master=5, replicas=[0])
    st.touch(0, "dup", 5.0)
    st.touch(0, "solo", 1.0)
    add_file(cat, st, "f", 2 * GB, master=4)
    single = make_strategy("hrs_singlephase", cat, topo, st)
    plan = single.plan_fetch("f", 0)
    assert plan.evictions == ["solo", "dup"]        # pure LRU order


def test_storage_accounting_exact():
    topo, cat, st = build()
    add_file(cat, st, "a", 3 * GB, master=1, replicas=[0])
    assert topo.sites[0].used_storage == 3 * GB
    st.remove(0, "a")
    assert topo.sites[0].used_storage == 0.0
    assert cat.holders("a") == {1}
