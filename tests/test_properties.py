"""Property-based tests (hypothesis) on the system's invariants."""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (GridTopology, Job, ReplicaCatalog, StorageState,
                        make_scheduler, make_strategy)

GB = 1e9


def build_world(n_regions, sites_per_region, n_files, seed):
    topo = GridTopology(n_regions, sites_per_region,
                        lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=4 * GB, seed=seed)
    cat = ReplicaCatalog()
    stor = StorageState(cat, topo)
    for i in range(n_files):
        # round-robin master placement: a stride that shares a factor with
        # n_sites would pile >4 masters (3.6 GB+) onto one 4 GB SE and make
        # the initial state itself violate the capacity invariant
        m = i % topo.n_sites
        cat.register_file(f"f{i}", 0.9 * GB, m)
        stor.bootstrap(m, f"f{i}")
    return topo, cat, stor


@settings(max_examples=40, deadline=None)
@given(
    n_regions=st.integers(2, 4),
    spr=st.integers(2, 5),
    n_files=st.integers(4, 12),
    strategy=st.sampled_from(["hrs", "bhr", "lru"]),
    ops=st.lists(st.tuples(st.integers(0, 11), st.integers(0, 19)),
                 min_size=1, max_size=60),
)
def test_storage_invariants_under_random_fetches(n_regions, spr, n_files,
                                                 strategy, ops):
    """Whatever sequence of fetches runs: SEs never overflow, masters are
    never destroyed, the catalog matches storage, pinned files survive."""
    topo, cat, stor = build_world(n_regions, spr, n_files, seed=1)
    strat = make_strategy(strategy, cat, topo, stor)
    now = 0.0
    for fi, si in ops:
        now += 1.0
        lfn = f"f{fi % n_files}"
        dst = si % topo.n_sites
        if stor.holds(dst, lfn):
            stor.touch(dst, lfn, now)
            continue
        plan = strat.plan_fetch(lfn, dst)
        # source must actually hold the file
        assert cat.has_replica(plan.lfn, plan.src)
        for victim in plan.evictions:
            assert stor.evictable(dst, victim)
            stor.remove(dst, victim)
        if plan.store:
            stor.add(dst, lfn, now)
        # invariants
        for s in topo.sites:
            assert s.used_storage <= s.storage_capacity + 1e-6
        for f in cat.files.values():
            assert cat.has_replica(f.lfn, f.master_site), "master destroyed"
            for h in cat.holders(f.lfn):
                assert stor.holds(h, f.lfn)


@settings(max_examples=30, deadline=None)
@given(
    replica_spread=st.lists(st.tuples(st.integers(0, 9), st.integers(0, 11)),
                            min_size=0, max_size=30),
    loads=st.lists(st.floats(0, 1e11), min_size=12, max_size=12),
    req=st.sets(st.integers(0, 9), min_size=1, max_size=6),
)
def test_scheduler_is_argmax_bytes_then_min_load(replica_spread, loads, req):
    """The paper's policy, checked against a brute-force oracle."""
    topo, cat, stor = build_world(3, 4, 10, seed=2)
    for fi, si in replica_spread:
        lfn = f"f{fi}"
        site = si % topo.n_sites
        if not cat.has_replica(lfn, site):
            cat.add_replica(lfn, site)
    for s, load in zip(topo.sites, loads):
        s.queued_work = load
    required = [f"f{i}" for i in sorted(req)]
    sched = make_scheduler("dataaware", cat, topo)
    pick = sched.select_site(Job(0, 0, required, 1.0))
    best = max(cat.bytes_at_site(required, s.site_id) for s in topo.sites)
    ties = [s.site_id for s in topo.sites
            if cat.bytes_at_site(required, s.site_id) == best]
    oracle = min(ties, key=lambda s: (topo.sites[s].relative_load(), s))
    assert pick == oracle
    assert cat.bytes_at_site(required, pick) == best


@settings(max_examples=20, deadline=None)
@given(
    data=st.data(),
)
def test_hrs_region_priority_property(data):
    """Whenever ANY replica exists in the destination's region, HRS never
    crosses the WAN (paper §3.3 top priority)."""
    topo, cat, stor = build_world(3, 3, 8, seed=3)
    # scatter extra replicas
    n_extra = data.draw(st.integers(0, 15))
    for _ in range(n_extra):
        fi = data.draw(st.integers(0, 7))
        si = data.draw(st.integers(0, topo.n_sites - 1))
        if not cat.has_replica(f"f{fi}", si):
            cat.add_replica(f"f{fi}", si)
            stor.bootstrap(si, f"f{fi}", now=0.0)
    strat = make_strategy("hrs", cat, topo, stor)
    fi = data.draw(st.integers(0, 7))
    dst = data.draw(st.integers(0, topo.n_sites - 1))
    lfn = f"f{fi}"
    if stor.holds(dst, lfn):
        return
    plan = strat.plan_fetch(lfn, dst)
    region = topo.region_of(dst)
    in_region = [h for h in cat.holders(lfn)
                 if topo.region_of(h) == region and h != dst]
    if in_region:
        assert not plan.inter_region
        assert plan.src in in_region


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_simulator_determinism(seed):
    from repro.core import GridConfig, run_experiment
    cfg = GridConfig(seed=seed % 7)
    a = run_experiment(cfg, strategy="hrs", n_jobs=30)
    b = run_experiment(cfg, strategy="hrs", n_jobs=30)
    assert a.avg_job_time == b.avg_job_time
    assert a.avg_inter_comms == b.avg_inter_comms


@settings(max_examples=8, deadline=None)
@given(
    n_regions=st.integers(2, 3),
    spr=st.integers(2, 4),
    n_jobs=st.integers(5, 30),
    strategy=st.sampled_from(["hrs", "bhr", "lru"]),
    seed=st.integers(0, 4),
)
def test_device_engine_matches_numpy(n_regions, spr, n_jobs, strategy, seed):
    """The batched ``device`` engine vs the bit-exact numpy oracle on
    random small worlds: integer results agree *exactly* (same jobs
    complete), continuous metrics agree within the eta-reconstruction
    tolerance (the engine rebuilds remaining bytes as rate * (eta - now),
    which drifts by ulps from stepwise integration — the honest fidelity
    break golden_tolerance.json pins on the paper grid)."""
    from repro.core import GridConfig, run_experiment
    cfg = GridConfig(n_regions=n_regions, sites_per_region=spr, seed=seed)
    a = run_experiment(cfg, strategy=strategy, n_jobs=n_jobs, net="numpy")
    b = run_experiment(cfg, strategy=strategy, n_jobs=n_jobs, net="device")
    assert b.completed_jobs == a.completed_jobs == n_jobs
    assert b.total_inter_comms == a.total_inter_comms
    for metric in ("avg_job_time", "makespan", "total_wan_gb"):
        assert getattr(b, metric) == pytest.approx(getattr(a, metric),
                                                   rel=1e-9), metric


@settings(max_examples=6, deadline=None)
@given(
    n_jobs=st.integers(4, 24),
    strategy=st.sampled_from(["hrs", "lru"]),
    seed=st.integers(0, 3),
)
def test_device_engine_event_invariants(n_jobs, strategy, seed):
    """Engine invariants observed at every handled event of a batched
    run: the event clock never goes backwards, and no in-flight transfer
    is ever overdue by more than the done-epsilon (its cached completion
    time is honored — equivalently, no reconstructed backlog goes
    negative past the epsilon)."""
    from repro.core import GridConfig
    from repro.core.network import _DONE_EPS
    from repro.core.simulator import GridSimulator
    from repro.core.workload import build_catalog, build_topology, generate_jobs

    cfg = GridConfig(n_regions=2, sites_per_region=3, seed=seed)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy=strategy, seed=seed, net="device")
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    for j, job in enumerate(generate_jobs(cfg, n_jobs)):
        sim.submit_job(job, at=j * cfg.interarrival)

    import numpy as np
    clock = []
    orig_handle = sim._handle

    def spy(kind, payload):
        clock.append(sim.now)
        net = sim.network
        live = net.active & (net.rate > 0.0)
        overdue = net.rate[live] * (sim.now - net.eta[live])
        assert (overdue <= _DONE_EPS * (1 + 1e-12)).all()
        orig_handle(kind, payload)

    sim._handle = spy
    res = sim.run()
    assert clock == sorted(clock)
    assert res.completed_jobs == n_jobs
