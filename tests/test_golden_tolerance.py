"""Tolerance-golden harness for the batched ``device`` engine.

Two-tier golden contract (docs/ARCHITECTURE.md):

* tier 1 — the incremental numpy/pallas backends are pinned *bit-exactly*
  by tests/test_golden_metrics.py against ``golden_metrics.json``;
* tier 2 — the batched ``device`` engine reconstructs remaining bytes
  from cached completion times (``rate * (eta - now)``) instead of
  integrating them stepwise. That is a deliberate, ulp-level fidelity
  break: this suite pins it inside per-metric *relative-error* bounds
  (``golden_tolerance.json``) measured against the tier-1 goldens over
  the full fig4/fig5 paper grid plus the deep_contended tree cell.

The bounds are asserted tight from both sides: each is ``headroom``x the
maximum drift observed at pinning time, and the slow full-grid sweep
also fails when the observed drift *improves* past 10x under its bound —
a vacuously loose tolerance is a stale contract, re-pin it instead.
``completed_jobs`` carries a zero bound: the engines must finish exactly
the same jobs everywhere.
"""

import dataclasses
import json
import os

import pytest

from repro.core import GridConfig, SCENARIOS, run_experiment
from repro.launch.experiments import run_spec

_HERE = os.path.dirname(__file__)
TOL = json.load(open(os.path.join(_HERE, "golden_tolerance.json")))
GOLDEN = json.load(open(os.path.join(_HERE, "golden_metrics.json")))["metrics"]
GOLDEN_DEEP = json.load(open(os.path.join(_HERE, "golden_deep.json")))

BOUNDS = TOL["bounds"]
METRICS = ("avg_job_time", "avg_inter_comms", "makespan")


def _rel(got: float, want: float) -> float:
    if got == want:
        return 0.0
    return abs(got - want) / max(abs(got), abs(want))


def _drift(key: str, net: str = "device") -> dict:
    """Run one golden cell under the batched engine; relative error per
    metric against the bit-exact numpy pin. completed_jobs is checked
    here (bound 0 = integer-exact on every cell)."""
    if key == "deep_contended":
        g = GOLDEN_DEEP["metrics"]
        spec = dataclasses.replace(SCENARIOS[GOLDEN_DEEP["scenario"]], net=net)
        r = run_spec(spec, n_jobs=GOLDEN_DEEP["n_jobs"])
        assert r.completed_jobs == g["completed_jobs"], key
    else:
        _, strategy, n = key.split("/")
        n = int(n)
        cfg = GridConfig(n_jobs=n) if key.startswith("fig5") else GridConfig()
        r = run_experiment(cfg, strategy=strategy, n_jobs=n, net=net)
        g = GOLDEN[key]
        assert r.completed_jobs == n, key
    return {m: _rel(getattr(r, m), g[m]) for m in METRICS}


def test_tolerance_file_shape():
    assert set(TOL["cells"]) >= set(TOL["fast_cells"])
    assert set(TOL["cells"]) == set(GOLDEN) | {"deep_contended"}
    assert BOUNDS["completed_jobs"] == 0.0
    for m in METRICS:
        assert 0.0 <= BOUNDS[m] < 1e-6, (m, "bound is not tight")


@pytest.mark.parametrize("key", TOL["fast_cells"])
def test_device_tolerance_fast_cells(key):
    for metric, err in _drift(key).items():
        assert err <= BOUNDS[metric], (key, metric, err)


@pytest.mark.slow
def test_device_tolerance_full_grid_and_bounds_stay_tight():
    """Every cell of the full grid inside its bound — and the pinned
    bounds still tight: observed max drift per metric at least bound/10
    (nonzero bounds only; a zero bound already demands exact equality)."""
    worst = {m: 0.0 for m in METRICS}
    for key in TOL["cells"]:
        for metric, err in _drift(key).items():
            assert err <= BOUNDS[metric], (key, metric, err)
            worst[metric] = max(worst[metric], err)
    for metric, w in worst.items():
        if BOUNDS[metric] > 0.0:
            assert w >= BOUNDS[metric] / 10.0, (
                metric, w, "drift improved past 10x headroom — re-pin "
                "golden_tolerance.json")


@pytest.mark.slow
def test_device_interpret_tolerance_cell():
    """One cell through the actual Pallas interpreter (x64): the fused
    flush *kernel* — not just its numpy oracle — keeps the run inside the
    tolerance contract. (Unlike the incremental backends this is not
    bit-identical to ``device``: the kernel route re-rates every slot on
    every flush, the host route only the dirty neighborhood, so their
    rounding histories differ — both must land inside the same bounds.)"""
    for metric, err in _drift("fig4/hrs/100", net="device-interpret").items():
        assert err <= BOUNDS[metric], (metric, err)
