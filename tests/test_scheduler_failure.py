"""Scheduling under site failure (the ShortestTransfer crash regression).

Seed bug: ``ShortestTransferScheduler.cost`` did ``max()`` over the online
holders of a file — when the only holder (the master) was offline the list
was empty and ``max()`` raised ValueError. Masters are durable (the paper
assumes the master site always has a safe copy), so they must stay
fetchable while their site is down.
"""

import pytest

from repro.core import (GridTopology, Job, ReplicaCatalog, StorageState,
                        make_scheduler, run_experiment, GridConfig)

GB = 1e9


def build():
    topo = GridTopology(2, 2, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=100 * GB)
    cat = ReplicaCatalog()
    st = StorageState(cat, topo)
    return topo, cat, st


def test_shortest_transfer_sole_holder_offline():
    topo, cat, st = build()
    cat.register_file("f", 1 * GB, 0)     # master (sole copy) at site 0
    st.bootstrap(0, "f")
    topo.sites[0].online = False          # every holder of "f" is offline
    sched = make_scheduler("shortesttransfer", cat, topo)
    site = sched.select_site(Job(0, 0, ["f"], length=1e9))
    assert site in (1, 2, 3)              # no crash, an online site chosen


def test_shortest_transfer_prefers_replica_holder():
    topo, cat, st = build()
    cat.register_file("big", 10 * GB, 0)
    st.bootstrap(0, "big")
    sched = make_scheduler("shortesttransfer", cat, topo)
    # site 0 needs no transfer at all -> minimal cost
    assert sched.select_site(Job(0, 0, ["big"], length=1e9)) == 0


def test_shortest_transfer_survives_injected_failure():
    """End-to-end: mid-run site failure with the shortesttransfer policy —
    the seed engine crashed inside cost(); now every job must complete."""
    cfg = GridConfig(n_regions=2, sites_per_region=3)
    res = run_experiment(cfg, scheduler="shortesttransfer", strategy="hrs",
                         n_jobs=60, failures=[(0, 500.0, 4000.0),
                                              (4, 2500.0, 3000.0)])
    assert res.completed_jobs == res.n_jobs == 60
    assert res.avg_job_time > 0


def test_dataaware_survives_injected_failure():
    res = run_experiment(GridConfig(n_regions=2, sites_per_region=3),
                         scheduler="dataaware", strategy="hrs", n_jobs=60,
                         failures=[(1, 1000.0, 5000.0)])
    assert res.completed_jobs == res.n_jobs == 60
