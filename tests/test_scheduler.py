"""Paper §3.2 scheduling algorithm."""

from repro.core import (GridTopology, Job, ReplicaCatalog, StorageState,
                        make_scheduler)

GB = 1e9


def build():
    topo = GridTopology(2, 3, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=100 * GB,
                        compute_capacities=[1e9, 2e9, 4e9, 1e9, 2e9, 4e9])
    cat = ReplicaCatalog()
    st = StorageState(cat, topo)
    return topo, cat, st


def test_selects_max_bytes_site():
    topo, cat, st = build()
    cat.register_file("a", 1 * GB, 0)
    st.bootstrap(0, "a")
    cat.register_file("b", 2 * GB, 3)
    st.bootstrap(3, "b")
    sched = make_scheduler("dataaware", cat, topo)
    job = Job(1, 0, ["a", "b"], length=1e9)
    assert sched.select_site(job) == 3        # 2 GB beats 1 GB


def test_tie_break_min_relative_load():
    topo, cat, st = build()
    cat.register_file("a", 1 * GB, 0)
    st.bootstrap(0, "a")
    cat.register_file("a2", 1 * GB, 1)
    st.bootstrap(1, "a2")
    # both sites hold 1 GB of the required set; site 0 cap 1e9 / site 1 cap 2e9
    topo.sites[0].queued_work = 2e9           # rel = 2.0
    topo.sites[1].queued_work = 2e9           # rel = 1.0  -> wins
    sched = make_scheduler("dataaware", cat, topo)
    job = Job(1, 0, ["a", "a2"], length=1e9)
    assert sched.select_site(job) == 1


def test_offline_sites_excluded():
    topo, cat, st = build()
    cat.register_file("a", 1 * GB, 0)
    st.bootstrap(0, "a")
    topo.sites[0].online = False
    sched = make_scheduler("dataaware", cat, topo)
    job = Job(1, 0, ["a"], length=1e9)
    assert sched.select_site(job) != 0


def test_jaxsched_matches_python():
    import random

    from repro.core import (GridConfig, build_catalog, build_topology,
                            generate_jobs)
    from repro.core.jaxsched import JaxScheduler
    cfg = GridConfig(seed=3)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    rng = random.Random(0)
    # random replica spread + loads
    for lfn in list(cat.files)[:40]:
        cat.add_replica(lfn, rng.randrange(topo.n_sites))
    for s in topo.sites:
        s.queued_work = rng.random() * 1e10
    py = make_scheduler("dataaware", cat, topo)
    jx = JaxScheduler(cat, topo)
    for job in generate_jobs(cfg, 25):
        assert py.select_site(job) == jx.select(job.required)
