"""Pallas kernel validation: interpret=True vs pure-jnp oracles, sweeping
shapes and dtypes (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.event_engine import event_engine, event_engine_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.net_rerate import net_rerate, net_rerate_ref
from repro.kernels.selective_scan.kernel import selective_scan_kernel
from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.st_cost import st_cost, st_cost_dense_ref, st_cost_ref
from repro.kernels.strategy_plan import strategy_plan
from repro.kernels.value_score import value_score, value_score_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,Sq,Skv,hd,causal,window,softcap",
    [
        (2, 4, 2, 128, 128, 64, True, None, None),
        (1, 4, 4, 256, 256, 32, True, None, 50.0),
        (2, 2, 1, 96, 192, 16, False, None, None),     # cross, GQA 2:1
        (1, 8, 4, 256, 256, 64, True, 64, None),       # sliding window
        (1, 2, 2, 64, 64, 128, True, None, None),
        (2, 6, 3, 80, 144, 32, True, None, None),      # ragged sizes (pad)
    ],
)
def test_flash_attention_matches_oracle(B, H, KV, Sq, Skv, hd, causal,
                                        window, softcap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Skv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Skv, hd)).astype(dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=64, block_k=64,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_decode_mode():
    """q_offset + kv_len emulate one-token decode against a padded cache."""
    B, H, KV, hd, S = 1, 4, 2, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, 1, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=True, q_offset=99,
                                 kv_len=100, block_q=8, block_k=64,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, q_offset=99, kv_len=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "Bz,S,Di,N,chunk,bd",
    [
        (2, 64, 32, 8, 16, 16),
        (1, 128, 64, 16, 32, 32),
        (2, 96, 48, 4, 32, 16),
        (1, 256, 128, 16, 64, 128),
    ],
)
def test_selective_scan_matches_oracle(Bz, S, Di, N, chunk, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    x = jax.random.normal(ks[0], (Bz, S, Di)).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, Di))) * 0.1
          ).astype(dtype)
    B = jax.random.normal(ks[2], (Bz, S, N)).astype(dtype)
    C = jax.random.normal(ks[3], (Bz, S, N)).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (Di, N)) * 0.3)
    D = jax.random.normal(ks[5], (Di,))
    h0 = jax.random.normal(ks[6], (Bz, Di, N))
    y1, h1 = selective_scan_kernel(x, dt, B, C, A, D, h0, chunk=chunk,
                                   block_d=bd, interpret=True)
    y2, h2 = selective_scan_ref(x, dt, B, C, A, D, h0)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10)


def _net_rerate_case(seed, slots, links, levels):
    """Random but realistic re-rate inputs: every slot crosses a NIC-like
    first link plus 0..levels-1 uplinks."""
    rng = np.random.default_rng(seed)
    path = np.where(rng.random((slots, levels)) < 0.35, -1,
                    rng.integers(0, links, (slots, levels)))
    path[:, 0] = rng.integers(0, links, slots)
    rem = rng.random(slots) * 1e9
    bw = rng.random(links) * 1e8 + 1e5
    act = rng.integers(0, 12, links).astype(float)
    return path, rem, bw, act


@pytest.mark.parametrize("seed,slots,links,levels", [
    (0, 1, 4, 2),            # single transfer, two-level shape
    (1, 37, 23, 4),          # ragged (pads to lane/sublane multiples)
    (2, 256, 60, 5),         # deep 5-tier path shape
    (3, 1000, 500, 3),       # wide link space
])
def test_net_rerate_interpret_matches_oracle(seed, slots, links, levels):
    """The Pallas re-rate kernel under x64 interpret mode is *bit-identical*
    to the float64 numpy oracle (divide/min are exact IEEE ops) — the same
    contract the golden-metrics suite pins end-to-end."""
    path, rem, bw, act = _net_rerate_case(seed, slots, links, levels)
    rate_ref, eta_ref = net_rerate_ref(path, rem, bw, act, now=321.5)
    rate_k, eta_k = net_rerate(path, rem, bw, act, 321.5, backend="interpret")
    assert np.array_equal(rate_k, rate_ref)
    assert eta_k == eta_ref


def _event_engine_case(seed, slots, links, levels):
    """Mixed slot-lifecycle flush inputs: ~1/4 released (all-hole path,
    zeroed state), ~1/3 freshly allocated (no cached rate — rem is read
    verbatim), the rest carried from a previous flush with finite
    (rate, eta)."""
    rng = np.random.default_rng(seed)
    path = np.where(rng.random((slots, levels)) < 0.35, -1,
                    rng.integers(0, links, (slots, levels)))
    path[:, 0] = rng.integers(0, links, slots)
    freed = rng.random(slots) < 0.25
    path[freed] = -1
    rem = rng.random(slots) * 1e9
    rate = rng.random(slots) * 1e7 + 1.0
    fresh = rng.random(slots) < 0.3
    rate[fresh | freed] = 0.0
    rem[freed] = 0.0
    eta = 321.5 + rng.random(slots) * 5e3
    eta[rate == 0.0] = np.inf
    bw = rng.random(links) * 1e8 + 1e5
    act = rng.integers(0, 12, links).astype(float)
    return path, rem, rate, eta, bw, act


@pytest.mark.parametrize("seed,slots,links,levels", [
    (0, 1, 4, 2),            # single transfer, two-level shape
    (1, 37, 23, 4),          # ragged (pads to lane/sublane multiples)
    (2, 256, 60, 5),         # deep 5-tier path shape
    (3, 1000, 500, 3),       # wide link space
])
def test_event_engine_interpret_matches_oracle(seed, slots, links, levels):
    """The fused event-engine flush kernel (share -> gather-min re-rate ->
    eta reconstruction -> running-min next completion) under x64
    interpret mode is *bit-identical* to the float64 numpy oracle — the
    net_rerate contract extended to the batched engine's once-per-instant
    pass that golden_tolerance.json pins end-to-end."""
    path, rem, rate, eta, bw, act = _event_engine_case(seed, slots, links,
                                                       levels)
    ref = event_engine_ref(path, rem, rate, eta, bw, act, 321.5)
    out = event_engine(path, rem, rate, eta, bw, act, 321.5,
                       backend="interpret")
    for got, want in zip(out[:3], ref[:3]):
        assert np.array_equal(got, want)
    assert out[3] == ref[3]


def test_event_engine_all_released_returns_inf():
    """A flush over nothing but released slots rates everything to zero
    and reports no next completion (eta_min = inf)."""
    path = np.full((8, 3), -1, np.int64)
    z = np.zeros(8)
    eta = np.full(8, np.inf)
    bw = np.ones(4) * 1e6
    act = np.zeros(4)
    for backend in ("numpy", "interpret"):
        rem, rate, eta_new, eta_min = event_engine(
            path, z, z, eta, bw, act, 10.0, backend=backend)
        assert (rate == 0.0).all() and (rem == 0.0).all()
        assert np.isinf(eta_new).all() and np.isinf(eta_min)


def test_net_rerate_auto_backend_on_cpu_is_exact():
    """backend='auto' off-TPU routes to the float64 oracle — the fast path
    the net='pallas' engine uses per event on this container."""
    path, rem, bw, act = _net_rerate_case(7, 64, 30, 3)
    rate_ref, eta_ref = net_rerate_ref(path, rem, bw, act, 0.0)
    rate_a, eta_a = net_rerate(path, rem, bw, act, 0.0, backend="auto")
    assert np.array_equal(rate_a, rate_ref)
    assert eta_a == eta_ref


def test_net_rerate_empty_and_padding_rows():
    rate, eta = net_rerate_ref(np.zeros((0, 3), int), np.zeros(0),
                               np.ones(4), np.zeros(4), 5.0)
    assert rate.shape == (0,) and eta == float("inf")
    # an all-padding row gets rate 0 and never drives the eta scan
    path = np.array([[0, -1], [-1, -1]])
    rate, eta = net_rerate_ref(path, np.array([10.0, 10.0]),
                               np.array([2.0]), np.array([1.0]), 1.0)
    assert rate[1] == 0.0
    assert eta == pytest.approx(1.0 + 10.0 / 2.0)


def test_net_rerate_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        net_rerate(np.zeros((1, 1), int), np.ones(1), np.ones(1),
                   np.ones(1), 0.0, backend="cuda")


def _value_score_case(seed, sites, files):
    """Random but realistic scorer inputs: sparse holders, bandwidths in
    the paper's LAN/WAN range, decayed-count-shaped demand."""
    rng = np.random.default_rng(seed)
    demand = rng.random((sites, files)) * 20.0
    sizes = rng.random(files) * 1e9 + 1e6
    presence = rng.random((sites, files)) < 0.25
    presence[0, :] = True                       # every file has a holder row
    bw = rng.random((sites, sites)) * 1.25e8 + 1e5
    return demand, sizes, presence, bw


@pytest.mark.parametrize("mode", ["cost", "plain"])
@pytest.mark.parametrize("seed,sites,files", [
    (0, 4, 8),               # tiny (heavy sublane/lane padding)
    (1, 13, 100),            # one paper region x the paper catalog
    (2, 52, 100),            # the full paper grid
    (3, 37, 260),            # ragged on both axes
])
def test_value_score_interpret_matches_oracle(seed, sites, files, mode):
    """The value-scoring kernel under x64 interpret mode is *bit-identical*
    to the float64 oracle (max/divide are exact IEEE ops and the
    max-reduction is order-independent) — the contract behind the
    ``econ='pallas-interpret'`` engine flag."""
    demand, sizes, presence, bw = _value_score_case(seed, sites, files)
    ref = value_score_ref(demand, sizes, presence, bw, mode=mode)
    out = value_score(demand, sizes, presence, bw, mode=mode,
                      backend="interpret")
    assert np.array_equal(out, ref)


def test_value_score_auto_backend_on_cpu_is_exact():
    demand, sizes, presence, bw = _value_score_case(7, 8, 24)
    ref = value_score_ref(demand, sizes, presence, bw)
    out = value_score(demand, sizes, presence, bw, backend="auto")
    assert np.array_equal(out, ref)


def test_value_score_self_supply_and_no_holder():
    """A file whose only holder is the destination itself scores its
    re-fetch-if-dropped value via *other* holders only; with no other
    holder it scores 0 (nothing to buy)."""
    demand = np.full((2, 2), 5.0)
    sizes = np.array([1e6, 1e6])
    presence = np.array([[True, True], [False, True]])
    bw = np.array([[10.0, 20.0], [30.0, 40.0]])
    v = value_score_ref(demand, sizes, presence, bw, mode="cost")
    assert v[0, 0] == 0.0                     # sole holder is site 0 itself
    assert v[0, 1] == pytest.approx(5.0 * 1e6 / 30.0)   # from site 1
    assert v[1, 0] == pytest.approx(5.0 * 1e6 / 20.0)   # from site 0
    plain = value_score_ref(demand, sizes, presence, bw, mode="plain")
    assert plain[0, 0] == 0.0 and plain[1, 0] == 5.0


def test_value_score_empty_and_errors():
    assert value_score_ref(np.zeros((0, 3)), np.ones(3),
                           np.zeros((0, 3), bool),
                           np.zeros((0, 0))).shape == (0, 3)
    with pytest.raises(ValueError, match="mode"):
        value_score_ref(np.zeros((1, 1)), np.ones(1),
                        np.ones((1, 1), bool), np.ones((1, 1)), mode="nope")
    with pytest.raises(ValueError, match="backend"):
        value_score(np.zeros((1, 1)), np.ones(1), np.ones((1, 1), bool),
                    np.ones((1, 1)), backend="cuda")


def _st_cost_case(seed, sites, files, jobs):
    """Random but realistic broker-batch inputs: sparse holders, some
    offline sites, durable-master fetchability, LAN/WAN-range bandwidths,
    12-ish-file requirement rows."""
    rng = np.random.default_rng(seed)
    bw = rng.random((sites, sites)) * 1.25e8 + 1e5
    presence = rng.random((sites, files)) < 0.2
    presence[0, :] = True                       # every file has a holder row
    online = rng.random(sites) < 0.85
    online[0] = True
    fetch_mask = presence & online[:, None]
    fetch_mask[0, :] = presence[0, :]           # site 0 plays durable master
    sizes = rng.random(files) * 1e9 + 1e6
    required = rng.random((jobs, files)) < min(0.5, 12.0 / files)
    rel = rng.random(sites) * 50.0
    return bw, fetch_mask, presence, sizes, required, rel, online


@pytest.mark.parametrize("seed,sites,files,jobs", [
    (0, 4, 8, 3),            # tiny (heavy sublane/lane padding)
    (1, 13, 100, 17),        # one paper region x the paper catalog
    (2, 52, 100, 50),        # the full paper grid x a bulk burst
    (3, 37, 260, 9),         # ragged on every axis
])
def test_st_cost_interpret_matches_oracle(seed, sites, files, jobs):
    """The blocked st-cost kernel under x64 interpret mode is
    *bit-identical* to the float64 oracle: the holder max is
    order-independent, max/divide are exact IEEE ops, and the file sum
    runs sequentially over ascending file index in both."""
    case = _st_cost_case(seed, sites, files, jobs)
    ref = st_cost_ref(*case)
    out = st_cost(*case, backend="interpret")
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("seed,sites,files,jobs", [
    (0, 4, 8, 3), (2, 52, 100, 50), (3, 37, 260, 9),
])
def test_st_cost_blocked_matches_dense(seed, sites, files, jobs):
    """The blocked pass equals the pre-blocked dense reduction (the
    ``(sites, files, sites)`` broadcast the old broker materialized) bit
    for bit — skipping exact-zero terms of a nonnegative running sum and
    reordering an exact max change nothing."""
    case = _st_cost_case(seed, sites, files, jobs)
    assert np.array_equal(st_cost_ref(*case), st_cost_dense_ref(*case))


def test_st_cost_auto_backend_on_cpu_is_exact():
    """backend='auto' off-TPU routes to the float64 oracle — the fast
    path the jitted shortesttransfer broker uses per dispatch batch."""
    case = _st_cost_case(7, 8, 24, 5)
    assert np.array_equal(st_cost(*case, backend="auto"),
                          st_cost_ref(*case))


def test_st_cost_guards_and_edges():
    """Zero-bandwidth guard (missing file with no fetchable source costs
    inf), offline sites cost inf, empty batches and empty catalogs work."""
    bw = np.array([[5.0, 5.0], [5.0, 5.0]])
    presence = np.array([[True], [False]])
    fetch = np.zeros((2, 1), bool)              # nothing fetchable at all
    sizes = np.array([10.0])
    required = np.array([[True]])
    rel = np.array([0.25, 0.5])
    online = np.array([True, False])
    out = st_cost_ref(bw, fetch, presence, sizes, required, rel, online)
    assert out[0, 0] == 0.25                    # present locally: queue only
    assert out[0, 1] == np.inf                  # offline
    fetch = np.array([[True], [False]])
    out = st_cost_ref(bw, fetch, presence, sizes, required, rel,
                      np.array([True, True]))
    assert out[0, 1] == max(10.0 / 5.0, 0.5)    # fetched from site 0
    assert st_cost_ref(bw, fetch, presence, sizes,
                       np.zeros((0, 1), bool), rel,
                       online).shape == (0, 2)
    empty_args = (bw, np.zeros((2, 0), bool), np.zeros((2, 0), bool),
                  np.zeros(0), np.zeros((3, 0), bool), rel,
                  np.array([True, False]))
    empty = st_cost_ref(*empty_args)
    assert np.array_equal(empty, [[0.25, np.inf]] * 3)  # queue time only
    # the kernel route must survive a 0-wide file axis too (empty batch
    # union / empty catalog), bit-identically
    assert np.array_equal(st_cost(*empty_args, backend="interpret"), empty)
    with pytest.raises(ValueError, match="backend"):
        st_cost(bw, fetch, presence, sizes, required, rel, online,
                backend="cuda")


def _collect_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    _collect_avals(inner, out)
                elif hasattr(sub, "eqns"):
                    _collect_avals(sub, out)
    return out


def test_st_cost_kernel_never_materializes_rank3():
    """Shape guard on the blocked path: abstract evaluation of the whole
    kernel call (padding, pallas_call body, fori loops) must contain no
    rank-3 intermediate — the ``(sites, files, sites)`` /
    ``(jobs, files, sites)`` broadcasts are exactly what this kernel
    exists to avoid — and no buffer larger than the padded 2-D planes."""
    from repro.kernels.st_cost.kernel import st_cost_kernel
    sites, files, jobs = 52, 100, 50
    case = _st_cost_case(2, sites, files, jobs)
    bw, fetch_mask, presence, sizes, required, rel, online = [
        np.asarray(a, np.float32) for a in case]
    jaxpr = jax.make_jaxpr(
        lambda *a: st_cost_kernel(*a, interpret=True))(
            bw, fetch_mask, presence, sizes, required, rel, online)
    avals = _collect_avals(jaxpr.jaxpr, [])
    assert avals, "no intermediates collected — walker is broken"
    pad = 128
    plane = max((sites + pad) * (files + pad), (jobs + pad) * (sites + pad))
    for aval in avals:
        assert len(aval.shape) <= 2, f"rank-3 intermediate: {aval}"
        assert int(np.prod(aval.shape, dtype=np.int64)) <= plane, aval


def test_selective_scan_streaming_equivalence():
    """Scanning a sequence in two kernel calls (carrying h) == one call."""
    Bz, S, Di, N = 1, 64, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (Bz, S, Di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, Di))) * 0.1
    B = jax.random.normal(ks[2], (Bz, S, N))
    C = jax.random.normal(ks[3], (Bz, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (Di, N)) * 0.3)
    D = jax.random.normal(ks[5], (Di,))
    h0 = jnp.zeros((Bz, Di, N))
    y_full, h_full = selective_scan_ref(x, dt, B, C, A, D, h0)
    half = S // 2
    y1, h_mid = selective_scan_ref(x[:, :half], dt[:, :half], B[:, :half],
                                   C[:, :half], A, D, h0)
    y2, h_end = selective_scan_ref(x[:, half:], dt[:, half:], B[:, half:],
                                   C[:, half:], A, D, h_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_full),
                               atol=1e-5)


# -- strategy_plan: batched replica-strategy planning ----------------------

def _strategy_plan_case(seed, sites, pairs):
    """Random burst: forced holder per pair (masters are durable), block
    regions, half the sites carrying decayed serve load."""
    rng = np.random.default_rng(seed)
    bw = rng.random((sites, pairs)) * 1.25e8 + 1e5
    fetch = rng.random((sites, pairs)) < 0.15
    fetch[rng.integers(0, sites, pairs), np.arange(pairs)] = True
    n_regions = max(2, sites // 8)
    region = np.arange(sites) * n_regions // sites
    local = region[:, None] == rng.integers(0, n_regions, pairs)[None, :]
    serve = np.where(rng.random(sites) < 0.5, rng.random(sites) * 9.0, 0.0)
    size = rng.random(pairs) * 1e9 + 1e6
    free = np.where(rng.random(pairs) < 0.5,
                    rng.random(pairs) * 2e9, rng.random(pairs) * 1e8)
    return bw, fetch, local, serve, free, size


@pytest.mark.parametrize("seed,sites,pairs", [
    (0, 4, 3),              # tiny (heavy sublane/lane padding)
    (1, 13, 17),            # one paper region
    (2, 52, 50),            # the full paper grid x a bulk burst
    (3, 129, 50),           # ragged site axis, grid_500-burst pair count
    (4, 37, 260),           # ragged on both axes
])
def test_strategy_plan_interpret_matches_oracle(seed, sites, pairs):
    """The plan kernel under x64 interpret mode is *bit-identical* to the
    float64 oracle: where/divide/compare are exact IEEE ops and the
    strict-> running maximum is np.argmax's first occurrence."""
    case = _strategy_plan_case(seed, sites, pairs)
    ref = strategy_plan(*case, backend="numpy")
    out = strategy_plan(*case, backend="interpret")
    for got, want in zip(out, ref):
        assert np.array_equal(got, want)


def test_strategy_plan_auto_backend_on_cpu_is_exact():
    """backend='auto' off-TPU routes to the float64 oracle — the per-burst
    fast path ``strategy_mode="batch"`` uses."""
    case = _strategy_plan_case(7, 24, 9)
    ref = strategy_plan(*case, backend="numpy")
    out = strategy_plan(*case, backend="auto")
    for got, want in zip(out, ref):
        assert np.array_equal(got, want)


def test_strategy_plan_decisions_and_edges():
    """Hand-checkable burst: lowest-id tie-break, serve-load discount
    flipping a pick, region-local restriction, inter-region flag off the
    chosen row, store verdict, empty-burst shapes."""
    bw = np.array([[4.0, 8.0], [4.0, 2.0], [3.0, 9.0]])
    fetch = np.array([[True, True], [True, True], [False, True]])
    local = np.array([[False, False], [True, True], [True, False]])
    serve = np.zeros(3)
    free = np.array([5.0, 1.0])
    size = np.array([4.0, 2.0])
    src_g, src_l, has_l, inter_g, store_ok = strategy_plan(
        bw, fetch, local, serve, free, size, backend="numpy")
    assert list(src_g) == [0, 2]        # pair 0: 4.0 tie -> lowest id
    assert list(src_l) == [1, 1]        # region-restricted best
    assert list(has_l) == [True, True]
    assert list(inter_g) == [True, True]
    assert list(store_ok) == [True, False]
    # a serve load on site 2 flips pair 1's global pick to site 0
    src_g2, _, _, inter_g2, _ = strategy_plan(
        bw, fetch, local, np.array([0.0, 0.0, 1.0]), free, size,
        backend="numpy")
    assert list(src_g2) == [0, 0]
    assert list(inter_g2) == [True, True]
    # empty burst: all five outputs are 0-wide
    empty = strategy_plan(bw[:, :0], fetch[:, :0], local[:, :0], serve,
                          free[:0], size[:0], backend="numpy")
    assert all(o.shape == (0,) for o in empty)
    with pytest.raises(ValueError, match="backend"):
        strategy_plan(bw, fetch, local, serve, free, size, backend="bogus")
