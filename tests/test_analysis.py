"""Unit tests for the static-analysis package (repro.analysis).

One positive + one negative (or suppressed) case per simlint rule, the
coherence rules, suppression/baseline plumbing, the jaxpr kernel audit,
and the acceptance pin: the shipped ``src/repro`` tree lints clean with
zero baseline entries.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import RULES, Baseline, collect_files, run_analysis
from repro.analysis.coherence import lint_coherence
from repro.analysis.findings import (Finding, inline_suppressions,
                                     is_inline_suppressed)
from repro.analysis.simlint import lint_source

SRC_ROOT = Path(__file__).resolve().parents[1] / "src"


def rules_of(source: str, path: str = "repro/core/x.py") -> list[str]:
    src = textwrap.dedent(source)
    return [f.rule for f in lint_source(src, path) + lint_coherence(src, path)]


# -- SL001: set iteration ---------------------------------------------------

def test_sl001_flags_set_iteration():
    assert "SL001" in rules_of("""
        def f(s: set[int]):
            for x in s:
                print(x)
    """)


def test_sl001_flags_self_attr_set():
    assert "SL001" in rules_of("""
        class C:
            def __init__(self):
                self.pending: set[int] = set()
            def drain(self):
                return [x for x in self.pending]
    """)


def test_sl001_flags_set_returning_method():
    assert "SL001" in rules_of("""
        def f(catalog, lfn):
            return [h + 1 for h in catalog.holders(lfn)]
    """)


def test_sl001_sorted_wrap_is_clean():
    assert rules_of("""
        def f(s: set[int]):
            for x in sorted(s):
                print(x)
    """) == []


def test_sl001_order_free_consumers_are_clean():
    assert rules_of("""
        def f(s: set[int]):
            return any(x > 0 for x in s), len(s), set(s), bool(s)
    """) == []


def test_sl001_min_max_over_set_flagged():
    # conservative: min/max key-function ties resolve in encounter order
    assert "SL001" in rules_of("""
        def f(s: set[int], cost):
            return min(s, key=cost)
    """)


def test_sl001_dict_iteration_is_clean():
    assert rules_of("""
        def f(d: dict[int, str]):
            for k in d:
                print(k)
    """) == []


# -- SL002: global / unseeded PRNG ------------------------------------------

def test_sl002_flags_global_random():
    assert "SL002" in rules_of("""
        import random
        def f():
            return random.random()
    """)


def test_sl002_flags_np_random_module(tmp_path):
    assert "SL002" in rules_of("""
        import numpy as np
        def f():
            return np.random.rand(3)
    """)


def test_sl002_seeded_instances_are_clean():
    assert rules_of("""
        import random as _random
        import numpy as np
        def f(seed):
            rng = _random.Random(seed)
            g = np.random.default_rng(seed)
            return rng.random(), g.random()
    """) == []


# -- SL003: float reduction over unordered containers -----------------------

def test_sl003_flags_sum_over_set():
    assert "SL003" in rules_of("""
        def f(s: set[float]):
            return sum(s)
    """)


def test_sl003_sum_over_sorted_is_clean():
    assert rules_of("""
        def f(s: set[float]):
            return sum(sorted(s))
    """) == []


# -- SL004: id()/hash() in sort keys ----------------------------------------

def test_sl004_flags_id_in_sort_key():
    assert "SL004" in rules_of("""
        def f(items):
            return sorted(items, key=lambda j: id(j))
    """)


def test_sl004_domain_key_is_clean():
    assert rules_of("""
        def f(items):
            return sorted(items, key=lambda j: j.job_id)
    """) == []


# -- SL005: wall-clock reads in sim-state code ------------------------------

def test_sl005_flags_wall_clock_in_core():
    assert "SL005" in rules_of("""
        import time
        def f():
            return time.time()
    """, path="repro/core/x.py")


def test_sl005_scope_excludes_fault_instrumentation():
    # perf_counter in repro/fault is host-side instrumentation, not sim state
    assert "SL005" not in rules_of("""
        import time
        def f():
            return time.perf_counter()
    """, path="repro/fault/failures.py")


def test_sl005_obs_package_is_the_sanctioned_exemption():
    # the telemetry probe measures host phase time by design; SL014
    # guards the other direction (it cannot write engine state)
    src = """
        import time
        def f():
            return time.perf_counter()
    """
    assert "SL005" not in rules_of(src, path="repro/obs/probe.py")
    assert "SL005" in rules_of(src, path="repro/core/simulator.py")


# -- SL010: heappush tie key ------------------------------------------------

def test_sl010_flags_missing_seq_key():
    assert "SL010" in rules_of("""
        import heapq
        def f(q, t):
            heapq.heappush(q, (t, "payload"))
    """)


def test_sl010_seq_key_is_clean():
    assert rules_of("""
        import heapq
        def f(q, t, kind):
            self_seq = 0
            heapq.heappush(q, (t, self_seq, kind, None))
    """) == []


# -- SL011: catalog bypass --------------------------------------------------

def test_sl011_flags_holders_access_outside_catalog():
    assert "SL011" in rules_of("""
        def f(cat):
            cat._holders["lfn"].add(3)
    """)


def test_sl011_flags_notify_less_mutation_inside_catalog():
    assert "SL011" in rules_of("""
        class ReplicaCatalog:
            def _notify(self, *a): ...
            def silent_add(self, lfn, site):
                self._holders[lfn].add(site)
    """, path="repro/core/catalog.py")


def test_sl011_notifying_mutation_is_clean():
    assert rules_of("""
        class ReplicaCatalog:
            def _notify(self, *a): ...
            def add_replica(self, lfn, site):
                self._holders[lfn].add(site)
                self._notify("on_add_replica", lfn, site)
    """, path="repro/core/catalog.py") == []


# -- SL012: sync coherence --------------------------------------------------

_SYNC_CLASS = """
    class Mirror:
        def __init__(self, catalog):
            self.catalog = catalog
            self._n = 0
        def sync(self):
            self._table = dict(self.catalog.files)
            self._n = len(self._table)
        def {sig}:
            {body}
"""


def test_sl012_flags_unsynced_read():
    src = _SYNC_CLASS.format(sig="lookup(self, k)",
                             body="return self._table[k]")
    assert "SL012" in rules_of(src)


def test_sl012_synced_read_is_clean():
    src = _SYNC_CLASS.format(
        sig="lookup(self, k)",
        body="self.sync()\n            return self._table[k]")
    assert rules_of(src) == []


def test_sl012_private_and_listener_hooks_exempt():
    for sig in ("_peek(self, k)", "on_add_replica(self, k)"):
        src = _SYNC_CLASS.format(sig=sig, body="return self._table[k]")
        assert "SL012" not in rules_of(src), sig


def test_sl012_transitive_sync_counts():
    # calling a helper that itself syncs satisfies the rule
    src = textwrap.dedent("""
        class Mirror:
            def sync(self):
                self._table = {}
            def _fresh(self):
                self.sync()
                return self._table
            def lookup(self, k):
                return self._fresh()[k]
    """)
    assert "SL012" not in rules_of(src)


# -- SL014: obs callbacks are observation-only ------------------------------

def test_sl014_flags_mutating_call_on_parameter():
    assert "SL014" in rules_of("""
        class Sampler:
            def sample(self, sim):
                sim.records.append(1)
    """, path="repro/obs/series.py")


def test_sl014_flags_write_through_parameter():
    src = """
        def f(sim):
            sim.now = 0.0
            sim.network.link_act[0] += 1.0
            del sim._cpu_queue[0]
    """
    rules = rules_of(src, path="repro/obs/series.py")
    assert rules.count("SL014") == 3


def test_sl014_reads_and_self_mutation_are_clean():
    assert rules_of("""
        class Sampler:
            def sample(self, sim):
                n = len(sim.records) + sim.network.n_active
                self.ring.append((sim.now, float(n)))
    """, path="repro/obs/series.py") == []


def test_sl014_scoped_to_obs_package():
    # the same mutation outside repro/obs/ is not SL014's business
    assert "SL014" not in rules_of("""
        def f(sim):
            sim.records.append(1)
    """, path="repro/core/simulator.py")


# -- suppressions + baseline ------------------------------------------------

def test_inline_same_line_suppression():
    assert rules_of("""
        def f(s: set[int]):
            for x in s:  # simlint: disable=SL001
                print(x)
    """) != []  # lint_source itself still reports ...
    supp = inline_suppressions(textwrap.dedent("""
        def f(s: set[int]):
            for x in s:  # simlint: disable=SL001
                print(x)
    """))
    f = Finding("SL001", "p.py", 3, "m", "for x in s:")
    assert is_inline_suppressed(f, supp)
    assert not is_inline_suppressed(
        Finding("SL003", "p.py", 3, "m", ""), supp)


def test_inline_next_line_and_blanket_suppression():
    supp = inline_suppressions(
        "# simlint: disable-next-line=SL010\nx = 1\n# simlint: disable\ny = 2\n")
    assert is_inline_suppressed(Finding("SL010", "p", 2, "m", ""), supp)
    assert is_inline_suppressed(Finding("SL999", "p", 3, "m", ""), supp)


def test_baseline_roundtrip_and_line_stability(tmp_path):
    f1 = Finding("SL001", "repro/core/x.py", 10, "m", "for x in s:")
    f2 = Finding("SL001", "repro/core/x.py", 99, "m", "for  x  in s:")
    path = tmp_path / "baseline.json"
    Baseline().write(path, [f1])
    loaded = Baseline.load(path)
    assert f1 in loaded
    # fingerprints hash the normalized snippet, not the line number
    assert f2 in loaded
    assert Finding("SL003", "repro/core/x.py", 10, "m", "for x in s:") \
        not in loaded
    assert json.loads(path.read_text())["version"] == 1


# -- acceptance pins --------------------------------------------------------

def test_shipped_tree_lints_clean():
    """The acceptance criterion: src/repro carries zero unsuppressed
    findings and zero baseline entries."""
    new, baselined, _ = run_analysis()
    assert new == [], "\n".join(f.render() for f in new)
    assert baselined == []


def test_collect_files_covers_tree():
    files = collect_files()
    assert len(files) > 50
    assert all(f.suffix == ".py" for f in files)


def test_rule_catalog_matches_emitted_rules():
    emitted = {"SL001", "SL002", "SL003", "SL004", "SL005", "SL010",
               "SL011", "SL012", "SL013", "SL014"}
    assert emitted <= set(RULES)


def test_cli_clean_run_exits_zero():
    env = dict(os.environ, PYTHONPATH=str(SRC_ROOT))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-jaxpr",
         "--fail-on-findings"],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# -- kernel registry (satellite: uniform packages) --------------------------

KERNEL_NAMES = {"net_rerate", "event_engine", "st_cost", "value_score",
                "selective_scan", "flash_attention", "strategy_plan"}


def test_registry_discovers_all_kernels():
    from repro.kernels import registered_kernels
    regs = registered_kernels()
    assert set(regs) == KERNEL_NAMES
    for name, spec in regs.items():
        assert spec.name == name
        assert spec.module == f"repro.kernels.{name}"
        assert spec.domain in ("sim", "model")
        assert spec.budget_bytes > 0


def test_kernel_spec_import_is_jax_free():
    """The registry must be importable on hosts without jax (the DES
    engine's numpy paths import kernel packages for their SPECs)."""
    env = dict(os.environ, PYTHONPATH=str(SRC_ROOT))
    code = ("import sys; import repro.kernels as k; k.registered_kernels(); "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr


# -- jaxpr audit ------------------------------------------------------------

jax = pytest.importorskip("jax")


def test_jaxpr_audit_single_kernel_ok():
    from repro.analysis.jaxpr_audit import audit_kernel
    from repro.kernels import get_kernel_spec
    entry = audit_kernel(get_kernel_spec("net_rerate"))
    assert entry["ok"], entry["checks"]
    assert entry["max_rank"] <= 2
    assert entry["checks"]["oracle_f64"]
    assert entry["checks"]["x64_interpret_identity"]


def test_jaxpr_audit_event_engine_pinned():
    """The batched event-engine flush kernel is registered and gated by
    the auditor with the same sim-kernel contract as net_rerate: rank
    ceiling 2 (no dense (slots, links, ·) materialization), a tight byte
    budget at the audit shapes, no host callbacks, and x64-interpret
    bit-identity against its float64 oracle — the intra-route half of
    the two-tier golden contract (the inter-engine half lives in
    tests/golden_tolerance.json)."""
    from repro.analysis.jaxpr_audit import audit_kernel
    from repro.kernels import get_kernel_spec
    spec = get_kernel_spec("event_engine")
    assert spec.domain == "sim"
    assert spec.max_rank == 2
    assert spec.multi_output
    entry = audit_kernel(spec)
    assert entry["ok"], entry["checks"]
    assert entry["callbacks"] == []
    assert entry["peak_eqn_bytes"] <= spec.budget_bytes
    assert entry["checks"]["oracle_f64"]
    assert entry["checks"]["x64_interpret_identity"]


@pytest.mark.slow
def test_jaxpr_audit_all_kernels_ok(tmp_path):
    from repro.analysis.jaxpr_audit import run_jaxpr_audit
    report, failures = run_jaxpr_audit(tmp_path / "kernels.json")
    assert failures == []
    assert set(report["kernels"]) == KERNEL_NAMES
    assert (tmp_path / "kernels.json").exists()


def _fake_spec(fn, *, max_rank=2, budget=10**9, shapes=((8, 8), (8, 8))):
    import types

    import numpy as np

    def make_inputs():
        rng = np.random.default_rng(0)
        return tuple(rng.random(s).astype(np.float32) for s in shapes), {}

    return types.SimpleNamespace(
        name="fake", domain="model", max_rank=max_rank, budget_bytes=budget,
        load_kernel=lambda: fn, make_inputs=make_inputs,
        make_small_inputs=None)


def test_jaxpr_audit_catches_rank_and_budget_violations():
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import audit_kernel

    def dense_blowup(a, b, interpret=True):
        return (a[:, :, None] * b[None, :, :]).sum(-1)

    entry = audit_kernel(_fake_spec(dense_blowup, max_rank=2, budget=512))
    assert not entry["checks"]["rank_ok"]
    assert not entry["checks"]["budget_ok"]
    assert entry["max_rank"] == 3
    assert not entry["ok"]


def test_jaxpr_audit_catches_host_callbacks():
    import numpy as np

    from repro.analysis.jaxpr_audit import audit_kernel

    def with_callback(a, b, interpret=True):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(a.shape, a.dtype), a)

    entry = audit_kernel(_fake_spec(with_callback))
    assert not entry["checks"]["no_callbacks"]
    assert entry["callbacks"]
