"""Telemetry layer (repro.obs): observation-only contract + plumbing.

The load-bearing guarantee is *observation-only*: running any ``obs=``
mode produces bit-identical simulation metrics to ``obs="off"`` (the
golden contract extends through telemetry), checked here per engine
backend and via the ``REPRO_OBS`` env override that CI uses to replay
the golden suites with tracing forced on. The rest pins the probe's
accounting invariants (exclusive span times partition wall, counters
mirror the DES event stream), the ring-buffer series, the Chrome trace
round-trip, and the result-surface plumbing (phases/counters rows,
net_stats, the prefetch ledger, ScenarioSpec round-trips).
"""

import json

import numpy as np
import pytest

from repro.core import (GridConfig, OBS_MODES, ScenarioSpec, get_scenario,
                        run_experiment)
from repro.core.simulator import GridSimulator
from repro.launch.experiments import run_scenario, run_spec
from repro.obs import (CHANNELS, DEFAULT_OBS_INTERVAL_S, GridSampler, Probe,
                       RingBuffer, TraceWriter, make_probe)

METRICS = ("avg_job_time", "avg_inter_comms", "total_wan_gb", "total_lan_gb",
           "makespan", "completed_jobs")


def _metrics(r) -> tuple:
    return tuple(getattr(r, m) for m in METRICS)


# -- probe unit behaviour ---------------------------------------------------

def test_span_exclusive_accounting_partitions_wall():
    """Nested spans: the child's inclusive time is subtracted from the
    parent's self time, so self times are disjoint and sum <= wall."""
    p = Probe("report")
    with p.span("outer"):
        for _ in range(3):
            with p.span("inner"):
                sum(range(2000))
    assert p.phase_calls == {"outer": 1, "inner": 3}
    # outer's inclusive time covers the inners entirely
    assert p.phase_total_s["outer"] >= p.phase_total_s["inner"]
    # exclusive times: outer self excludes the inner inclusive time
    assert p.phase_self_s["outer"] == pytest.approx(
        p.phase_total_s["outer"] - p.phase_total_s["inner"])
    report = p.finalize()
    assert sum(report.phase_self_s.values()) <= report.wall_s


def test_probe_counters_and_merge():
    p = Probe("report")
    p.count("a")
    p.count("a", 2)
    p.event("SUBMIT", 1.0)
    p.merge_counters("net", {"x": 2, "y": 3.0})
    assert p.counters == {"a": 3, "event.SUBMIT": 1, "net.x": 2, "net.y": 3}
    assert isinstance(p.counters["net.y"], int)


def test_make_probe_modes():
    assert make_probe("off") is None
    assert make_probe("report").sampler is None
    assert make_probe("series").sampler is not None
    assert make_probe("series").trace is None
    tr = make_probe("trace")
    assert tr.sampler is not None and tr.trace is not None
    with pytest.raises(ValueError, match="unknown obs mode"):
        make_probe("verbose")


def test_deepcopy_drops_probe():
    """Sanitizer twins must not double-count into the primary's probe."""
    import copy
    assert copy.deepcopy(Probe("report")) is None


def test_phase_breakdown_partitions_wall():
    p = Probe("report")
    with p.span("broker.dispatch"):
        pass
    bd = p.finalize().phase_breakdown(wall_s=2.0)
    assert set(bd) == {"dispatch_s", "strategy_plan_s", "flush_s", "other_s"}
    assert sum(bd.values()) == pytest.approx(2.0, abs=1e-5)


# -- ring-buffer series -----------------------------------------------------

def test_ring_buffer_wraps_chronologically():
    rb = RingBuffer(4, ("t", "v"))
    for i in range(7):
        rb.append((float(i), float(10 * i)))
    assert rb.n_total == 7 and len(rb) == 4
    rows = rb.rows()
    assert rows[:, 0].tolist() == [3.0, 4.0, 5.0, 6.0]   # oldest survivor first
    assert rb.arrays()["v"].tolist() == [30.0, 40.0, 50.0, 60.0]


def test_series_channels_from_live_run():
    r = run_experiment(GridConfig(), n_jobs=60, obs="series")
    series = r.telemetry.series
    assert set(series) == set(CHANNELS)
    t = series["t"]
    assert r.telemetry.n_samples == len(t) > 1
    assert np.all(np.diff(t) > 0)                        # sim clock advances
    for ch in ("wan_bytes", "accesses", "completed_jobs"):
        assert np.all(np.diff(series[ch]) >= 0), ch      # cumulative channels
    assert series["completed_jobs"][-1] <= r.completed_jobs
    assert np.all(series["se_used_frac"] >= 0.0)
    assert np.all(series["se_used_frac"] <= 1.0)


# -- trace export -----------------------------------------------------------

def test_trace_round_trip_and_nesting(tmp_path):
    """Exported trace is valid Chrome-trace JSON and the host-phase
    complete events nest monotonically (no partial overlap)."""
    r = run_experiment(GridConfig(), n_jobs=60, obs="trace")
    tel = r.telemetry
    path = tmp_path / "run.trace.json"
    tel.save_trace(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    spans = sorted((e for e in events if e.get("ph") == "X"),
                   key=lambda e: (e["ts"], -e["dur"]))
    assert spans, "no host-phase spans exported"
    stack = []
    for e in spans:
        while stack and e["ts"] >= stack[-1]:
            stack.pop()
        if stack:                      # strictly nested, never straddling
            assert e["ts"] + e["dur"] <= stack[-1]
        stack.append(e["ts"] + e["dur"])
    instants = [e for e in events if e.get("ph") == "i"]
    # one sim-track instant per handled DES event (within the cap)
    n_events = sum(v for k, v in tel.counters.items()
                   if k.startswith("event."))
    assert len(instants) == n_events
    # JSONL event log round-trips line by line, metadata excluded
    jl = tmp_path / "run.events.jsonl"
    tel.save_events_jsonl(str(jl))
    lines = [json.loads(l) for l in jl.read_text().splitlines()]
    assert len(lines) == len(tel.trace)
    assert all(e["ph"] != "M" for e in lines)


def test_trace_writer_caps_events():
    tw = TraceWriter(max_events=3)
    for i in range(5):
        tw.add_instant("E", float(i))
    assert len(tw) == 3 and tw.dropped == 2
    assert tw.to_dict()["otherData"]["dropped_events"] == 2


# -- observation-only: goldens unchanged under every obs mode ---------------

@pytest.mark.parametrize("mode", ["report", "series", "trace"])
def test_obs_modes_bit_identical_numpy(mode):
    base = _metrics(run_experiment(GridConfig(), n_jobs=100))
    assert _metrics(run_experiment(GridConfig(), n_jobs=100, obs=mode)) == base


def test_obs_bit_identical_device_backend():
    base = _metrics(run_experiment(GridConfig(), n_jobs=100, net="device"))
    got = _metrics(run_experiment(GridConfig(), n_jobs=100, net="device",
                                  obs="trace"))
    assert got == base


def test_repro_obs_env_override(monkeypatch):
    """CI replays the golden suites with REPRO_OBS=trace; the override
    must attach telemetry without touching a single metric."""
    base = run_experiment(GridConfig(), n_jobs=100)
    assert base.telemetry is None
    monkeypatch.setenv("REPRO_OBS", "trace")
    forced = run_experiment(GridConfig(), n_jobs=100)
    assert forced.telemetry is not None and forced.telemetry.mode == "trace"
    assert _metrics(forced) == _metrics(base)
    monkeypatch.setenv("REPRO_OBS", "loud")
    with pytest.raises(ValueError, match="obs mode"):
        run_experiment(GridConfig(), n_jobs=10)


def test_obs_events_do_not_change_sim_clock_semantics():
    """Trailing OBS samples must not stretch the reported makespan."""
    base = run_experiment(GridConfig(), n_jobs=100)
    fine = run_experiment(GridConfig(), n_jobs=100, obs="series",
                          obs_interval=50.0)
    assert fine.makespan == base.makespan
    assert fine.telemetry.n_samples > 100


# -- counter/event-stream consistency ---------------------------------------

def _check_counter_invariants(seed: int) -> None:
    cfg = GridConfig(seed=seed, n_regions=2, sites_per_region=3)
    r = run_experiment(cfg, n_jobs=80, obs="series")
    tel = r.telemetry
    c, calls = tel.counters, tel.phase_calls
    # every handled event of a phase-mapped kind passed through its span
    assert c["event.SUBMIT"] + c.get("event.FLUSH", 0) == \
        calls["broker.dispatch"]
    assert c["event.CPU_DONE"] == calls["cpu.done"] == r.completed_jobs == 80
    assert c.get("event.NET", 0) == calls.get("net.events", 0)
    # one sample per OBS event plus the baseline sample taken at arming
    assert tel.n_samples == c.get("event.OBS", 0) + 1
    # exclusive phase times partition measured wall
    assert sum(tel.phase_self_s.values()) <= tel.wall_s + 1e-9
    for name, total in tel.phase_total_s.items():
        assert tel.phase_self_s[name] <= total + 1e-12, name


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_counter_invariants_seeded(seed):
    """Fixed-seed slice of the property probe — runs without hypothesis."""
    _check_counter_invariants(seed)


def test_counter_invariants_property():
    """Hypothesis-driven probe over arbitrary world seeds."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(seed=st.integers(0, 2**32 - 1))
    def probe(seed):
        _check_counter_invariants(seed)

    probe()


# -- result-surface plumbing ------------------------------------------------

def test_prefetch_ledger_surfaces():
    spec = get_scenario("paper_baseline")
    r = run_spec(spec, n_jobs=50)
    assert r.prefetches == 0 and r.prefetch_gb == 0.0
    econ = run_experiment(GridConfig(), strategy="economic", n_jobs=200,
                          econ_interval=500.0)
    assert econ.prefetches > 0 and econ.prefetch_gb > 0.0


def test_run_scenario_rows_carry_phases(tmp_path):
    spec = ScenarioSpec(name="obs_smoke", description="x",
                        tier_fanouts=(2, 3), n_jobs=60, seeds=(0,),
                        obs="trace")
    rows = run_scenario(spec, obs_dir=str(tmp_path))
    row = rows[0]
    assert set(row["phases"]) == {"dispatch_s", "strategy_plan_s",
                                  "flush_s", "other_s"}
    assert sum(row["phases"].values()) == pytest.approx(
        row["wall_s"], abs=0.1 * max(row["wall_s"], 0.01))
    assert row["counters"]["event.SUBMIT"] == 60
    assert (tmp_path / "obs_smoke_s0.telemetry.json").exists()
    assert (tmp_path / "obs_smoke_s0.trace.json").exists()
    assert (tmp_path / "obs_smoke_s0.events.jsonl").exists()


def test_scenario_spec_obs_round_trip():
    spec = ScenarioSpec(name="x", description="x", obs="series",
                        obs_interval_s=120.0)
    clone = ScenarioSpec.from_dict(spec.to_dict())
    assert clone.obs == "series" and clone.obs_interval_s == 120.0
    with pytest.raises(ValueError, match="obs"):
        ScenarioSpec(name="x", description="x", obs="loud")


def test_simulator_rejects_bad_obs_args():
    from repro.core.workload import build_catalog, build_topology, generate_jobs
    cfg = GridConfig()
    topo = build_topology(cfg)
    with pytest.raises(ValueError, match="obs mode"):
        GridSimulator(topo, build_catalog(cfg, topo), obs="loud")


def test_default_interval_exported():
    assert DEFAULT_OBS_INTERVAL_S == 300.0
    assert OBS_MODES == ("off", "report", "series", "trace")
    assert GridSampler().ring.capacity == 8192
