"""Deliberately unit-broken engine code — the units checker's test prey.

NOT imported by anything: :mod:`tests.test_units` feeds this file's
*source* to :func:`repro.analysis.units.lint_units` and asserts every
seeded violation is flagged (at least three distinct SL02x rules). Each
bug below is a realistic slip of the grid engine's own vocabulary:
engine state in bytes / bytes-per-second / sim-seconds, config fields
in Mbps, probe spans in wall-clock microseconds.
"""

from __future__ import annotations


class BrokenEngine:
    """A caricature of GridSimulator/NetworkEngine bookkeeping."""

    def __init__(self) -> None:
        self.now = 0.0
        self.total_wan_bytes = 0.0
        self.makespan = 0.0

    def advance(self, size, bandwidth, elapsed_us, link_mbps, n_bytes):
        # SL020: bytes + sim_seconds
        backlog = size + self.now
        # SL021: bytes compared against bytes_per_s
        if size > bandwidth:
            backlog = size
        # SL022: transfer time from bytes / Mbps (8e6/1e6 factor wrong)
        eta = n_bytes / link_mbps
        # SL023: sim-clock minus wall-clock probe span
        lag = self.now - elapsed_us
        # SL024: raw conversion literal scaling a dimensioned value
        gigs = n_bytes / 1e9
        # SL020 (AugAssign): seconds accumulated into a byte counter
        self.total_wan_bytes += self.now
        # SL025: makespan (sim_seconds) assigned a byte total
        self.makespan = n_bytes
        return backlog, eta, lag, gigs


def build_grid(make, spec_mbps):
    # SL022: Mbps config value bound to a bytes/s keyword unconverted
    return make(wan_bandwidth=spec_mbps)
