"""Scenario engine: spec round-trip, n-tier topology invariants, registry
completeness (scenarios, sweeps, and the full strategy registry), and a
2-scenario smoke through the runner."""

import json
import math

import pytest

from repro.core import (GridConfig, GridTopology, SCENARIOS, STRATEGIES,
                        SWEEPS, ScenarioSpec, SweepSpec, arrival_schedule,
                        get_scenario, get_sweep, to_grid_config, with_axis)
from repro.core.scenarios import ChurnSpec
from repro.fault.failures import churn_schedule


# -- registry ---------------------------------------------------------------
def test_registry_completeness():
    assert len(SCENARIOS) >= 8
    # the regimes the scenario engine exists to cover
    for name in ("paper_baseline", "deep_4tier", "deep_5tier", "fat_region",
                 "flash_crowd", "diurnal", "bulk_diana", "site_churn",
                 "cache_starved", "grid_500"):
        assert name in SCENARIOS, name
    for name, spec in SCENARIOS.items():
        assert spec.name == name
        assert spec.description and spec.probes, f"{name} is undocumented"
        # every registered spec must build a world without errors
        topo = __import__("repro.core", fromlist=["build_topology"]) \
            .build_topology(to_grid_config(spec))
        assert topo.n_sites == spec.n_sites


def test_get_scenario_unknown_name():
    with pytest.raises(KeyError, match="registered"):
        get_scenario("nope")


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_every_strategy_runs_paper_baseline(strategy):
    """Registry-completeness smoke: every STRATEGIES entry — including the
    access-aware pair — runs the paper baseline at 50 jobs without error
    and completes every job."""
    import dataclasses

    from repro.launch.experiments import run_spec
    spec = dataclasses.replace(SCENARIOS["paper_baseline"],
                               strategy=strategy)
    r = run_spec(spec, n_jobs=50)
    assert r.completed_jobs == r.n_jobs == 50
    assert r.avg_job_time > 0 and r.makespan > 0


def test_grid_500_smoke():
    """The 500-site scale scenario runs end to end at a reduced job count
    through the jitted batch broker (incremental presence bitmap + shared
    network snapshot hot paths)."""
    from repro.launch.experiments import run_spec
    spec = SCENARIOS["grid_500"]
    assert spec.n_sites == 500 and spec.n_jobs == 100_000
    r = run_spec(spec, n_jobs=200)
    assert r.completed_jobs == 200
    assert r.avg_job_time > 0 and r.makespan > 0


# -- sweeps ------------------------------------------------------------------
def test_sweep_registry_completeness():
    assert {"starved_strategies", "drift_strategies",
            "contended_nets", "baseline_wan"} <= set(SWEEPS)
    for name, sw in SWEEPS.items():
        assert sw.name == name and sw.description
        assert sw.base in SCENARIOS
        cells = sw.expand()
        assert len(cells) == len(sw.values)
        for v, cell in cells:
            assert cell.name == f"{sw.base}@{sw.axis}={v}"


def test_sweep_round_trip_and_validation():
    sw = SWEEPS["drift_strategies"]
    wire = json.loads(json.dumps(sw.to_dict()))
    assert SweepSpec.from_dict(wire) == sw
    with pytest.raises(ValueError, match="axis"):
        SweepSpec(name="bad", base="paper_baseline", axis="warp",
                  values=(1,))
    with pytest.raises(ValueError, match="value"):
        SweepSpec(name="bad", base="paper_baseline", axis="n_jobs",
                  values=())
    with pytest.raises(KeyError):
        get_sweep("nope")
    # a sweep cell inherits full spec validation
    bad = SweepSpec(name="bad", base="paper_baseline", axis="strategy",
                    values=("magic",))
    with pytest.raises(ValueError, match="strategy"):
        bad.expand()


def test_with_axis_vocabulary():
    base = SCENARIOS["paper_baseline"]
    assert with_axis(base, "n_jobs", 42).n_jobs == 42
    assert with_axis(base, "strategy", "economic").strategy == "economic"
    assert with_axis(base, "net", "pallas").net == "pallas"
    assert with_axis(base, "wan_mbps", 100.0).uplink_mbps[0] == 100.0
    with pytest.raises(ValueError, match="axis"):
        with_axis(base, "name", "x")


def test_sweep_runner_writes_grid(tmp_path):
    from repro.launch.experiments import run_scenarios
    out = tmp_path / "bench.json"
    payload = run_scenarios(["baseline_wan"], n_jobs=20, out_path=str(out),
                            quiet=True)
    entry = payload["sweeps"]["baseline_wan"]
    rows = entry["rows"]
    assert len(rows) == len(SWEEPS["baseline_wan"].values)
    assert {r["wan_mbps"] for r in rows} == set(
        SWEEPS["baseline_wan"].values)
    for r in rows:
        assert r["completed_jobs"] == 20
    assert json.loads(out.read_text())["sweeps"]["baseline_wan"][
        "sweep"]["axis"] == "wan_mbps"


def test_spec_validation():
    with pytest.raises(ValueError, match="uplink"):
        ScenarioSpec(name="bad", tier_fanouts=(2, 3, 4))  # missing uplink bw
    with pytest.raises(ValueError, match="arrival"):
        ScenarioSpec(name="bad", arrival="bursty")
    with pytest.raises(ValueError, match="strategy"):
        ScenarioSpec(name="bad", strategy="magic")
    with pytest.raises(ValueError, match="econ"):
        ScenarioSpec(name="bad", econ="cuda")
    # drift needs a Zipf workload: fixed filesets cannot shift
    with pytest.raises(ValueError, match="Zipf"):
        ScenarioSpec(name="bad", zipf_alpha=None, hotset_shifts=2)


# -- serialization ----------------------------------------------------------
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_spec_round_trip(name):
    spec = SCENARIOS[name]
    wire = json.loads(json.dumps(spec.to_dict()))   # through real JSON
    assert ScenarioSpec.from_dict(wire) == spec


def test_baseline_lowers_to_golden_grid_config():
    """The paper-baseline scenario must hit the exact GridConfig the
    golden-metrics suite pins — same floats, same defaults."""
    assert to_grid_config(SCENARIOS["paper_baseline"]) == GridConfig()


# -- n-tier topology invariants --------------------------------------------
@pytest.mark.parametrize("fanouts,uplinks", [
    ((2, 3), (1.25e6,)),
    ((2, 3, 4), (1.25e6, 12.5e6)),
    ((2, 2, 2, 3), (1.25e6, 6.25e6, 12.5e6)),
])
def test_ntier_invariants(fanouts, uplinks):
    topo = GridTopology(0, 0, lan_bandwidth=125e6, wan_bandwidth=uplinks[0],
                        storage_capacity=1e10, tier_fanouts=fanouts,
                        uplink_bandwidths=uplinks)
    n = math.prod(fanouts)
    assert topo.n_sites == n
    assert topo.n_regions * topo.sites_per_region == n
    # region partition: disjoint cover
    seen = set()
    for region in topo.regions:
        assert not seen & set(region.site_ids)
        seen.update(region.site_ids)
    assert seen == set(range(n))
    # uplink count: one per internal node
    expected_links = 0
    nodes = 1
    for f in fanouts[:-1]:
        nodes *= f
        expected_links += nodes
    assert len(topo.wan_links) == expected_links
    for a in range(n):
        for b in range(n):
            if a == b:
                continue
            # reachability: every pair has a positive-bandwidth path
            links = topo.links_for(a, b)
            assert links and all(l.bandwidth > 0 for l in links)
            assert topo.point_bandwidth(a, b) > 0
            # link symmetry: crossing the hierarchy is direction-independent
            ua, ub = topo.uplink_index(a, b), topo.uplink_index(b, a)
            assert (ua >= 0) == (ub >= 0)
            assert topo.is_inter_region(a, b) == topo.is_inter_region(b, a)
            if ua >= 0:
                # a source-side uplink belongs to the source's ancestry
                off = ua - [o for o in topo._uplink_offset if o <= ua][-1]
                assert off in topo.ancestors(a)


def test_two_level_fanouts_match_classic_form():
    classic = GridTopology(3, 4, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                           storage_capacity=1e10)
    tiered = GridTopology(0, 0, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                          storage_capacity=1e10, tier_fanouts=(3, 4))
    assert classic.n_sites == tiered.n_sites == 12
    assert len(classic.wan_links) == len(tiered.wan_links) == 3
    for a in range(12):
        assert classic.region_of(a) == tiered.region_of(a)
        for b in range(12):
            assert classic.uplink_index(a, b) == tiered.uplink_index(a, b)
            if a != b and not classic.same_region(a, b):
                # two-level invariant the simulator's slot arrays rely on
                assert classic.uplink_index(a, b) == classic.region_of(a)


def test_heterogeneity_knobs_reject_bad_targets():
    common = dict(lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                  storage_capacity=1e10)
    with pytest.raises(ValueError, match="uplink_scale level"):
        GridTopology(2, 2, uplink_scale=((0, 0, 10.0),), **common)
    with pytest.raises(ValueError, match="uplink_scale node"):
        GridTopology(2, 2, uplink_scale=((1, 2, 10.0),), **common)
    with pytest.raises(ValueError, match="storage_scale region"):
        GridTopology(2, 2, storage_scale=((7, 0.1),), **common)


def test_heterogeneity_knobs():
    topo = GridTopology(2, 2, lan_bandwidth=125e6, wan_bandwidth=1.25e6,
                        storage_capacity=1e10,
                        uplink_scale=((1, 0, 10.0),),
                        storage_scale=((1, 0.25),))
    assert topo.wan_links[0].bandwidth == 12.5e6     # fat region 0
    assert topo.wan_links[1].bandwidth == 1.25e6
    assert topo.sites[0].storage_capacity == 1e10    # region 0 untouched
    assert topo.sites[2].storage_capacity == 2.5e9   # region 1 starved


# -- arrival processes ------------------------------------------------------
def _spec(**kw):
    return ScenarioSpec(name="t", description="d", probes="p", **kw)


def test_uniform_arrivals_use_default_path():
    assert arrival_schedule(_spec(), 100) is None


@pytest.mark.parametrize("arrival", ["poisson", "flash_crowd", "diurnal"])
def test_arrival_processes(arrival):
    spec = _spec(arrival=arrival)
    n = 300
    times = arrival_schedule(spec, n, seed=3)
    assert len(times) == n
    assert times[0] == 0.0
    assert all(b >= a for a, b in zip(times, times[1:]))   # nondecreasing
    assert times == arrival_schedule(spec, n, seed=3)      # deterministic
    # same mean rate (within process-specific tolerance): the whole stream
    # spans roughly n * interarrival seconds
    uniform_span = n * spec.interarrival_s
    assert 0.4 * uniform_span < times[-1] <= 1.9 * uniform_span


def test_flash_crowd_compresses_the_burst():
    spec = _spec(arrival="flash_crowd", crowd_at=0.5, crowd_frac=0.2,
                 crowd_factor=10.0)
    times = arrival_schedule(spec, 100, seed=0)
    gaps = [b - a for a, b in zip(times, times[1:])]
    assert min(gaps) == spec.interarrival_s / 10.0
    assert max(gaps) == spec.interarrival_s


# -- injections -------------------------------------------------------------
def test_churn_schedule_deterministic_and_bounded():
    spec = ChurnSpec(n_failures=5, window=(1000.0, 9000.0),
                     mean_downtime_s=2000.0)
    events = churn_schedule(spec, n_sites=8, seed=7)
    assert events == churn_schedule(spec, n_sites=8, seed=7)
    assert len(events) == 5
    sites = [s for s, _, _ in events]
    assert len(set(sites)) == 5                      # no site hit twice
    for site, at, duration in events:
        assert 0 <= site < 8
        assert 1000.0 <= at <= 9000.0
        assert duration >= 1.0
    assert churn_schedule(ChurnSpec(), n_sites=8) == []


# -- the runner -------------------------------------------------------------
def test_runner_bare_filename_out(tmp_path, monkeypatch):
    from repro.launch.experiments import run_scenarios
    monkeypatch.chdir(tmp_path)
    run_scenarios(["paper_baseline"], n_jobs=5, out_path="out.json",
                  quiet=True)
    assert json.loads((tmp_path / "out.json").read_text())["scenarios"]


def test_runner_two_scenario_smoke(tmp_path):
    from repro.launch.experiments import ROW_KEYS, run_scenarios
    out = tmp_path / "BENCH_scenarios.json"
    payload = run_scenarios(["paper_baseline", "deep_4tier"], n_jobs=40,
                            out_path=str(out), quiet=True)
    on_disk = json.loads(out.read_text())
    assert set(on_disk["scenarios"]) == {"paper_baseline", "deep_4tier"}
    for name, entry in payload["scenarios"].items():
        assert ScenarioSpec.from_dict(entry["spec"]) == SCENARIOS[name]
        for row in entry["rows"]:
            for key in ROW_KEYS:
                assert key in row, (name, key)
            assert row["completed_jobs"] == row["n_jobs"] == 40
            assert row["avg_job_time_s"] > 0
            assert row["makespan_s"] > 0
