"""AccessHistory: decay math against hand-computed fixtures, accounting
parity with the simulator's own metrics, and aggregation-view properties."""

import numpy as np
import pytest

from repro.core import (AccessHistory, GridConfig, GridSimulator,
                        ReplicaCatalog, build_catalog, build_topology,
                        generate_jobs)


def _world(n_regions=2, sites=3, n_files=6):
    cfg = GridConfig(n_regions=n_regions, sites_per_region=sites)
    topo = build_topology(cfg)
    cat = ReplicaCatalog()
    for i in range(n_files):
        cat.register_file(f"lfn{i:04d}", 1e6, i % topo.n_sites)
    return topo, cat


# -- decay math (hand-computed fixture) -------------------------------------
def test_decay_hand_computed():
    topo, cat = _world()
    h = AccessHistory(cat, topo, half_life_s=10.0)
    h.record_access(0, "lfn0000", now=0.0)
    # one half-life later the first unit is worth 0.5; add another
    h.record_access(0, "lfn0000", now=10.0)
    assert h.site_counts(0, now=10.0)[0] == pytest.approx(1.5)
    # one more half-life, no new accesses
    assert h.site_counts(0, now=20.0)[0] == pytest.approx(0.75)
    # a different cell is untouched
    assert h.site_counts(1, now=20.0)[0] == 0.0
    assert h.accesses == 2


def test_decay_weight_and_snapshot_normalization():
    topo, cat = _world()
    h = AccessHistory(cat, topo, half_life_s=100.0)
    h.record_access(2, "lfn0001", now=0.0, weight=4.0)
    snap = h.snapshot(now=200.0)          # two half-lives
    fidx = h.lfn_index["lfn0001"]
    assert snap[2, fidx] == pytest.approx(1.0)
    # snapshot normalized in place: stamps moved, counts rescaled, and a
    # second snapshot at the same now is identical
    assert np.array_equal(h.snapshot(now=200.0), snap)


def test_scores_ordering_is_time_shift_invariant():
    topo, cat = _world()
    h = AccessHistory(cat, topo, half_life_s=50.0)
    h.record_access(1, "lfn0000", now=0.0, weight=8.0)   # old and big
    h.record_access(1, "lfn0001", now=100.0)             # fresh and small
    lfns = ["lfn0000", "lfn0001"]
    order_now = np.argsort(h.scores(1, lfns))
    later = h.site_counts(1, now=500.0)[[h.lfn_index[l] for l in lfns]]
    assert np.array_equal(order_now, np.argsort(later))


def test_invalid_half_life_rejected():
    topo, cat = _world()
    with pytest.raises(ValueError):
        AccessHistory(cat, topo, half_life_s=0.0)


def test_sync_picks_up_late_registered_files():
    topo, cat = _world(n_files=2)
    h = AccessHistory(cat, topo, half_life_s=10.0)
    h.record_access(0, "lfn0001", now=0.0, weight=3.0)
    cat.register_file("lfn0000a", 2e6, 1)   # sorts between the two
    h.record_access(0, "lfn0000a", now=0.0)
    # old counts carried over by LFN, new column live
    assert h.site_counts(0, now=0.0)[h.lfn_index["lfn0001"]] == 3.0
    assert h.site_counts(0, now=0.0)[h.lfn_index["lfn0000a"]] == 1.0
    assert h.sizes[h.lfn_index["lfn0000a"]] == 2e6


# -- aggregation views -------------------------------------------------------
def test_region_counts_equal_sum_of_member_sites():
    """Property: for random access patterns, every region row equals the
    sum of its member sites' rows, and the grid view sums the regions."""
    topo, cat = _world(n_regions=3, sites=4, n_files=8)
    rng = np.random.default_rng(7)
    for trial in range(5):
        h = AccessHistory(cat, topo, half_life_s=30.0)
        t = 0.0
        for _ in range(200):
            t += float(rng.exponential(5.0))
            h.record_access(int(rng.integers(topo.n_sites)),
                            f"lfn{int(rng.integers(8)):04d}", now=t,
                            weight=float(rng.random() + 0.1))
        snap = h.snapshot()
        regional = h.region_counts()
        for region in topo.regions:
            np.testing.assert_allclose(
                regional[region.region_id],
                snap[region.site_ids].sum(axis=0), rtol=1e-12)
        np.testing.assert_allclose(h.grid_counts(), regional.sum(axis=0),
                                   rtol=1e-12)


# -- accounting parity with the simulator ------------------------------------
def _run_sim(strategy="hrs", n_jobs=60, **sim_kw):
    cfg = GridConfig(n_regions=2, sites_per_region=4)
    topo = build_topology(cfg)
    cat = build_catalog(cfg, topo)
    sim = GridSimulator(topo, cat, strategy=strategy, **sim_kw)
    for info in cat.files.values():
        sim.storage.bootstrap(info.master_site, info.lfn)
    jobs = generate_jobs(cfg, n_jobs)
    for j, job in enumerate(jobs):
        sim.submit_job(job, at=j * 60.0)
    return sim, jobs, sim.run()


def test_accounting_parity_with_sim_counters():
    """The history's fetch counters are incremented at exactly the points
    the simulator accounts its own metrics, so they agree by construction
    (reactive strategy: no prefetch traffic in either ledger)."""
    sim, jobs, res = _run_sim("hrs")
    h = sim.access
    assert h.accesses == sum(len(j.required) for j in jobs)
    assert h.remote_fetches == res.total_inter_comms
    assert h.wan_bytes == res.total_wan_bytes
    assert h.lan_bytes == res.total_lan_bytes
    assert h.prefetches == 0 and h.prefetch_bytes == 0.0
    assert 0 < h.hits <= h.accesses
    assert h.fetches >= h.remote_fetches


def test_prefetch_accounting_separated():
    """With the economy armed, proactive transfers land in the prefetch
    ledger, never in the per-job fetch one; job-driven WAN bytes stay a
    subset of the simulator's total."""
    sim, jobs, res = _run_sim("predictive", n_jobs=80)
    h = sim.access
    assert len(res.records) == 80
    assert h.prefetches > 0
    assert h.remote_fetches == res.total_inter_comms
    assert h.wan_bytes + h.lan_bytes + h.prefetch_bytes == pytest.approx(
        res.total_wan_bytes + res.total_lan_bytes)


def test_observation_does_not_perturb_reactive_runs():
    """The tracker is pure observation: an HRS run is bit-identical
    whether or not anything ever reads the history."""
    _, _, a = _run_sim("hrs", n_jobs=40)
    _, _, b = _run_sim("hrs", n_jobs=40)
    assert a.avg_job_time == b.avg_job_time
    assert a.total_wan_bytes == b.total_wan_bytes
